"""Fig. 11 (beyond-paper): the elastic coding plane under estimated rates
and mid-run membership changes.

Fig. 9 established that rate-aware encode weights need the per-rank
participation rates q_i — but it fed them from the ORACLE
(`StragglerProcess.rates()`), which no production system has.  This sweep
closes the loop the way `launch.train --elastic` does: a bias-corrected
online `RateEstimator` learns q_i from the observed masks and a
`CodingPlan` refits the encode weights every step, re-running the greedy
`rate_aware_allocation` only when the estimates drift past the replan
threshold.  Three methods, identical wire payloads:

  oracle      rate-aware weights + allocation from the true q_i (fig9's
              best case — the ceiling)
  estimated   the live plane: weights from the online estimate, replans
              on drift (what a real deployment can actually run)
  mean_rate   eq. 3 weights from the scalar mean rate (the floor)

Halfway through every run the fleet SHRINKS to 3N/4 ranks: the error
vectors of the survivors ride `checkpoint.elastic_rescale_ef`, the subset
count M stays fixed, every method replans its allocation for the new
fleet, and the estimated method additionally carries the survivors' rate
statistics through `RateEstimator.resize`.  The acceptance criterion is
that the estimated curve's time-to-target stays close to the oracle's
(~10%) and the membership change does not reset the loss curve.

Emits results/repro/fig11.json.  `--perf-floor` additionally times the
1024-rank fleet hot paths (allocation + mask sampling + StepTimer) against
a wall-clock budget and exits non-zero on violation (the CI elastic-smoke
job runs both).

  PYTHONPATH=src python benchmarks/fig11_elastic.py [--smoke] [--perf-floor]
"""
import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint import elastic_rescale_ef
from repro.core import coding, compression as C, error_feedback as EF
from repro.core.coding_state import CodingPlan, RateEstimator, maybe_replan
from repro.core.collectives import SignWire
from repro.core.plan import PlanSpec
from repro.sim import (DEFAULT_COMPUTE, DEFAULT_LINK, HeterogeneousRates,
                       MarkovBursty, StepTimer, TraceReplay,
                       elastic_replan_hook)

try:
    from . import _repro_common as R
except ImportError:                      # run as a script
    import _repro_common as R

OUT = None                # optional override; default R.results_dir()

N_WIRE = 1 << 22          # production wire scale (ROADMAP comm table)

METHODS = ("oracle", "estimated", "mean_rate")

P_SLOW, P_FAST, SLOW_FRACTION = 0.8, 0.02, 0.3

PERF_N, PERF_MASKS = 1024, 1000          # the 1000-rank fleet floor
PERF_BUDGET_S = 30.0


def _phase_processes(N, N2, smoke=False):
    """(phase-1 process at N ranks, phase-2 process at N2 ranks) per
    straggler family.  Phase 2 keeps each family's structure on the
    surviving ranks: hetero keeps the survivors' p_i, markov restarts the
    chain on the smaller fleet, trace replays the survivors' columns."""
    two = HeterogeneousRates.two_class(N, p_slow=P_SLOW, p_fast=P_FAST,
                                       slow_fraction=SLOW_FRACTION)
    rows = np.array(two.sample_trace(jax.random.PRNGKey(99),
                                     24 if smoke else 64))
    burst = 4.0 if smoke else 8.0
    return {
        "hetero": (two, HeterogeneousRates(num_devices=N2,
                                           p_ranks=two.p_ranks[:N2])),
        "markov": (MarkovBursty(num_devices=N, p=0.2, mean_burst=burst),
                   MarkovBursty(num_devices=N2, p=0.2, mean_burst=burst)),
        "trace": (TraceReplay.from_array(rows),
                  TraceReplay.from_array(rows[:, :N2])),
    }


def _mean_p(proc) -> float:
    return float(1.0 - np.asarray(proc.rates()).mean())


def _plan_for(method, proc, M, d, p_bar, est=None, replan_hook=None):
    """(W provider, per-phase static W or live plan) for one method."""
    rates = np.asarray(proc.rates())
    if method == "oracle":
        alloc = coding.rate_aware_allocation(rates, M, d)
        return coding.encode_weights(alloc, rates=rates), None
    if method == "mean_rate":
        alloc = coding.rate_aware_allocation(
            np.full((proc.num_devices,), 1.0 - p_bar), M, d)
        return coding.encode_weights(alloc, p_bar), None
    # estimated: the planner starts from the uniform mean-rate guess (all
    # a fresh deployment knows) and learns the rest online; the optional
    # hook re-runs the PlanSpec pruning stage on every drift replan
    plan = CodingPlan.create(np.full((proc.num_devices,), 1.0 - p_bar),
                             M, d, replan_hook=replan_hook)
    return None, plan


def _run_elastic_trial(method, procs, T, T1, M, d, gamma, seed,
                       record_every, timer, replan_hook=None):
    """One trial of one method through the membership change.  Returns a
    history dict with time_s attached (phase timelines concatenated) and
    replan diagnostics."""
    proc_a, proc_b = procs
    N, N2 = proc_a.num_devices, proc_b.num_devices
    p_bar = _mean_p(proc_a)
    grad_fn, loss_fn, theta0, _ = R.tasks.linreg_task(
        seed=seed, num_subsets=M, dim=M // 2)
    trace_a = np.asarray(proc_a.sample_trace(jax.random.PRNGKey(1000 + seed),
                                             T1), np.float32)
    trace_b = np.asarray(proc_b.sample_trace(jax.random.PRNGKey(5000 + seed),
                                             T - T1), np.float32)
    times = np.concatenate([timer.steps(trace_a)[0], timer.steps(trace_b)[0]])
    cum = np.cumsum(times)

    est = RateEstimator(N) if method == "estimated" else None
    W, plan = _plan_for(method, proc_a, M, d, p_bar, est,
                        replan_hook=replan_hook)
    comp = C.GroupedSign()
    st = EF.EFState.init(theta0, N)
    hist = {"step": [], "loss": [], "time_s": []}
    replans = 0
    last_ranking = None

    def record(t):
        hist["step"].append(t)
        hist["loss"].append(float(loss_fn(st.theta)))
        hist["time_s"].append(float(cum[t]))

    for t in range(T):
        if t == T1:
            # ---- membership change: N -> N2, M fixed -------------------
            e2 = np.asarray(elastic_rescale_ef(
                np.asarray(st.e)[:, None, :], (N, 1), (N2, 1),
                st.e.shape[-1]))[:, 0]
            st = EF.EFState(theta=st.theta, e=jax.numpy.asarray(e2))
            if method == "estimated":
                est.resize(N2)            # survivors keep their statistics
                plan.resize(est.rates, M)
                replans += 1
            else:
                W, _ = _plan_for(method, proc_b, M, d, p_bar)
        mask = (trace_a[t] if t < T1 else trace_b[t - T1])
        if method == "estimated":
            state, info = maybe_replan(
                plan, est.rates if est.steps_seen.any() else None)
            replans += int(info["reallocated"])
            if "plan_ranking" in info:
                last_ranking = info["plan_ranking"]
            W = np.asarray(state.W)
        st = EF.cocoef_step(st, grad_fn, W, mask, gamma, comp, step=t)
        if method == "estimated":
            est.update(mask)
        if t % record_every == 0 or t == T - 1:
            record(t)
    hist["replans"] = replans
    hist["plan_ranking"] = last_ranking
    return hist


def run(trials=3, T=400, N=64, gamma=2e-5, record_every=20, d=3,
        n_wire=N_WIRE, link=DEFAULT_LINK, compute=DEFAULT_COMPUTE,
        smoke=False, out_dir=None):
    if smoke:
        trials, T, N, record_every, gamma = 1, 120, 16, 5, 1e-4
    N2 = 3 * N // 4
    M, T1 = N, T // 2
    # every method ships the identical sign wire: one PlanSpec prices the
    # shared StepTimer AND seeds the drift-triggered planner re-invocation
    plan_spec = R.plan_from_args(base=PlanSpec(d=d, compressor="sign",
                                               group_size=512))
    timer = R.plan_timer(plan_spec, n_wire, link, compute)
    hook = elastic_replan_hook(n_wire, link=link, compute=compute)
    res = {"meta": {**R.run_metadata(), "n_wire": n_wire, "trials": trials,
                    "T": T, "N": N, "N_after": N2, "resize_step": T1,
                    "M": M, "d": d, "gamma": gamma,
                    "plan": plan_spec.to_dict(),
                    "two_class": {"p_slow": P_SLOW, "p_fast": P_FAST,
                                  "slow_fraction": SLOW_FRACTION},
                    "link": dataclasses.asdict(link),
                    "compute": dataclasses.asdict(compute)},
           "curves": {}, "summary": {}}

    for pname, procs in _phase_processes(N, N2, smoke=smoke).items():
        curves, replans = {}, {}
        rankings = {}
        for mname in METHODS:
            per_trial = [
                _run_elastic_trial(mname, procs, T, T1, M, d, gamma, s,
                                   record_every, timer, replan_hook=hook)
                for s in range(trials)]
            replans[mname] = float(np.mean([h.pop("replans")
                                            for h in per_trial]))
            ranked = [h.pop("plan_ranking") for h in per_trial]
            if mname == "estimated" and any(r for r in ranked):
                # last drift replan's analytic top pick (trial 0 with one)
                top = next(r for r in ranked if r)[0]
                rankings["drift_top_plan"] = top
            curves[mname] = R.summarize_trials(
                per_trial, keys=("loss", "time_s"))
        target, t2t = R.target_and_t2t(curves)
        # loss continuity through the resize: the recorded losses straddling
        # step T1 must not blow back up toward the start
        steps = np.asarray(curves["estimated"]["step"])
        loss = np.asarray(curves["estimated"]["loss"])
        pre = loss[steps < T1][-1]
        post = loss[steps >= T1][0]
        summary = {"target_loss": target, "time_to_target_s": t2t,
                   "mean_replans": replans, **rankings,
                   "final_loss": {m: c["loss"][-1]
                                  for m, c in curves.items()},
                   "resize_loss_pre": float(pre),
                   "resize_loss_post": float(post),
                   "resize_continuous": bool(
                       post < loss[0] and post < 2.0 * max(pre, target))}
        if t2t["estimated"] and t2t["oracle"]:
            summary["estimated_vs_oracle_slowdown"] = \
                t2t["estimated"] / t2t["oracle"]
        res["curves"][pname] = curves
        res["summary"][pname] = summary

    out = Path(out_dir) if out_dir else (OUT or R.results_dir())
    out.mkdir(parents=True, exist_ok=True)
    (out / "fig11.json").write_text(json.dumps(res, indent=1))
    return res


def run_perf_floor(budget_s=PERF_BUDGET_S):
    """The 1000-rank fleet floor: allocation + mask sampling + StepTimer
    must stay interactive (the elastic plane runs these on the host every
    replan / every step).  Returns the timings; asserts the budget."""
    N, T = PERF_N, PERF_MASKS
    rng = np.random.default_rng(0)
    rates = np.clip(rng.uniform(0.3, 0.99, N), 0.0, 0.99)
    t0 = time.perf_counter()
    alloc = coding.rate_aware_allocation(rates, N, 3)
    t_alloc = time.perf_counter() - t0

    proc = HeterogeneousRates.linear(N, 0.2)
    t0 = time.perf_counter()
    trace = np.asarray(proc.sample_trace(jax.random.PRNGKey(0), T))
    t_masks = time.perf_counter() - t0

    timer = StepTimer(wire=SignWire(group_size=512), n=N_WIRE)
    t0 = time.perf_counter()
    times, _, _ = timer.steps(trace)
    t_timer = time.perf_counter() - t0

    est = RateEstimator(N)
    t0 = time.perf_counter()
    for t in range(T):
        est.update(trace[t])
    t_est = time.perf_counter() - t0

    total = t_alloc + t_masks + t_timer + t_est
    out = {"N": N, "masks": T, "budget_s": budget_s,
           "alloc_s": t_alloc, "mask_sample_s": t_masks,
           "steptimer_s": t_timer, "estimator_s": t_est, "total_s": total,
           "alloc_replicas": int(np.asarray(alloc.S).sum()),
           "mean_step_s": float(times.mean())}
    print(f"perf floor (N={N}): alloc={t_alloc:.2f}s "
          f"masks={t_masks:.2f}s timer={t_timer:.2f}s "
          f"estimator={t_est:.2f}s total={total:.2f}s "
          f"(budget {budget_s:.0f}s)")
    if total > budget_s:
        raise SystemExit(f"perf floor VIOLATED: {total:.2f}s > "
                         f"{budget_s:.0f}s for the {N}-rank fleet")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny configuration for CI (1 trial, 120 steps, "
                         "16 ranks)")
    ap.add_argument("--perf-floor", action="store_true",
                    help="also time the 1024-rank fleet hot paths against "
                         "a wall-clock budget (non-zero exit on violation)")
    ap.add_argument("--trials", type=int, default=3)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--out", default=None,
                    help="output directory (default: $REPRO_RESULTS_DIR "
                         "or results/repro)")
    args = ap.parse_args()
    perf = run_perf_floor() if args.perf_floor else None
    res = run(trials=args.trials, T=args.steps, smoke=args.smoke,
              out_dir=args.out)
    if perf is not None:
        out = Path(args.out) if args.out else (OUT or R.results_dir())
        res["meta"]["perf_floor"] = perf
        (out / "fig11.json").write_text(json.dumps(res, indent=1))
    for pname, s in res["summary"].items():
        t2t = ", ".join(
            f"{m}={v:.2f}s" if v is not None else f"{m}=never"
            for m, v in s["time_to_target_s"].items())
        slow = s.get("estimated_vs_oracle_slowdown")
        print(f"{pname:8s} target={s['target_loss']:.1f}  {t2t}"
              + (f"  estimated/oracle x{slow:.2f}" if slow else "")
              + f"  replans={s['mean_replans']}"
              + f"  resize {'ok' if s['resize_continuous'] else 'RESET'}")
    sys.exit(0)


if __name__ == "__main__":
    main()
