"""Roofline table assembled from the cached dry-run artifacts.

For each (arch x shape x mesh) cell: the three roofline terms, the dominant
bottleneck, MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE), and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPS (catches remat/redundancy/
padding waste — note gradient-coding redundancy d intentionally recomputes
d x, so ~1/d is expected for train cells).
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import REGISTRY, STANDARD_SHAPES
from repro.nn import Model

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"

# active params per token (MoE: shared + top-k routed + attn + embed read)
_ACTIVE_FRACTION_CACHE = {}


def active_params(arch_id: str) -> int:
    if arch_id in _ACTIVE_FRACTION_CACHE:
        return _ACTIVE_FRACTION_CACHE[arch_id]
    spec = REGISTRY[arch_id]
    cfg = spec.config
    total = Model(cfg).num_params()
    if cfg.moe_experts:
        # experts: only top_k (+ shared) of moe_experts are active
        expert_p = cfg.moe_experts * cfg.moe_ff * cfg.d_model * 3
        layers_with_moe = (cfg.num_layers - cfg.moe_first_dense
                           if cfg.family == "deepseek" else cfg.num_layers)
        total_expert = expert_p * layers_with_moe
        active_expert = total_expert * cfg.moe_top_k / cfg.moe_experts
        total = total - total_expert + active_expert
    _ACTIVE_FRACTION_CACHE[arch_id] = int(total)
    return int(total)


def model_flops(arch_id: str, shape_name: str, n_code: int, b_loc: int,
                seq: int, is_train: bool, batch: int) -> float:
    """6*N*D for train (fwd+bwd), 2*N*D for inference, over the tokens the
    cell actually processes (per step)."""
    n_active = active_params(arch_id)
    if is_train:
        tokens = n_code * b_loc * seq      # includes coding redundancy
        unique = REGISTRY[arch_id].shapes[shape_name].global_batch * seq
        return 6.0 * n_active * tokens, 6.0 * n_active * unique
    if shape_name.startswith("prefill"):
        tokens = batch * seq
    else:
        tokens = batch                     # one new token per request
    f = 2.0 * n_active * tokens
    return f, f


def load_cells(mode: str = "cocoef", tag: str = ""):
    rows = []
    sfx = f"_{tag}" if tag else ""
    for f in sorted(RESULTS.glob(f"*__{mode}{sfx}.json")):
        rec = json.loads(f.read_text())
        rows.append(rec)
    return rows


def table(mode: str = "cocoef", tag: str = ""):
    rows = []
    for rec in load_cells(mode, tag):
        if rec["status"] != "ok":
            rows.append({**rec, "summary": rec.get("reason",
                                                   rec.get("error", ""))})
            continue
        arch, shp, mesh = rec["arch"], rec["shape"], rec["mesh"]
        ndev = 512 if mesh == "multi" else 256
        is_train = shp.startswith("train")
        mf_total, mf_unique = model_flops(
            arch, shp, rec.get("n_code", 1), rec.get("b_loc", 0),
            REGISTRY[arch].shapes[shp].seq_len, is_train,
            REGISTRY[arch].shapes[shp].global_batch)
        hlo_flops_total = rec["cost"].get("flops", 0.0) * ndev
        r = rec["roofline"]
        rows.append({
            "arch": arch, "shape": shp, "mesh": mesh, "status": "ok",
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": r["dominant"],
            "model_flops": mf_total, "model_flops_unique": mf_unique,
            "hlo_flops_total": hlo_flops_total,
            "useful_ratio": (mf_unique / hlo_flops_total
                             if hlo_flops_total else 0.0),
            "roofline_fraction": r["roofline_fraction"],
            "peak_bytes": rec["memory"]["peak_estimate_bytes"],
            "wire_bytes": rec["collectives"]["wire_bytes_per_device"],
        })
    return rows


def main():
    rows = table()
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':6s} {'comp_ms':>8s} "
           f"{'mem_ms':>8s} {'coll_ms':>8s} {'dom':>10s} {'useful':>7s} "
           f"{'roofl%':>7s} {'peakGB':>7s}")
    print(hdr)
    for r in rows:
        if r.get("status") != "ok":
            print(f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:6s} "
                  f"-- {r.get('summary','')[:60]}")
            continue
        print(f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:6s} "
              f"{r['compute_s']*1e3:8.2f} {r['memory_s']*1e3:8.2f} "
              f"{r['collective_s']*1e3:8.2f} {r['dominant']:>10s} "
              f"{r['useful_ratio']:7.3f} {r['roofline_fraction']*100:6.1f}% "
              f"{r['peak_bytes']/2**30:7.1f}")


if __name__ == "__main__":
    main()
