"""Render the final §Roofline table (markdown) from cached dry-run JSONs
and append/replace it in EXPERIMENTS.md below the marker line."""
from pathlib import Path

from benchmarks import roofline

MARK = "(table inserted by the final sweep — see §Roofline-table below)"
ROOT = Path(__file__).resolve().parents[1]


def render():
    rows = roofline.table()
    out = ["", "### §Roofline-table (single-pod + multi-pod, all cells)", "",
           "| arch | shape | mesh | comp_ms | mem_ms | coll_ms | dominant |"
           " useful | roofl% | peakGB |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"— skipped: {r.get('summary','')[:70]} |||||||")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']*1e3:.1f} | {r['memory_s']*1e3:.1f} "
            f"| {r['collective_s']*1e3:.1f} | {r['dominant']} "
            f"| {max(0, r['useful_ratio']):.2f} "
            f"| {r['roofline_fraction']*100:.1f}% "
            f"| {r['peak_bytes']/2**30:.1f} |")
    ok = [r for r in rows if r.get("status") == "ok"]
    trains = [r for r in ok if r["shape"].startswith("train")]
    out += ["",
            f"{len(ok)} cells compiled ok; "
            f"train-cell roofline fractions: "
            f"min {min(r['roofline_fraction'] for r in trains)*100:.1f}%, "
            f"median {sorted(r['roofline_fraction'] for r in trains)[len(trains)//2]*100:.1f}%, "
            f"max {max(r['roofline_fraction'] for r in trains)*100:.1f}%. "
            "Decode cells are bandwidth-bound by construction (one token per "
            "pass over weights+cache): their relevant roofline is the memory "
            "term itself.", ""]
    return "\n".join(out)


def main():
    exp = ROOT / "EXPERIMENTS.md"
    text = exp.read_text()
    table = render()
    if "### §Roofline-table" in text:
        head = text.split("### §Roofline-table")[0].rstrip("\n")
        text = head + "\n" + table
    elif MARK in text:
        text = text.replace(MARK, MARK + "\n" + table)
    else:
        text += "\n" + table
    exp.write_text(text)
    print(table[:1500])


if __name__ == "__main__":
    main()
