"""Render the final EXPERIMENTS.md tables (markdown) from cached artifacts:
the §Roofline table (dry-run JSONs), the §Time-to-accuracy table
(results/repro/fig8.json — the cluster-sim sweep), and the cost-model
step-time table (computed live from repro.sim.StepTimer, same WireFormat
accounting the comm-volume table prints).  Each section is replaced
in-place below its header; EXPERIMENTS.md is created when missing."""
import json
from pathlib import Path

from benchmarks import roofline
from benchmarks._repro_common import results_dir
from benchmarks.comm_volume import N_MODEL, WIRE_TABLE

MARK = "(table inserted by the final sweep — see §Roofline-table below)"
ROOT = Path(__file__).resolve().parents[1]
RESULTS = results_dir()


def render():
    rows = roofline.table()
    out = ["", "### §Roofline-table (single-pod + multi-pod, all cells)", "",
           "| arch | shape | mesh | comp_ms | mem_ms | coll_ms | dominant |"
           " useful | roofl% | peakGB |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"— skipped: {r.get('summary','')[:70]} |||||||")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']*1e3:.1f} | {r['memory_s']*1e3:.1f} "
            f"| {r['collective_s']*1e3:.1f} | {r['dominant']} "
            f"| {max(0, r['useful_ratio']):.2f} "
            f"| {r['roofline_fraction']*100:.1f}% "
            f"| {r['peak_bytes']/2**30:.1f} |")
    ok = [r for r in rows if r.get("status") == "ok"]
    trains = [r for r in ok if r["shape"].startswith("train")]
    out += ["",
            f"{len(ok)} cells compiled ok; "
            f"train-cell roofline fractions: "
            f"min {min(r['roofline_fraction'] for r in trains)*100:.1f}%, "
            f"median {sorted(r['roofline_fraction'] for r in trains)[len(trains)//2]*100:.1f}%, "
            f"max {max(r['roofline_fraction'] for r in trains)*100:.1f}%. "
            "Decode cells are bandwidth-bound by construction (one token per "
            "pass over weights+cache): their relevant roofline is the memory "
            "term itself.", ""]
    return "\n".join(out)


def render_cost_model(n: int = N_MODEL):
    """Simulated step time per wire at n coords/rank under the default link
    profile — the cost-model analogue of the comm-volume table (both read
    the same `WireFormat.wire_bytes`)."""
    import numpy as np

    from repro.sim import DEFAULT_COMPUTE, DEFAULT_LINK, StepTimer

    lk = DEFAULT_LINK
    out = ["", "### §Cost-model step times "
           f"(n={n} coords/rank, default link: {lk.bandwidth_gbps:g} Gbit/s "
           f"up / {lk.down_bandwidth_gbps:g} Gbit/s down, "
           f"{lk.latency_s*1e3:g} ms latency, "
           f"compute {DEFAULT_COMPUTE.grad_s*1e3:g} ms)", "",
           "| wire | bytes up/rank | step ms (no stragglers) |",
           "|---|---|---|"]
    for name, wire in WIRE_TABLE:
        t = StepTimer(wire=wire, n=n)
        out.append(f"| {name} | {t.bytes_up():,} "
                   f"| {t.step_time(np.ones(8)) * 1e3:.2f} |")
    # bucketed aggregation + pipelined-overlap pricing (StepTimer knobs
    # mirroring CocoEFConfig.num_buckets / bucket_schedule)
    sign = WIRE_TABLE[0][1]
    out += ["", "Bucketed aggregation + overlap (`StepTimer(num_buckets, "
            "overlap, pack_s)`, sign g=512 wire; pack_s = the fused "
            "local-step seconds fed into the pipeline as its compute "
            "stage):", "",
            "| pack_s | schedule | B=1 | B=4 | B=8 |",
            "|---|---|---|---|---|"]
    mask = np.ones(8)
    for pack in (0.0, 5e-3):
        for overlap in (False, True):
            cells = [StepTimer(wire=sign, n=n, num_buckets=B,
                               overlap=overlap, pack_s=pack
                               ).step_time(mask) * 1e3
                     for B in (1, 4, 8)]
            sched = "pipelined" if overlap else "serial"
            out.append(f"| {pack*1e3:g} ms | {sched} | "
                       + " | ".join(f"{c:.2f}" for c in cells) + " |")
    out += ["", "Serial bucketing only adds per-message latency "
            "(+2(B-1) ms here); the pipelined schedule pays fill + (B-1) "
            "x bottleneck-stage, so with a real pack stage (5 ms) B=4 "
            "pipelined BEATS the single-shot step — the compression is "
            "hidden behind the wire.  fig8/fig10 expose "
            "`--num-buckets/--overlap`; in fig10 the same flags also "
            "switch the mesh step's `bucket_schedule`, which is "
            "bit-for-bit equal to serial (tests/test_backend_parity.py).",
            ""]
    return "\n".join(out)


def render_kernel_bench():
    """§Kernel microbench from BENCH_kernels*.json artifacts in the repo
    root (benchmarks/kernel_bench.py --json; absent artifacts leave the
    committed section untouched)."""
    arts = []
    for p in sorted(ROOT.glob("BENCH_kernels*.json")):
        arts.append(json.loads(p.read_text()))
    if not arts:
        return None
    arts.sort(key=lambda a: a["n"])
    out = ["", "### §Kernel microbench (benchmarks/kernel_bench.py, "
           "XLA:CPU jnp backend; verified fused==unfused before timing; "
           "backend_ran recorded per row)", "",
           "The PR-6 fusion-barrier fix (`kernels/topk_fast.py`: "
           "`optimization_barrier` per `lax.top_k` output — XLA:CPU "
           "otherwise re-runs the sort once per consumer fusion): "
           "`ef_topk_local_step` went from 1.03x to the numbers below.  "
           "CI (`kernel-bench-smoke`) enforces `--min-speedup "
           "ef_topk_local_step=2.0` at both sizes.", "",
           "| op | n | unfused (us) | fused (us) | speedup |",
           "|---|---|---|---|---|"]
    for a in arts:
        tag = "1M" if a["n"] == 1 << 20 else (
            "4M" if a["n"] == 1 << 22 else f"{a['n']:,}")
        for r in a["rows"]:
            out.append(f"| {r['name']} | {tag} "
                       f"| {r['jnp_unfused_us']:,.0f} "
                       f"| {r['fused_us']:,.0f} | {r['speedup']:.2f}x |")
    out += ["", "(sign_decode_reduce < 1x on CPU is the price of the "
            "rank-order scan accumulation the PR-5 parity gate demands; "
            "Pallas numbers need a TPU.)", ""]
    return "\n".join(out)


def render_sim():
    """§Time-to-accuracy table from the cached fig8 sweep (plus fig3
    straggler-process variants when present)."""
    fig8 = RESULTS / "fig8.json"
    if not fig8.exists():
        return None
    res = json.loads(fig8.read_text())
    out = ["", "### §Time-to-accuracy (fig8: wire x straggler process, "
           f"simulated at n={res['meta']['n_wire']} coords/rank)", "",
           "| straggler | method | final loss | time-to-target (s) "
           "| GB up (total) |",
           "|---|---|---|---|---|"]
    for pname, curves in res["curves"].items():
        t2t = res["summary"][pname]["time_to_target_s"]
        for mname, c in curves.items():
            t = t2t.get(mname)
            t_cell = f"{t:.2f}" if t is not None else "never"
            out.append(f"| {pname} | {mname} | {c['loss'][-1]:.1f} "
                       f"| {t_cell} | {c['bytes_up_cum'][-1]/2**30:.2f} |")
    out.append("")
    for pname, s in res["summary"].items():
        speed = s.get("sign_vs_dense_speedup")
        if speed:
            out.append(f"- {pname}: COCO-EF(sign) reaches the target loss "
                       f"{speed:.2f}x sooner than dense SGC.")
    for variant in ("markov", "hetero"):
        f3 = RESULTS / f"fig3_{variant}.json"
        if f3.exists():
            r = json.loads(f3.read_text())
            finals = "; ".join(f"{k}={v['loss'][-1]:.1f}"
                               for k, v in r.items() if k != "meta")
            out += ["", f"fig3[{variant}] final losses: {finals}"]
    out.append("")
    return "\n".join(out)


def render_fig9():
    """§Rate-aware coding table from the cached fig9 sweep: rate-aware vs
    mean-rate encode weights (+ greedy allocation) under non-iid
    stragglers, with the closed-form weight bias per variant."""
    fig9 = RESULTS / "fig9.json"
    if not fig9.exists():
        return None
    res = json.loads(fig9.read_text())
    m = res["meta"]
    out = ["", "### §Rate-aware coding (fig9: encode weights from per-rank "
           f"rates q_i; N={m['N']}, dim={m['dim']}, d={m['d']}, "
           f"two-class p_slow={m['two_class']['p_slow']})", "",
           "| straggler | variant | final loss | time-to-target (s) "
           "| max weight bias |",
           "|---|---|---|---|---|"]
    for pname, curves in res["curves"].items():
        s = res["summary"][pname]
        for mname, c in curves.items():
            t = s["time_to_target_s"].get(mname)
            t_cell = f"{t:.2f}" if t is not None else "never"
            b = s["weight_bias_max"].get(mname)
            b_cell = f"{b:.3f}" if b is not None else "—"
            out.append(f"| {pname} | {mname} | {c['loss'][-1]:.1f} "
                       f"| {t_cell} | {b_cell} |")
    out.append("")
    for pname, s in res["summary"].items():
        speed = s.get("rate_aware_vs_mean_rate_speedup")
        if speed:
            out.append(f"- {pname}: rate-aware weights reach the target "
                       f"loss {speed:.2f}x sooner than mean-rate eq. 3.")
    demo = m.get("budget_demo")
    if demo:
        ks = demo["k_budgets"]
        out += ["", f"Per-rank wire budgets (solve_k_budgets, equal-time): "
                f"slow-uplink ranks at {min(demo['rank_bandwidth_gbps'])} "
                f"Gbit/s send k={min(ks)}/block vs k={max(ks)}/block at "
                f"{max(demo['rank_bandwidth_gbps'])} Gbit/s."]
    out.append("")
    return "\n".join(out)


def render_fig10():
    """§Model zoo table from the cached fig10 sweep: the REAL mesh train
    step per (arch x wire x straggler), per-model compute from the
    compiled step's HLO flops (ComputeProfile.from_compiled_hlo), and the
    relative-drop time-to-target."""
    fig10 = RESULTS / "fig10.json"
    if not fig10.exists():
        return None
    res = json.loads(fig10.read_text())
    m = res["meta"]
    out = ["", "### §Model zoo (fig10: production mesh train step, "
           f"T={m['T']}, mesh={m['mesh']}, p={m['p_straggler']}, "
           f"device {m['device_flops']:.0e} FLOP/s @ mfu {m['mfu']})", "",
           "| arch | straggler | wire | compute ms/step | final loss "
           "| t2t (ms) | KiB up/step/rank |",
           "|---|---|---|---|---|---|---|"]
    for arch, by_strag in res["curves"].items():
        for strag, curves in by_strag.items():
            t2t = res["summary"][arch][strag]["time_to_target_s"]
            for wname, c in curves.items():
                comp = res["compute"][arch][strag][wname]
                t = t2t.get(wname)
                t_cell = f"{t*1e3:.1f}" if t is not None else "never"
                out.append(
                    f"| {arch} | {strag} | {wname} "
                    f"| {comp['grad_s']*1e3:.3f} | {c['loss'][-1]:.3f} "
                    f"| {t_cell} | {comp['bytes_up_per_rank']/1024:.1f} |")
    out.append("")
    from benchmarks._repro_common import compute_range_ms, fmt_ms_range
    comps = {arch: compute_range_ms(by)
             for arch, by in res["compute"].items()}
    out.append("Per-model phase-1 compute (from `launch.hlo_cost` flops of "
               "each cell's compiled step, NOT the cost model's 5 ms "
               "default; min-max over that arch's wire x straggler cells): "
               + ", ".join(f"{a}={fmt_ms_range(lo, hi)}"
                           for a, (lo, hi) in comps.items())
               + ".  The reference-vs-mesh Algorithm-1 parity gate "
               "(`fig10_model_zoo.py --parity`, "
               "tests/test_algorithm_parity.py) holds bit-for-bit for "
               "sign, block_topk and dense wires.")
    out.append("")
    return "\n".join(out)


def _replace_section(text: str, header: str, table: str) -> str:
    """Replace everything from `header` to the next '### §' (or EOF)."""
    if header in text:
        head, rest = text.split(header, 1)
        nxt = rest.find("\n### §")
        tail = rest[nxt + 1:] if nxt >= 0 else ""
        return head.rstrip("\n") + "\n" + table.strip("\n") + "\n" + tail
    return text.rstrip("\n") + "\n" + table.strip("\n") + "\n"


def main():
    exp = ROOT / "EXPERIMENTS.md"
    text = exp.read_text() if exp.exists() else "# EXPERIMENTS\n"
    if MARK in text:
        text = text.replace(MARK, "")
    try:
        text = _replace_section(text, "### §Roofline-table", render())
    except Exception as e:  # noqa: BLE001 — roofline cache may be absent
        print(f"roofline table unavailable: {e}")
    kb = render_kernel_bench()
    if kb is not None:
        text = _replace_section(text, "### §Kernel microbench", kb)
    text = _replace_section(text, "### §Cost-model step times",
                            render_cost_model())
    sim = render_sim()
    if sim is not None:
        text = _replace_section(text, "### §Time-to-accuracy", sim)
    f9 = render_fig9()
    if f9 is not None:
        text = _replace_section(text, "### §Rate-aware coding", f9)
    f10 = render_fig10()
    if f10 is not None:
        text = _replace_section(text, "### §Model zoo", f10)
    exp.write_text(text)
    print(text[-2500:])


if __name__ == "__main__":
    main()
