"""Benchmark harness — one entry per paper table/figure + system tables.
Prints ``name,us_per_call,derived`` CSV (derived = headline metric).

The artifact directory is configurable: ``--results-dir DIR`` or
``$REPRO_RESULTS_DIR`` (default: the gitignored <repo>/results/repro)."""
import argparse
import json
import os
import time
from pathlib import Path


def _repro_dir() -> Path:
    # single source of truth for the artifact root (lazy: keeps --help fast)
    from benchmarks._repro_common import results_dir
    return results_dir()


def _timed(fn, *a, **k):
    t0 = time.perf_counter()
    out = fn(*a, **k)
    return (time.perf_counter() - t0) * 1e6, out


def _fig(name, runner, headline, trials, T):
    """Use the cached <results>/<name>.json when present (the full runs are
    produced by the repro sweep); else run reduced."""
    cached = _repro_dir() / f"{name}.json"
    if cached.exists():
        res = json.loads(cached.read_text())
        return 0.0, headline(res)
    us, res = _timed(runner, trials=trials, T=T)
    return us, headline(res)


def main() -> None:
    rows = []

    from benchmarks import (comm_volume, fig2_linreg_baselines as f2,
                            fig3_straggler_sweep as f3,
                            fig4_redundancy_sweep as f4,
                            fig5_ef_ablation as f5, fig6_lr_schedule as f6,
                            fig7_classification as f7,
                            fig8_time_to_accuracy as f8,
                            fig9_hetero_sweep as f9, kernel_bench)

    us, d = _fig("fig2", f2.run,
                 lambda r: (f"cocoef_sign={r['cocoef_sign']['loss'][-1]:.1f}"
                            f"|unbiased_sign={r['unbiased_sign']['loss'][-1]:.1f}"),
                 trials=2, T=200)
    rows.append(("fig2_equal_bits", us, d))
    us, d = _fig("fig3", f3.run,
                 lambda r: "|".join(f"{k}={v['loss'][-1]:.1f}"
                                    for k, v in r.items()
                                    if k != "meta"), 2, 200)
    rows.append(("fig3_straggler_p", us, d))
    # fig3 straggler-process variants (cached only — produced by
    # `fig3_straggler_sweep.py --straggler markov|hetero`)
    for variant in ("markov", "hetero"):
        cached = _repro_dir() / f"fig3_{variant}.json"
        if cached.exists():
            r = json.loads(cached.read_text())
            rows.append((f"fig3_straggler_p[{variant}]", 0.0,
                         "|".join(f"{k}={v['loss'][-1]:.1f}"
                                  for k, v in r.items() if k != "meta")))
    us, d = _fig("fig4", f4.run,
                 lambda r: "|".join(f"{k}={v['loss'][-1]:.1f}"
                                    for k, v in r.items()
                                    if k != "meta"), 2, 200)
    rows.append(("fig4_redundancy", us, d))
    us, d = _fig("fig5", f5.run,
                 lambda r: (f"cocoef_topk={r['cocoef_topk']['loss'][-1]:.1f}"
                            f"|coco_topk={r['coco_topk']['loss'][-1]:.1f}"),
                 2, 200)
    rows.append(("fig5_ef_ablation", us, d))
    us, d = _fig("fig6", f6.run,
                 lambda r: (f"const={r['constant']['loss'][-1]:.1f}"
                            f"|decay={r['decaying']['loss'][-1]:.1f}"), 2, 200)
    rows.append(("fig6_lr_schedule", us, d))
    us, d = _fig("fig7", f7.run,
                 lambda r: "|".join(f"{k}={v['test_acc'][-1]:.3f}"
                                    for k, v in r.items()
                                    if k != "meta"
                                    and not k.endswith("_std")), 1, 100)
    rows.append(("fig7_heterogeneous_cls", us, d))

    def _fig8_headline(r):
        parts = []
        for pname, s in r["summary"].items():
            t = s["time_to_target_s"]
            sign, dense = t.get("cocoef_sign"), t.get("sgc_dense")
            parts.append(f"{pname}:sign={sign:.2f}s" if sign is not None
                         else f"{pname}:sign=never")
            if sign is not None and dense is not None:
                parts[-1] += f"|dense={dense:.2f}s|x{dense / sign:.2f}"
        return "|".join(parts)

    us, d = _fig("fig8", f8.run, _fig8_headline, trials=1, T=120)
    rows.append(("fig8_time_to_accuracy", us, d))

    def _fig9_headline(r):
        parts = []
        for pname, s in r["summary"].items():
            t = s["time_to_target_s"]
            ra, mr = t.get("rate_aware"), t.get("mean_rate")
            parts.append(f"{pname}:ra={ra:.2f}s" if ra is not None
                         else f"{pname}:ra=never")
            if ra is not None and mr is not None:
                parts[-1] += f"|mean={mr:.2f}s|x{mr / ra:.2f}"
        return "|".join(parts)

    us, d = _fig("fig9", f9.run, _fig9_headline, trials=1, T=120)
    rows.append(("fig9_hetero_sweep", us, d))

    # fig10 model-zoo sweep (cached only — the real mesh train step needs
    # a forced multi-device XLA before jax initializes, so the sweep runs
    # as its own process: benchmarks/fig10_model_zoo.py [--smoke])
    cached = _repro_dir() / "fig10.json"
    if cached.exists():
        from benchmarks._repro_common import compute_range_ms, fmt_ms_range
        r = json.loads(cached.read_text())
        for arch, by_strag in r["summary"].items():
            parts = []
            comp = "comp=" + fmt_ms_range(
                *compute_range_ms(r["compute"][arch]))
            for pname, s in by_strag.items():
                t = s["time_to_target_s"]
                cell = "|".join(
                    f"{w}={v*1e3:.1f}ms" if v is not None else f"{w}=never"
                    for w, v in t.items())
                parts.append(f"{pname}:{cell}")
            rows.append((f"fig10_model_zoo[{arch}]", 0.0,
                         comp + "|" + "|".join(parts)))
    else:
        rows.append(("fig10_model_zoo", 0.0,
                     "uncached:run benchmarks/fig10_model_zoo.py --smoke"))

    for name, bits, ratio in comm_volume.run():
        rows.append((f"comm_volume[{name}]", 0.0,
                     f"bits={bits}|x{ratio:.1f}"))

    for r in kernel_bench.run():
        rows.append((f"kernel[{r['name']}]", r["fused_us"],
                     f"unfused={r['jnp_unfused_us']}us|x{r['speedup']}"))

    # roofline summary (from cached dry-run artifacts)
    try:
        from benchmarks import roofline
        cells = [r for r in roofline.table() if r.get("status") == "ok"]
        if cells:
            worst = min(cells, key=lambda r: r["roofline_fraction"])
            best = max(cells, key=lambda r: r["roofline_fraction"])
            rows.append(("roofline_cells_ok", 0.0, str(len(cells))))
            rows.append(("roofline_worst", 0.0,
                         f"{worst['arch']}/{worst['shape']}/{worst['mesh']}"
                         f"={worst['roofline_fraction']*100:.1f}%"))
            rows.append(("roofline_best", 0.0,
                         f"{best['arch']}/{best['shape']}/{best['mesh']}"
                         f"={best['roofline_fraction']*100:.1f}%"))
    except Exception as e:  # noqa: BLE001
        rows.append(("roofline", 0.0, f"unavailable:{e}"))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--results-dir", default=None,
                    help="benchmark artifact directory (default: "
                         "$REPRO_RESULTS_DIR or <repo>/results/repro)")
    args = ap.parse_args()
    if args.results_dir:
        # exported so every lazily-imported benchmark module (fig8/fig9
        # writers, emit_tables readers) resolves the same directory
        os.environ["REPRO_RESULTS_DIR"] = args.results_dir
    main()
