"""Hillclimb helper: re-lower a cell and print the top collective ops by
(trip-scaled) wire bytes, with their HLO metadata op_name — tells you
exactly which model op generates the traffic.

  PYTHONPATH=src python -m benchmarks.inspect_collectives \
      --arch qwen1.5-110b --shape train_4k --mesh multi [--top 15]
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import re

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--mode", default="cocoef")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--run-json", default=None)
    ap.add_argument("--bytes", action="store_true", help="top ops by HBM bytes")
    args = ap.parse_args()

    import json

    import jax

    from repro.configs import get_arch
    from repro.launch import hlo_cost
    from repro.launch.hlo_analysis import _WIRE_FACTOR, _group_size
    from repro.launch.mesh import make_production_mesh
    from repro.launch.serve import build_serve_setup
    from repro.launch.train import TrainRun, build_train_setup

    spec = get_arch(args.arch)
    shape = spec.shapes[args.shape]
    mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    ndev = int(np.prod(mesh.devices.shape))

    if shape.is_train:
        extra = json.loads(args.run_json) if args.run_json else {}
        setup = build_train_setup(spec, mesh, shape,
                                  TrainRun(mode=args.mode, **extra))
        sp = setup.input_specs()
        compiled = jax.jit(setup.train_step).lower(
            sp["params"], sp["e"], sp["opt"], sp["batch"], sp["step"],
            sp["key"]).compile()
    else:
        setup = build_serve_setup(spec, mesh, shape)
        kind = "decode" if shape.kind == "decode" else "prefill"
        sp = setup.input_specs(kind)
        if kind == "decode":
            compiled = jax.jit(setup.decode_step,
                               out_shardings=setup.decode_out_shardings
                               ).lower(sp["params"], sp["caches"],
                                       sp["inputs"], sp["pos"]).compile()
        else:
            compiled = jax.jit(setup.prefill_step,
                               out_shardings=setup.prefill_out_shardings
                               ).lower(sp["params"], sp["inputs"]).compile()

    txt = compiled.as_text()
    comps = hlo_cost.parse_computations(txt)

    # build while multipliers per computation by walking from entry
    mult = {}

    def walk(name, m):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0) + m
        for op in comps[name].ops:
            if op.kind == "while":
                tm = hlo_cost._TRIP.search(op.line)
                trip = int(tm.group(1)) if tm else 1
                bm = hlo_cost._CALLS.search(op.line)
                if bm:
                    walk(bm.group(1), m * trip)
                cm = hlo_cost._COND.search(op.line)
                if cm:
                    walk(cm.group(1), m * trip)
            elif op.kind in ("call", "conditional"):
                bm = hlo_cost._CALLS.search(op.line)
                if bm:
                    walk(bm.group(1), m)

    entry = None
    for raw in txt.splitlines():
        if raw.startswith("ENTRY"):
            entry = hlo_cost._COMP_HDR.match(raw.strip()).group(1)
            break
    walk(entry, 1)

    rows = []
    meta_re = re.compile(r'op_name="([^"]*)"')
    if args.bytes:
        brows = []
        for cname, m in mult.items():
            comp = comps[cname]
            for op in comp.ops:
                if op.kind in hlo_cost._SKIP_KINDS or op.kind in (
                        "while", "call", "conditional"):
                    continue
                b = hlo_cost._nbytes(op.rtype)
                for o in op.operands:
                    t = comp.symbols.get(o)
                    if t:
                        b += hlo_cost._nbytes(t)
                mm = meta_re.search(op.line)
                brows.append((b * m, op.kind, op.rtype[:44], m,
                              mm.group(1)[:86] if mm else ""))
        brows.sort(reverse=True)
        print(f"total bytes {sum(r[0] for r in brows)/2**30:.1f} GiB/device")
        for b, kind, rt, m, name in brows[:args.top]:
            print(f"{b/2**30:9.2f} GiB x{m:5d} {kind:22s} {rt:44s} {name}")
        return
    for cname, m in mult.items():
        for op in comps[cname].ops:
            base = op.kind.split("-start")[0]
            if base not in ("all-gather", "all-reduce", "reduce-scatter",
                            "all-to-all", "collective-permute"):
                continue
            nb = hlo_cost._nbytes(op.rtype)
            g = _group_size(op.line, ndev)
            wire = nb * _WIRE_FACTOR[base](max(g, 1)) * m
            mm = meta_re.search(op.line)
            rows.append((wire, base, op.rtype[:48], g, m,
                         mm.group(1)[:90] if mm else ""))
    rows.sort(reverse=True)
    total = sum(r[0] for r in rows)
    print(f"total wire {total/2**30:.2f} GiB/device over {len(rows)} "
          f"collective sites")
    for wire, base, rt, g, m, name in rows[:args.top]:
        print(f"{wire/2**30:8.2f} GiB x{m:3d} g={g:3d} {base:18s} {rt:48s} "
              f"{name}")


if __name__ == "__main__":
    main()
