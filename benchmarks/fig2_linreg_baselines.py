"""Fig. 2: COCO-EF vs unbiased baselines at equal communication overhead.

Protocol (Sec. V.A): N=M=100, d_k=5, p=0.2, K=2, T=400.
Learning rates as fine-tuned in the paper: COCO-EF 1e-5; Unbiased(Sign)
2e-6, Unbiased(Rand-K) 1e-5, Unbiased-diff(Sign) 2e-6 (alpha tuned),
Unbiased-diff(Rand-K) 6e-6.

Claim validated: at identical per-iteration bits, COCO-EF(Sign) <
Unbiased(-diff)(Sign) and COCO-EF(TopK) < Unbiased(-diff)(RandK).
"""
import json
from pathlib import Path

from repro.core import compression as C

from . import _repro_common as R

OUT = Path(__file__).resolve().parents[1] / "results" / "repro"

CASES = {
    # name: (method, compressor, lr, diana_alpha)
    "cocoef_sign": ("cocoef", C.GroupedSign(), 1e-5, None),
    "cocoef_topk": ("cocoef", C.TopK(k=2), 1e-5, None),
    "unbiased_sign": ("unbiased", C.StochasticSign(), 2e-6, None),
    "unbiased_randk": ("unbiased", C.RandK(k=2), 1e-5, None),
    "unbiased_diff_sign": ("unbiased_diff", C.StochasticSign(), 6e-6, 0.2),
    # DIANA step size alpha ~ 1/(omega+1): rand-2 of D=100 has omega ~ 50
    "unbiased_diff_randk": ("unbiased_diff", C.RandK(k=2), 6e-6, 0.01),
    "uncompressed": ("uncompressed", None, 1e-5, None),
}


def run(trials=5, T=400):
    res = {}
    for name, (method, comp, lr, alpha) in CASES.items():
        kw = dict(diff_alpha=alpha) if alpha is not None else {}
        res[name] = R.run_trials(method, comp, trials=trials,
                                 d=5, p=0.2, gamma=lr, T=T, **kw)
    res["meta"] = R.run_metadata(trials=trials, T=T)
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "fig2.json").write_text(json.dumps(res, indent=1))
    return res


if __name__ == "__main__":
    r = run()
    for k, v in r.items():
        if k == "meta":
            continue
        print(f"{k:22s} final_loss={v['loss'][-1]:.1f}")
