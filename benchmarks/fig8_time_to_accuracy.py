"""Fig. 8 (beyond-paper): time-to-accuracy under simulated cluster dynamics.

The paper reports loss vs ITERATION; what actually motivates biased
compression is loss vs WALL-CLOCK on a cluster where stragglers and
communication both cost time.  This sweep joins the two halves of
`repro.sim`:

  dynamics — the paper's linreg protocol (Sec. V.A) trained per method
    with a pluggable `StragglerProcess` driving the participation masks;
  timing   — a `StepTimer` replaying the SAME mask trace through the
    wire-aware cost model, with each method's phase-1 bytes taken from the
    production `WireFormat` it would ship at model scale
    (`n_wire` = 4M coords/rank, the ROADMAP comm-volume table scale).

Methods: COCO-EF on the sign and sparse wires vs dense SGC [31] (coded,
uncompressed) vs an uncoded dense baseline (d=1).  Each runs under every
straggler process (iid Bernoulli, bursty Markov, heterogeneous rates).

Emits results/repro/fig8.json: per-(process, method) (time, loss) curves,
a bytes-on-wire ledger, and time-to-target-loss summaries.

  PYTHONPATH=src python benchmarks/fig8_time_to_accuracy.py [--smoke]
"""
import argparse
import dataclasses
import json
from pathlib import Path

import jax
import numpy as np

from repro.core import compression as C
from repro.core.plan import PlanSpec
from repro.sim import (DEFAULT_COMPUTE, DEFAULT_LINK, TraceReplay,
                       attach_times, get_straggler_process, simulate_run)

try:
    from . import _repro_common as R
except ImportError:                      # run as a script
    import _repro_common as R

OUT = None                # optional override; default R.results_dir()

N_WIRE = 1 << 22        # 4M coords/rank: the production wire scale the
                        # step times are projected at (ROADMAP comm table)

# method -> (EF step, trial compressor, PlanSpec).  The plan is the single
# source for redundancy d AND the production wire the step times are priced
# at — timer, bytes ledger, and metadata all derive from plan.wire(n_wire).
METHODS = {
    "cocoef_sign": ("cocoef", C.GroupedSign(),
                    PlanSpec(d=2, compressor="sign", group_size=512)),
    "cocoef_topk": ("cocoef", C.TopK(k=2),
                    PlanSpec(d=2, compressor="block_topk", k_per_block=8,
                             block_size=512)),
    "sgc_dense": ("uncompressed", None, PlanSpec(d=2, compressor="identity")),
    "uncoded_dense": ("uncompressed", None,
                      PlanSpec(d=1, compressor="identity")),
}


def _processes(N, p, smoke=False):
    procs = {
        "iid": get_straggler_process("iid", N, p),
        "markov": get_straggler_process("markov", N, p,
                                        mean_burst=4.0 if smoke else 8.0),
        "hetero": get_straggler_process("hetero", N, p,
                                        spread=R.hetero_spread(p, 0.8)),
    }
    # recorded-incident replay with one total-outage row: the all-straggler
    # step semantics (ghat = 0, error vectors untouched, timeout-cost step,
    # zero uplink bytes) ride the full pipeline end to end
    rows = np.array(procs["hetero"].sample_trace(
        jax.random.PRNGKey(7), 24 if smoke else 48))
    rows[3, :] = 0.0
    procs["trace"] = TraceReplay.from_array(rows)
    return procs


def run(trials=3, T=400, N=100, p=0.2, gamma=1e-5, record_every=20,
        n_wire=N_WIRE, link=DEFAULT_LINK, compute=DEFAULT_COMPUTE,
        num_buckets=1, overlap=False, smoke=False, out_dir=None):
    if smoke:
        trials, T, N, record_every = 1, 60, 20, 5
    # fold the shared bucket knobs into each method's plan ONCE; everything
    # downstream (d, timer wire, bytes ledger, metadata) reads the plan
    plans = {name: R.plan_from_args(
                 base=mplan, num_buckets=num_buckets,
                 bucket_schedule=("pipelined" if overlap else "serial"))
             for name, (_, _, mplan) in METHODS.items()}
    res = {"meta": {**R.run_metadata(), "n_wire": n_wire, "p": p,
                    "trials": trials, "T": T, "N": N, "gamma": gamma,
                    "num_buckets": num_buckets, "overlap": overlap,
                    "link": dataclasses.asdict(link),
                    "compute": dataclasses.asdict(compute),
                    "plans": {name: pl.to_dict()
                              for name, pl in plans.items()},
                    "wire_bytes_up_per_rank": {
                        name: int(pl.wire(n_wire).wire_bytes(n_wire))
                        for name, pl in plans.items()}},
           "curves": {}, "summary": {}}

    for pname, proc in _processes(N, p, smoke=smoke).items():
        curves = {}
        for mname, (method, comp, _) in METHODS.items():
            plan = plans[mname]
            d = plan.d
            timer = R.plan_timer(plan, n_wire, link, compute)
            per_trial = []
            for s in range(trials):
                grad_fn, loss_fn, theta0, _ = R.tasks.linreg_task(
                    seed=s, num_subsets=N)
                hist = R.run_trial(method, comp, grad_fn, loss_fn, theta0,
                                   N=N, M=N, d=d, p=p, gamma=gamma, T=T,
                                   seed=s, record_every=record_every,
                                   straggler=proc)
                sim = simulate_run(proc, timer, T,
                                   jax.random.PRNGKey(1000 + s))
                per_trial.append(attach_times(hist, sim))
            curves[mname] = R.summarize_trials(per_trial)

        target, t2t = R.target_and_t2t(curves)
        summary = {"target_loss": target, "time_to_target_s": t2t}
        if t2t["cocoef_sign"] and t2t["sgc_dense"]:
            summary["sign_vs_dense_speedup"] = \
                t2t["sgc_dense"] / t2t["cocoef_sign"]
        res["curves"][pname] = curves
        res["summary"][pname] = summary

    out = Path(out_dir) if out_dir else (OUT or R.results_dir())
    out.mkdir(parents=True, exist_ok=True)
    (out / "fig8.json").write_text(json.dumps(res, indent=1))
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny configuration for CI (1 trial, 60 steps, "
                         "20 ranks)")
    ap.add_argument("--trials", type=int, default=3)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--num-buckets", type=int, default=1,
                    help="flat-vector buckets the cost model splits the "
                         "aggregation into (matches CocoEFConfig)")
    ap.add_argument("--overlap", action="store_true",
                    help="time the PIPELINED bucket schedule: per-bucket "
                         "pack/uplink/downlink stages overlap, so the "
                         "aggregation costs max-stage instead of "
                         "sum-of-stages per extra bucket")
    ap.add_argument("--out", default=None,
                    help="output directory (default: $REPRO_RESULTS_DIR "
                         "or results/repro)")
    args = ap.parse_args()
    res = run(trials=args.trials, T=args.steps, smoke=args.smoke,
              num_buckets=args.num_buckets, overlap=args.overlap,
              out_dir=args.out)
    for pname, s in res["summary"].items():
        t2t = ", ".join(
            f"{m}={v:.2f}s" if v is not None else f"{m}=never"
            for m, v in s["time_to_target_s"].items())
        speed = s.get("sign_vs_dense_speedup")
        print(f"{pname:8s} target={s['target_loss']:.1f}  {t2t}"
              + (f"  sign-vs-dense x{speed:.2f}" if speed else ""))


if __name__ == "__main__":
    main()
