"""Fig. 4: COCO-EF (Sign) under varying redundancy d_k at p=0.9.
Claim: d_k 1 -> 10 improves strongly, then saturates."""
import json
from pathlib import Path

from repro.core import compression as C

from . import _repro_common as R

OUT = Path(__file__).resolve().parents[1] / "results" / "repro"
DS = [1, 2, 5, 10, 20]


def run(trials=5, T=400):
    res = {}
    for d in DS:
        res[f"d={d}"] = R.run_trials("cocoef", C.GroupedSign(), trials=trials,
                                     d=d, p=0.9, gamma=1e-5, T=T)
    res["meta"] = R.run_metadata(trials=trials, T=T, p=0.9, ds=DS)
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "fig4.json").write_text(json.dumps(res, indent=1))
    return res


if __name__ == "__main__":
    for k, v in run().items():
        if k == "meta":
            continue
        print(f"{k:8s} final_loss={v['loss'][-1]:.1f}")
