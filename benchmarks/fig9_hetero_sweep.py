"""Fig. 9 (beyond-paper): rate-aware vs mean-rate gradient coding under
non-iid stragglers.

Eq. 3's encode weights 1/(d_k (1-p)) divide by the expected number of
participating holders ONLY when every rank participates with the same
marginal rate 1-p.  Under heterogeneous participation (per-rank rates q_i)
the mean-rate aggregate is a *biased* estimate of the global gradient —
E[ghat] = sum_k c_k grad_k with c_k = mean_{i in S_k} q_i / (1-p) != 1 —
so COCO-EF converges to the wrong point (the failure mode approximate
gradient coding in heterogeneous systems is structured to avoid, Song &
Choi; biased-compressor error compounds per Beznosikov et al.).

This sweep drives the paper's linreg protocol (overdetermined so the bias
shows up as a loss plateau, not just a different interpolant) with three
coding variants under every non-iid straggler process:

  mean_rate         eq. 3 weights from the scalar mean rate p (the bug)
  rate_aware        W[i,k] = S[i,k] / sum_j S[j,k] q_j  (unbiased for any
                    per-rank rates; bit-for-bit eq. 3 when rates are
                    uniform — see markov, where the two curves coincide)
  rate_aware_alloc  rate-aware weights on the greedy expected-coverage
                    allocation (coding.rate_aware_allocation): same replica
                    budget, extra redundancy where the fleet is unreliable

All three ship the identical SignWire payload, so simulated step times are
identical and any time-to-target gap is purely the coding.  Emits
results/repro/fig9.json with per-(process, method) (time, loss) curves,
closed-form weight-bias diagnostics, a per-rank wire-budget demo
(sim.solve_k_budgets under a heterogeneous uplink), and time-to-target
summaries.

  PYTHONPATH=src python benchmarks/fig9_hetero_sweep.py [--smoke]
"""
import argparse
import dataclasses
import json
from pathlib import Path

import jax
import numpy as np

from repro.core import coding, compression as C
from repro.core.plan import PlanSpec
from repro.sim import (DEFAULT_COMPUTE, DEFAULT_LINK, HeterogeneousRates,
                       LinkProfile, MarkovBursty, TraceReplay, attach_times,
                       simulate_run, solve_k_budgets)

try:
    from . import _repro_common as R
except ImportError:                      # run as a script
    import _repro_common as R

OUT = None                # optional override; default R.results_dir()

N_WIRE = 1 << 22          # production wire scale (ROADMAP comm table)

METHODS = ("mean_rate", "rate_aware", "rate_aware_alloc")

P_SLOW, P_FAST, SLOW_FRACTION = 0.8, 0.02, 0.3


def _processes(N, smoke=False):
    """The non-iid processes of the sweep.  `trace` replays a recorded
    sample of the two-class fleet INCLUDING one total-outage row, so the
    all-straggler step semantics (ghat = 0, error untouched, timeout-cost
    step) ride through the whole pipeline."""
    two = HeterogeneousRates.two_class(N, p_slow=P_SLOW, p_fast=P_FAST,
                                       slow_fraction=SLOW_FRACTION)
    rows = np.array(two.sample_trace(jax.random.PRNGKey(99),
                                     24 if smoke else 64))
    rows[3, :] = 0.0                     # recorded total outage
    return {
        "hetero": two,
        "markov": MarkovBursty(num_devices=N, p=0.2,
                               mean_burst=4.0 if smoke else 8.0),
        "trace": TraceReplay.from_array(rows),
    }


def _mean_p(proc) -> float:
    return float(1.0 - np.asarray(proc.rates()).mean())


def _weight_bias(alloc, W, rates) -> float:
    """max_k |sum_i q_i W[i,k] - 1|: the closed-form per-subset bias of the
    masked aggregate's expectation (0 = unbiased)."""
    q = np.asarray(rates, np.float64)
    coeff = q @ np.asarray(W, np.float64)
    return float(np.max(np.abs(coeff - 1.0)))


def _budget_demo(N: int):
    """Per-rank wire budgets under a heterogeneous uplink: the slow-uplink
    third of the fleet gets smaller top-K budgets (equal-time solver),
    carried as a per-rank-budget PlanSpec so the bytes ledger comes from
    the same object a run would execute."""
    slow = max(1, N // 3)
    link = LinkProfile(rank_bandwidth_gbps=(2.5,) * slow
                       + (10.0,) * (N - slow))
    ks = solve_k_budgets(N_WIRE, N, link, block_size=512, k_ref=8)
    plan = PlanSpec(compressor="block_topk", k_per_block=ks, block_size=512,
                    num_ranks=N)
    per_rank = plan.rank_wire_bytes(N_WIRE)
    return {"rank_bandwidth_gbps": list(link.up_bandwidths(N)),
            "k_budgets": list(ks),
            "plan": plan.to_dict(),
            "bytes_up_per_rank": [int(b) for b in per_rank],
            "uplink_s_per_rank": list(link.up_s_ranks(per_rank))}


def run(trials=3, T=400, N=60, gamma=2e-5, record_every=20, d=3,
        n_wire=N_WIRE, link=DEFAULT_LINK, compute=DEFAULT_COMPUTE,
        smoke=False, out_dir=None):
    # gamma is sized so the run REACHES its plateau within T: the mean-rate
    # bias is a plateau-level effect (deep in the transient the biased
    # weights act like a slightly larger step and can even look faster)
    if smoke:
        trials, T, N, record_every, gamma = 1, 120, 16, 5, 1e-4
    dim = N // 2                        # overdetermined: bias => plateau
    # all three variants ship the identical sign wire; the shared PlanSpec
    # (d + wire knobs) prices the one StepTimer every curve reuses
    plan = R.plan_from_args(base=PlanSpec(d=d, compressor="sign",
                                          group_size=512))
    timer = R.plan_timer(plan, n_wire, link, compute)
    res = {"meta": {**R.run_metadata(), "n_wire": n_wire,
                    "trials": trials, "T": T, "N": N,
                    "dim": dim, "d": d, "gamma": gamma,
                    "plan": plan.to_dict(),
                    "two_class": {"p_slow": P_SLOW, "p_fast": P_FAST,
                                  "slow_fraction": SLOW_FRACTION},
                    "link": dataclasses.asdict(link),
                    "compute": dataclasses.asdict(compute),
                    "budget_demo": _budget_demo(N)},
           "curves": {}, "summary": {}}

    for pname, proc in _processes(N, smoke=smoke).items():
        rates = np.asarray(proc.rates())
        p_bar = _mean_p(proc)
        # every variant ships the identical wire, so one simulated timeline
        # per trial serves all three method curves
        sims = [simulate_run(proc, timer, T, jax.random.PRNGKey(1000 + s))
                for s in range(trials)]
        curves, bias = {}, {}
        for mname in METHODS:
            per_trial = []
            for s in range(trials):
                grad_fn, loss_fn, theta0, _ = R.tasks.linreg_task(
                    seed=s, num_subsets=N, dim=dim)
                alloc = (coding.rate_aware_allocation(rates, N, d)
                         if mname == "rate_aware_alloc" else
                         coding.random_allocation(s, N, N, d))
                hist = R.run_trial(
                    "cocoef", C.GroupedSign(), grad_fn, loss_fn, theta0,
                    N=N, M=N, d=d, p=p_bar, gamma=gamma, T=T, seed=s,
                    record_every=record_every, straggler=proc,
                    rate_aware=mname != "mean_rate", allocation=alloc)
                per_trial.append(attach_times(hist, sims[s]))
                if s == 0:
                    W = (coding.encode_weights(alloc, rates=rates)
                         if mname != "mean_rate" else
                         coding.encode_weights(alloc, p_bar))
                    bias[mname] = _weight_bias(alloc, W, rates)
            curves[mname] = R.summarize_trials(per_trial)

        target, t2t = R.target_and_t2t(curves)
        summary = {"target_loss": target, "time_to_target_s": t2t,
                   "weight_bias_max": bias,
                   "final_loss": {m: c["loss"][-1]
                                  for m, c in curves.items()}}
        if t2t["rate_aware"] and t2t["mean_rate"]:
            summary["rate_aware_vs_mean_rate_speedup"] = \
                t2t["mean_rate"] / t2t["rate_aware"]
        res["curves"][pname] = curves
        res["summary"][pname] = summary

    out = Path(out_dir) if out_dir else (OUT or R.results_dir())
    out.mkdir(parents=True, exist_ok=True)
    (out / "fig9.json").write_text(json.dumps(res, indent=1))
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny configuration for CI (1 trial, 120 steps, "
                         "16 ranks)")
    ap.add_argument("--trials", type=int, default=3)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--out", default=None,
                    help="output directory (default: $REPRO_RESULTS_DIR "
                         "or results/repro)")
    args = ap.parse_args()
    res = run(trials=args.trials, T=args.steps, smoke=args.smoke,
              out_dir=args.out)
    for pname, s in res["summary"].items():
        t2t = ", ".join(
            f"{m}={v:.2f}s" if v is not None else f"{m}=never"
            for m, v in s["time_to_target_s"].items())
        bias = ", ".join(f"{m}={b:.3f}"
                         for m, b in s["weight_bias_max"].items())
        speed = s.get("rate_aware_vs_mean_rate_speedup")
        print(f"{pname:8s} target={s['target_loss']:.1f}  {t2t}"
              + (f"  rate-aware x{speed:.2f}" if speed else "")
              + f"  |bias|={bias}")


if __name__ == "__main__":
    main()
