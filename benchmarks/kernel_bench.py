"""Microbenchmarks of the COCO-EF hot-path ops: fused vs unfused.

Every `*_local_step` pair times the SAME math two ways:

  unfused — the pre-backend-layer train path: accumulate (ref.mul_add),
            pack, unpack, error-update as four separately-jitted stages,
            each a full HBM round-trip over the model-sized vector.  The
            stages are the kernels/ref.py oracles (barrier-free), so this
            arm also exhibits THE perf bug the fused path fixes: XLA:CPU
            re-materializes `lax.top_k`'s sort once per consumer fusion.
  fused   — the `kernels.ops` entry point the train path calls, dispatched
            exactly like `WireFormat.fused_local_step` does (tile-guarded
            `resolve_use_pallas`).

Decode pairs compare the vmapped dense unpack + masked sum (unfused)
against the fused decode_reduce.

Honesty guarantees (this file used to lack both):
  * every pair is VERIFIED before it is timed — float outputs must
    allclose and the top-k index SETS must match exactly per block; a
    mismatch aborts the bench with a nonzero exit instead of publishing
    timings of two different computations;
  * each row records `backend_requested` (the --backend flag) AND
    `backend_ran` ("jnp" | "pallas" | "pallas-interpret") — the tile
    guard can silently reject a shape, and a "pallas" number that really
    measured the jnp path is worse than no number.

`--min-speedup name=floor` turns the bench into a CI regression gate:
exit 1 if any named row's fused/unfused speedup drops below its floor.
Writes BENCH_kernels.json so the perf trajectory is tracked across PRs.
"""
import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.kernels.sign_pack import G_BLK as _SIGN_G_BLK
from repro.kernels.topk_pack import R_BLK as _TOPK_R_BLK

try:
    from . import _repro_common as R
except ImportError:
    import _repro_common as R

N_DEFAULT = 1 << 22     # 4M-element gradient slice
GROUP = 512
K, BLOCK = 16, 512
N_SENDERS = 8


def _time(fn, *args, iters=20, repeats=3):
    """us/call: best (min) of `repeats` batches of `iters` calls each —
    the min filters out co-tenant noise on a shared box.  Warms up ONCE."""
    out = fn(*args)                      # warm up ONCE (compile + first run)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters * 1e6)
    return best


def _pipeline(*stages):
    """Run separately-jitted stages back to back (each stage receives the
    previous stage's outputs spliced after the captured leading args)."""
    def run_all(*args):
        out = args
        for fn in stages:
            out = fn(*out)
            if not isinstance(out, tuple):
                out = (out,)
        return out
    return run_all


def _ran(use: bool) -> str:
    if not use:
        return "jnp"
    return "pallas" if jax.default_backend() == "tpu" else "pallas-interpret"


def _check(name, label, ok):
    if not ok:
        print(f"VERIFY FAILED [{name}] {label}: fused and unfused arms "
              f"disagree — refusing to time two different computations",
              file=sys.stderr)
        raise SystemExit(2)


def _close(a, b, tol=1e-6):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return bool(np.allclose(a, b, rtol=tol, atol=tol))


def _same_index_sets(ia, ib):
    """Exact per-block SET equality: order may differ only within ties,
    but the selected coordinates must be identical."""
    ia, ib = np.asarray(ia), np.asarray(ib)
    return bool(np.array_equal(np.sort(ia, -1), np.sort(ib, -1)))


def run(n: int = N_DEFAULT, iters: int = 20, backend: str = "auto"):
    """Paired unfused-vs-fused timings; returns a list of row dicts.
    Every pair is verified (allclose + exact index sets) before timing."""
    gamma, mask_self = 0.01, 1.0
    use_req = ops.backend_use_pallas(backend)
    x = jax.random.normal(jax.random.PRNGKey(0), (n,))
    e = jax.random.normal(jax.random.PRNGKey(1), (n,)) * 0.1
    rows = []

    def pair(name, ran, unfused_us, fused_us):
        rows.append({"name": name, "n": n,
                     "backend_requested": backend, "backend_ran": ran,
                     "jnp_unfused_us": round(unfused_us, 1),
                     "fused_us": round(fused_us, 1),
                     "speedup": round(unfused_us / fused_us, 2)})

    # ---- sign wire: fused local step (EF + pack + c) ----------------------
    acc_fn = jax.jit(lambda g, ee: (ref.mul_add(gamma, g, ee), g, ee))
    pack_fn = jax.jit(lambda a, g, ee: ref.sign_pack_ref(a, GROUP)
                      + (a, ee))
    unpack_fn = jax.jit(lambda w, s, a, ee:
                        (ref.sign_unpack_ref(w, s, GROUP), w, s, a, ee))
    enew_fn = jax.jit(lambda c, w, s, a, ee:
                      (w, s, c, jnp.where(mask_self > 0, a - c, ee)))
    unfused = _pipeline(acc_fn, pack_fn, unpack_fn, enew_fn)
    s_use = ops.resolve_use_pallas(use_req, n, _SIGN_G_BLK * GROUP,
                                   op="ef_sign_fused", dtype="float32")
    fused = jax.jit(lambda g, ee: ops.ef_sign_fused(g, ee, gamma, mask_self,
                                                    GROUP, use_pallas=s_use))
    uw, us_, uc, ue = unfused(x, e)
    fw, fs, fc, fe = fused(x, e)
    _check("ef_sign_local_step", "sign words", np.array_equal(uw, fw))
    _check("ef_sign_local_step", "scales", _close(us_, fs))
    _check("ef_sign_local_step", "c", _close(uc, fc))
    _check("ef_sign_local_step", "e_new", _close(ue, fe))
    pair("ef_sign_local_step", _ran(s_use),
         _time(unfused, x, e, iters=iters), _time(fused, x, e, iters=iters))

    # ---- sparse wire: fused local step ------------------------------------
    tacc_fn = jax.jit(lambda g, ee: (ref.mul_add(gamma, g, ee), g, ee))
    tpack_fn = jax.jit(lambda a, g, ee: ref.topk_pack_ref(a, K, BLOCK)
                       + (a, ee))
    tunpack_fn = jax.jit(lambda i, v, s, a, ee:
                         (ref.topk_unpack_ref(i, v, s, BLOCK), i, v, s, a, ee))
    tenew_fn = jax.jit(lambda c, i, v, s, a, ee:
                       (i, v, s, c, jnp.where(mask_self > 0, a - c, ee)))
    tunfused = _pipeline(tacc_fn, tpack_fn, tunpack_fn, tenew_fn)
    t_use = ops.resolve_use_pallas(use_req, n, _TOPK_R_BLK * BLOCK,
                                   op="ef_topk_fused", dtype="float32")
    tfused = jax.jit(lambda g, ee: ops.ef_topk_fused(g, ee, gamma, mask_self,
                                                     K, BLOCK,
                                                     use_pallas=t_use))
    ui, uv, usc, uc, ue = tunfused(x, e)
    fi, fv, fsc, fc, fe = tfused(x, e)
    _check("ef_topk_local_step", "index sets", _same_index_sets(ui, fi))
    _check("ef_topk_local_step", "scales", _close(usc, fsc))
    _check("ef_topk_local_step", "c", _close(uc, fc))
    _check("ef_topk_local_step", "e_new", _close(ue, fe))
    pair("ef_topk_local_step", _ran(t_use),
         _time(tunfused, x, e, iters=iters), _time(tfused, x, e, iters=iters))

    # ---- decode + masked reduce (server side, N senders) ------------------
    nc = n // N_SENDERS                  # per-sender chunk, total work = n
    mask = (jnp.arange(N_SENDERS) % 2).astype(jnp.float32)
    w, s = ref.sign_pack_ref(x[:nc], GROUP)
    words = jnp.stack([w] * N_SENDERS)
    scales = jnp.stack([s] * N_SENDERS)
    dec_unf = _pipeline(
        jax.jit(lambda ws, ss: (jax.vmap(
            lambda a, b: ref.sign_unpack_ref(a, b, GROUP))(ws, ss),)),
        jax.jit(lambda dec: (mask[:, None] * dec).sum(0)))
    sd_use = ops.resolve_use_pallas(use_req, nc, _SIGN_G_BLK * GROUP,
                                    op="sign_decode_reduce",
                                    dtype="float32")
    dec_fus = jax.jit(lambda ws, ss: ops.sign_decode_reduce(
        ws, ss, mask, GROUP, use_pallas=sd_use))
    _check("sign_decode_reduce", "reduced vector",
           _close(dec_unf(words, scales)[0], dec_fus(words, scales)))
    pair("sign_decode_reduce", _ran(sd_use),
         _time(dec_unf, words, scales, iters=iters),
         _time(dec_fus, words, scales, iters=iters))

    ti, tv, ts = ref.topk_pack_ref(x[:nc], K, BLOCK)
    tis = jnp.stack([ti] * N_SENDERS)
    tvs = jnp.stack([tv] * N_SENDERS)
    tss = jnp.stack([ts] * N_SENDERS)
    tdec_unf = _pipeline(
        jax.jit(lambda a, b, c: (jax.vmap(
            lambda i, v, sc: ref.topk_unpack_ref(i, v, sc, BLOCK))(a, b, c),)),
        jax.jit(lambda dec: (mask[:, None] * dec).sum(0)))
    td_use = ops.resolve_use_pallas(use_req, nc, _TOPK_R_BLK * BLOCK,
                                    op="topk_decode_reduce",
                                    dtype="float32")
    tdec_fus = jax.jit(lambda a, b, c: ops.topk_decode_reduce(
        a, b, c, mask, BLOCK, use_pallas=td_use))
    _check("topk_decode_reduce", "reduced vector",
           _close(tdec_unf(tis, tvs, tss)[0], tdec_fus(tis, tvs, tss)))
    pair("topk_decode_reduce", _ran(td_use),
         _time(tdec_unf, tis, tvs, tss, iters=iters),
         _time(tdec_fus, tis, tvs, tss, iters=iters))

    return rows


def _parse_floor(s: str):
    try:
        name, floor = s.split("=", 1)
        return name, float(floor)
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"expected name=floor (e.g. ef_topk_local_step=2.0), got {s!r}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=N_DEFAULT,
                    help="flat vector length (default 4M)")
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--backend", default="auto", choices=ops.BACKENDS,
                    help="kernel dispatch: auto = Pallas on TPU, jnp "
                         "elsewhere; rows record what actually ran")
    ap.add_argument("--json", default="BENCH_kernels.json",
                    help="artifact path ('' to skip)")
    ap.add_argument("--min-speedup", action="append", type=_parse_floor,
                    default=[], metavar="NAME=FLOOR",
                    help="fail (exit 1) if the named row's fused/unfused "
                         "speedup is below FLOOR; repeatable")
    args = ap.parse_args()

    rows = run(n=args.n, iters=args.iters, backend=args.backend)
    print(f"{'op':24s} {'ran':>16s} {'jnp_unfused_us':>14s} "
          f"{'fused_us':>10s} {'speedup':>8s}")
    for r in rows:
        print(f"{r['name']:24s} {r['backend_ran']:>16s} "
              f"{r['jnp_unfused_us']:14.1f} "
              f"{r['fused_us']:10.1f} {r['speedup']:7.2f}x")
    if args.json:
        artifact = {"n": args.n, "iters": args.iters,
                    "jax": jax.__version__,
                    "backend_requested": args.backend,
                    "backend": jax.default_backend(),
                    "meta": R.run_metadata(backend_requested=args.backend),
                    "rows": rows}
        with open(args.json, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"wrote {args.json}")

    floors = dict(args.min_speedup)
    by_name = {r["name"]: r for r in rows}
    failed = False
    for name, floor in floors.items():
        row = by_name.get(name)
        if row is None:
            print(f"--min-speedup: no row named {name!r} "
                  f"(have {sorted(by_name)})", file=sys.stderr)
            failed = True
        elif row["speedup"] < floor:
            print(f"REGRESSION: {name} speedup {row['speedup']:.2f}x is "
                  f"below the floor {floor:.2f}x", file=sys.stderr)
            failed = True
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
