"""Microbenchmarks of the COCO-EF hot-path ops: fused vs unfused.

Every `*_local_step` pair times the SAME math two ways:

  unfused — the pre-backend-layer train path: accumulate (gamma*g + e),
            pack, unpack, error-update as four separately-jitted stages,
            each a full HBM round-trip over the model-sized vector.
  fused   — the `WireFormat.fused_local_step` entry point the train path
            now calls (kernels.ops dispatch: Pallas on TPU, single-fusion
            jnp reference elsewhere).

Decode pairs compare the vmapped dense unpack + masked sum (unfused)
against the fused decode_reduce.  Numbers on CPU are for relative
comparison; Pallas engages on TPU.  Writes BENCH_kernels.json so the perf
trajectory is tracked across PRs (CI uploads it as an artifact).
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

N_DEFAULT = 1 << 22     # 4M-element gradient slice
GROUP = 512
K, BLOCK = 16, 512
N_SENDERS = 8


def _time(fn, *args, iters=20, repeats=3):
    """us/call: best (min) of `repeats` batches of `iters` calls each —
    the min filters out co-tenant noise on a shared box.  Warms up ONCE."""
    out = fn(*args)                      # warm up ONCE (compile + first run)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters * 1e6)
    return best


def _pipeline(*stages):
    """Run separately-jitted stages back to back (each stage receives the
    previous stage's outputs spliced after the captured leading args)."""
    def run_all(*args):
        out = args
        for fn in stages:
            out = fn(*out)
            if not isinstance(out, tuple):
                out = (out,)
        return out
    return run_all


def run(n: int = N_DEFAULT, iters: int = 20):
    """Paired jnp-vs-fused timings; returns a list of row dicts."""
    gamma, mask_self = 0.01, 1.0
    x = jax.random.normal(jax.random.PRNGKey(0), (n,))
    e = jax.random.normal(jax.random.PRNGKey(1), (n,)) * 0.1
    rows = []

    def pair(name, unfused_us, fused_us):
        rows.append({"name": name, "n": n,
                     "jnp_unfused_us": round(unfused_us, 1),
                     "fused_us": round(fused_us, 1),
                     "speedup": round(unfused_us / fused_us, 2)})

    # ---- sign wire: fused local step (EF + pack + c) ----------------------
    acc_fn = jax.jit(lambda g, ee: (gamma * g + ee, g, ee))
    pack_fn = jax.jit(lambda a, g, ee: ref.sign_pack_ref(a, GROUP)
                      + (a, ee))
    unpack_fn = jax.jit(lambda w, s, a, ee:
                        (ref.sign_unpack_ref(w, s, GROUP), w, s, a, ee))
    enew_fn = jax.jit(lambda c, w, s, a, ee:
                      (w, s, c, jnp.where(mask_self > 0, a - c, ee)))
    unfused = _pipeline(acc_fn, pack_fn, unpack_fn, enew_fn)
    fused = jax.jit(lambda g, ee: ops.ef_sign_fused(g, ee, gamma, mask_self,
                                                    GROUP))
    pair("ef_sign_local_step",
         _time(unfused, x, e, iters=iters), _time(fused, x, e, iters=iters))

    # ---- sparse wire: fused local step ------------------------------------
    tacc_fn = jax.jit(lambda g, ee: (gamma * g + ee, g, ee))
    tpack_fn = jax.jit(lambda a, g, ee: ref.topk_pack_ref(a, K, BLOCK)
                       + (a, ee))
    tunpack_fn = jax.jit(lambda i, v, s, a, ee:
                         (ref.topk_unpack_ref(i, v, s, BLOCK), i, v, s, a, ee))
    tenew_fn = jax.jit(lambda c, i, v, s, a, ee:
                       (i, v, s, c, jnp.where(mask_self > 0, a - c, ee)))
    tunfused = _pipeline(tacc_fn, tpack_fn, tunpack_fn, tenew_fn)
    tfused = jax.jit(lambda g, ee: ops.ef_topk_fused(g, ee, gamma, mask_self,
                                                     K, BLOCK))
    pair("ef_topk_local_step",
         _time(tunfused, x, e, iters=iters), _time(tfused, x, e, iters=iters))

    # ---- decode + masked reduce (server side, N senders) ------------------
    nc = n // N_SENDERS                  # per-sender chunk, total work = n
    mask = (jnp.arange(N_SENDERS) % 2).astype(jnp.float32)
    w, s = ref.sign_pack_ref(x[:nc], GROUP)
    words = jnp.stack([w] * N_SENDERS)
    scales = jnp.stack([s] * N_SENDERS)
    dec_unf = _pipeline(
        jax.jit(lambda ws, ss: (jax.vmap(
            lambda a, b: ref.sign_unpack_ref(a, b, GROUP))(ws, ss),)),
        jax.jit(lambda dec: (mask[:, None] * dec).sum(0)))
    dec_fus = jax.jit(lambda ws, ss: ops.sign_decode_reduce(ws, ss, mask,
                                                            GROUP))
    pair("sign_decode_reduce",
         _time(dec_unf, words, scales, iters=iters),
         _time(dec_fus, words, scales, iters=iters))

    ti, tv, ts = ref.topk_pack_ref(x[:nc], K, BLOCK)
    tis = jnp.stack([ti] * N_SENDERS)
    tvs = jnp.stack([tv] * N_SENDERS)
    tss = jnp.stack([ts] * N_SENDERS)
    tdec_unf = _pipeline(
        jax.jit(lambda a, b, c: (jax.vmap(
            lambda i, v, sc: ref.topk_unpack_ref(i, v, sc, BLOCK))(a, b, c),)),
        jax.jit(lambda dec: (mask[:, None] * dec).sum(0)))
    tdec_fus = jax.jit(lambda a, b, c: ops.topk_decode_reduce(a, b, c, mask,
                                                              BLOCK))
    pair("topk_decode_reduce",
         _time(tdec_unf, tis, tvs, tss, iters=iters),
         _time(tdec_fus, tis, tvs, tss, iters=iters))

    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=N_DEFAULT,
                    help="flat vector length (default 4M)")
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--json", default="BENCH_kernels.json",
                    help="artifact path ('' to skip)")
    args = ap.parse_args()

    rows = run(n=args.n, iters=args.iters)
    print(f"{'op':24s} {'jnp_unfused_us':>14s} {'fused_us':>10s} "
          f"{'speedup':>8s}")
    for r in rows:
        print(f"{r['name']:24s} {r['jnp_unfused_us']:14.1f} "
              f"{r['fused_us']:10.1f} {r['speedup']:7.2f}x")
    if args.json:
        artifact = {"n": args.n, "iters": args.iters,
                    "jax": jax.__version__,
                    "backend": jax.default_backend(), "rows": rows}
        with open(args.json, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
