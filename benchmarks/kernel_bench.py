"""Microbenchmarks of the COCO-EF hot-path ops (jnp reference path — the
numbers on CPU are for relative comparisons; Pallas engages on TPU)."""
import time

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _time(fn, *args, iters=20):
    fn(*args).block_until_ready() if hasattr(fn(*args), "block_until_ready") \
        else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    n, g = 1 << 22, 512     # 4M-element gradient slice
    x = jax.random.normal(jax.random.PRNGKey(0), (n,))
    e = jax.random.normal(jax.random.PRNGKey(1), (n,)) * 0.1

    pack = jax.jit(lambda v: ref.sign_pack_ref(v, g))
    fused = jax.jit(lambda a, b: ref.ef_sign_fused_ref(a, b, 0.01, 1.0, g))
    topk = jax.jit(lambda v: ref.block_topk_ref(v, 16, 512))
    tpack = jax.jit(lambda v: ref.topk_pack_ref(v, 16, 512))

    w, s = pack(x)
    unpack = jax.jit(lambda ww, ss: ref.sign_unpack_ref(ww, ss, g))
    ti, tv, ts = tpack(x)
    tunpack = jax.jit(lambda a, b, c: ref.topk_unpack_ref(a, b, c, 512))

    rows = [
        ("sign_pack_4M", _time(pack, x), n * 4 / 8 / 1.0),   # bytes ratio
        ("sign_unpack_4M", _time(unpack, w, s), 0),
        ("ef_fused_4M", _time(fused, x, e), 0),
        ("block_topk_4M", _time(topk, x), 0),
        ("topk_pack_4M", _time(tpack, x), 0),
        ("topk_unpack_4M", _time(tunpack, ti, tv, ts), 0),
    ]
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
