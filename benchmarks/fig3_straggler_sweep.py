"""Fig. 3: COCO-EF (Sign) under varying straggler probability p.
Protocol: d_k=2, gamma=1e-5; degradation should be mild until p -> 1."""
import json
from pathlib import Path

from repro.core import compression as C

from . import _repro_common as R

OUT = Path(__file__).resolve().parents[1] / "results" / "repro"
PS = [0.1, 0.3, 0.5, 0.7, 0.9]


def run(trials=5, T=400):
    res = {}
    for p in PS:
        res[f"p={p}"] = R.run_trials("cocoef", C.GroupedSign(), trials=trials,
                                     d=2, p=p, gamma=1e-5, T=T)
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "fig3.json").write_text(json.dumps(res, indent=1))
    return res


if __name__ == "__main__":
    for k, v in run().items():
        print(f"{k:8s} final_loss={v['loss'][-1]:.1f}")
