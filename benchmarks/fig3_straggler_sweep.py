"""Fig. 3: COCO-EF under varying straggler probability p — generalized
over every wire format (sign / block top-K / dense) and over the pluggable
straggler processes of `repro.sim` (iid Bernoulli by default; pass
`straggler="markov"|"hetero"` to exercise correlated bursts or per-rank
heterogeneity from the same figure).

Protocol: d_k=2, gamma=1e-5; degradation should be mild until p -> 1.

  PYTHONPATH=src python benchmarks/fig3_straggler_sweep.py [--straggler markov]
"""
import argparse
import json

from repro.core import compression as C
from repro.sim import get_straggler_process

try:
    from . import _repro_common as R
except ImportError:                      # run as a script
    import _repro_common as R

OUT = None                # optional override; default R.results_dir()
PS = [0.1, 0.3, 0.5, 0.7, 0.9]

# wire-format sweep: (method, compressor) per wire the collective supports
WIRES = {
    "sign": ("cocoef", C.GroupedSign()),
    "block_topk": ("cocoef", C.BlockTopK(k_per_block=2, block_size=20)),
    "dense": ("uncompressed", None),
}


def run(trials=5, T=400, wires=tuple(WIRES), straggler="iid", N=100,
        mean_burst=8.0, spread=0.5):
    res = {}
    for wname in wires:
        method, comp = WIRES[wname]
        for p in PS:
            eff_spread = (R.hetero_spread(p, spread)
                          if straggler == "hetero" else spread)
            eff_burst = (R.markov_burst(p, mean_burst)
                         if straggler == "markov" else mean_burst)
            proc = get_straggler_process(straggler, N, p,
                                         mean_burst=eff_burst,
                                         spread=eff_spread)
            res[f"{wname},p={p}"] = R.run_trials(
                method, comp, trials=trials, N=N, M=N, d=2, p=p, gamma=1e-5,
                T=T, straggler=proc)
    res["meta"] = {**R.run_metadata(trials=trials, T=T),
                   "straggler": straggler, "wires": list(wires), "N": N}
    out = OUT or R.results_dir()
    out.mkdir(parents=True, exist_ok=True)
    suffix = "" if straggler == "iid" else f"_{straggler}"
    (out / f"fig3{suffix}.json").write_text(json.dumps(res, indent=1))
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--straggler", default="iid",
                    choices=["iid", "markov", "hetero"])
    ap.add_argument("--trials", type=int, default=5)
    ap.add_argument("--steps", type=int, default=400)
    args = ap.parse_args()
    out = run(trials=args.trials, T=args.steps, straggler=args.straggler)
    for k, v in out.items():
        if k == "meta":
            continue
        print(f"{k:20s} final_loss={v['loss'][-1]:.1f}")
