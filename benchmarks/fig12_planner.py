"""Fig. 12 (beyond-paper): the auto-planner vs the exhaustive (d, wire, k)
grid.

The planner (`repro.sim.plan_search`) claims its three stages — enumerate
the PlanSpec grid, prune analytically with StepTimer x convergence-penalty,
confirm the survivors with short simulated runs — land on the cell an
exhaustive sweep would pick.  This benchmark checks that claim the honest
way: EVERY cell of `enumerate_candidates` is trained through the fig8
protocol (reference EF dynamics under the straggler process's masks,
joined to the cell's own StepTimer wall clock at production wire scale)
under each non-iid process (hetero / markov / trace), time-to-target is
measured against one shared drop target, and the planner's pick must
dominate or tie the best fixed cell.

It also runs the "config priced is config run" audit on every pick: the
per-rank uplink bytes the chosen plan's StepTimer charges must equal the
PlanSpec's own `rank_wire_bytes` ledger exactly (same object, two readers).

Emits results/repro/fig12.json.

  PYTHONPATH=src python benchmarks/fig12_planner.py [--smoke] [--strict]
"""
import argparse
import dataclasses
import json
import sys
from pathlib import Path

import jax
import numpy as np

from repro.sim import (DEFAULT_COMPUTE, DEFAULT_LINK, HeterogeneousRates,
                       MarkovBursty, TraceReplay, attach_times,
                       enumerate_candidates, plan_search, simulate_run)
from repro.sim.planner import plan_allocation, toy_compressor

try:
    from . import _repro_common as R
except ImportError:                      # run as a script
    import _repro_common as R

OUT = None                # optional override; default R.results_dir()

N_WIRE = 1 << 22          # production wire scale (ROADMAP comm table)

P_SLOW, P_FAST, SLOW_FRACTION = 0.8, 0.02, 0.3

# measured-t2t slack for "tie": the planner's cell must be within this
# factor of the best fixed cell (trial noise on short runs)
TIE_TOL = 0.15


def _processes(N, smoke=False):
    """fig9's non-iid family: two-class hetero, bursty markov, and a
    recorded trace with one total-outage row."""
    two = HeterogeneousRates.two_class(N, p_slow=P_SLOW, p_fast=P_FAST,
                                       slow_fraction=SLOW_FRACTION)
    rows = np.array(two.sample_trace(jax.random.PRNGKey(99),
                                     24 if smoke else 64))
    rows[3, :] = 0.0
    return {
        "hetero": two,
        "markov": MarkovBursty(num_devices=N, p=0.2,
                               mean_burst=4.0 if smoke else 8.0),
        "trace": TraceReplay.from_array(rows),
    }


def cell_label(plan) -> str:
    k = plan.k_per_block
    ks = ""
    if plan.compressor == "block_topk":
        ks = "-k*" if isinstance(k, tuple) else f"-k{k}"
    return f"d{plan.d}-{plan.compressor}{ks}"


def _cell_curve(plan, proc, rates, *, n_wire, link, compute, trials, T,
                gamma, dim, record_every):
    """Brute-force ground truth for one grid cell: the fig8 protocol —
    reference EF dynamics at toy `dim` under the process's masks, priced
    by THIS cell's plan_timer at production `n_wire`."""
    N = proc.num_devices
    alloc = plan_allocation(plan, rates)
    timer = R.plan_timer(plan, n_wire, link, compute)
    per_trial = []
    for s in range(trials):
        grad_fn, loss_fn, theta0, _ = R.tasks.linreg_task(
            seed=s, num_subsets=alloc.num_subsets, dim=dim)
        comp = toy_compressor(plan, dim, n_wire)
        method = "uncompressed" if comp is None else "cocoef"
        hist = R.run_trial(method, comp, grad_fn, loss_fn, theta0,
                           N=N, M=alloc.num_subsets, d=plan.d,
                           p=float(1.0 - np.mean(rates)), gamma=gamma,
                           T=T, seed=s, record_every=record_every,
                           straggler=proc, rate_aware=True,
                           allocation=alloc)
        sim = simulate_run(proc, timer, T, jax.random.PRNGKey(1000 + s))
        per_trial.append(attach_times(hist, sim))
    return R.summarize_trials(per_trial, keys=("loss", "time_s"))


def price_audit(plan, n_wire, N, link, compute) -> dict:
    """The type-level guarantee, checked numerically anyway: the StepTimer
    built from a plan charges exactly the per-rank uplink bytes the plan's
    own `rank_wire_bytes` ledger declares."""
    timer = R.plan_timer(plan, n_wire, link, compute)
    t_bytes = np.asarray(timer.bytes_up_ranks(N))
    p_bytes = np.asarray(plan.rank_wire_bytes(n_wire))
    match = bool(np.array_equal(t_bytes, p_bytes))
    if not match:                         # pragma: no cover
        raise AssertionError(
            f"price audit FAILED for {cell_label(plan)}: timer charges "
            f"{t_bytes.tolist()} but the plan ledger says "
            f"{p_bytes.tolist()}")
    return {"bytes_up_per_rank": [int(b) for b in p_bytes],
            "total_bytes_up": int(t_bytes.sum()), "match": match}


def run(trials=2, T=300, N=32, gamma=1e-5, record_every=20,
        n_wire=N_WIRE, link=DEFAULT_LINK, compute=DEFAULT_COMPUTE,
        smoke=False, out_dir=None):
    if smoke:
        trials, T, N, record_every = 1, 80, 12, 10
    dim = 2 * N
    grid = enumerate_candidates(N, link=link, n=n_wire)
    res = {"meta": {**R.run_metadata(), "n_wire": n_wire, "trials": trials,
                    "T": T, "N": N, "dim": dim, "gamma": gamma,
                    "tie_tol": TIE_TOL, "grid_size": len(grid),
                    "grid": [cell_label(p) for p in grid],
                    "two_class": {"p_slow": P_SLOW, "p_fast": P_FAST,
                                  "slow_fraction": SLOW_FRACTION},
                    "link": dataclasses.asdict(link),
                    "compute": dataclasses.asdict(compute)},
           "curves": {}, "summary": {}}

    all_pass = True
    for pname, proc in _processes(N, smoke=smoke).items():
        rates = np.asarray(proc.rates())
        curves = {}
        for plan in grid:
            curves[cell_label(plan)] = _cell_curve(
                plan, proc, rates, n_wire=n_wire, link=link,
                compute=compute, trials=trials, T=T, gamma=gamma,
                dim=dim, record_every=record_every)
        target, t2t = R.drop_target_and_t2t(curves)

        # the planner's three-stage pick over the SAME grid
        search = plan_search(n_wire, link=link, compute=compute,
                             process=proc, candidates=grid, top_k=4,
                             confirm_steps=min(T, 150), trials=trials,
                             seed=0, dim=dim, gamma=gamma,
                             record_every=record_every)
        pick = search.best.plan
        pick_label = cell_label(pick)
        inf = float("inf")
        best_label = min(t2t, key=lambda m: (t2t[m] if t2t[m] is not None
                                             else inf, m))
        best_t2t, pick_t2t = t2t[best_label], t2t[pick_label]
        ok = (pick_t2t is not None and best_t2t is not None
              and pick_t2t <= best_t2t * (1.0 + TIE_TOL))
        all_pass = all_pass and ok

        res["curves"][pname] = curves
        res["summary"][pname] = {
            "target_loss": target, "time_to_target_s": t2t,
            "planner_pick": pick.to_dict(), "pick_label": pick_label,
            "pick_time_to_target_s": pick_t2t,
            "best_fixed_label": best_label,
            "best_fixed_time_to_target_s": best_t2t,
            "dominates_or_ties": ok,
            "num_enumerated": search.num_enumerated,
            "pruned_to": search.pruned_to,
            "price_audit": price_audit(pick, n_wire, N, link, compute)}
    res["meta"]["all_dominate_or_tie"] = all_pass

    out = Path(out_dir) if out_dir else (OUT or R.results_dir())
    out.mkdir(parents=True, exist_ok=True)
    (out / "fig12.json").write_text(json.dumps(res, indent=1))
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny configuration for CI (1 trial, 80 steps, "
                         "12 ranks)")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero unless the planner dominates or "
                         "ties every process (full-run acceptance; smoke "
                         "runs are too short to gate on)")
    ap.add_argument("--trials", type=int, default=2)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--out", default=None,
                    help="output directory (default: $REPRO_RESULTS_DIR "
                         "or results/repro)")
    args = ap.parse_args()
    res = run(trials=args.trials, T=args.steps, smoke=args.smoke,
              out_dir=args.out)
    for pname, s in res["summary"].items():
        pick = s["pick_time_to_target_s"]
        best = s["best_fixed_time_to_target_s"]
        fmt = lambda v: f"{v:.3f}s" if v is not None else "never"
        tag = "OK " if s["dominates_or_ties"] else "MISS"
        print(f"{pname:8s} [{tag}] planner={s['pick_label']:16s} "
              f"t2t={fmt(pick)}  best-fixed={s['best_fixed_label']:16s} "
              f"t2t={fmt(best)}  "
              f"(grid {s['num_enumerated']} -> confirm {s['pruned_to']}; "
              f"audit {'ok' if s['price_audit']['match'] else 'FAIL'})")
    if args.strict and not res["meta"]["all_dominate_or_tie"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
