"""Fig. 7: heterogeneous image classification (label-sharded subsets),
COCO-EF (Sign) vs Unbiased (Sign) across d_k, p=0.6.

MNIST is unavailable offline; the synthetic 10-class set keeps the exact
heterogeneity protocol (every subset single-class).  Claims validated:
COCO-EF beats Unbiased at every d_k; performance improves with d_k.
"""
import json
from pathlib import Path

from repro.core import compression as C

from . import _repro_common as R

OUT = Path(__file__).resolve().parents[1] / "results" / "repro"
DS = [1, 2, 5]


def run(trials=3, T=300):
    res = {}
    for d in DS:
        res[f"cocoef_d={d}"] = R.run_trials(
            "cocoef", C.GroupedSign(), task="classification", trials=trials,
            d=d, p=0.6, gamma=3e-3, T=T, record_every=25)
        res[f"unbiased_d={d}"] = R.run_trials(
            "unbiased", C.StochasticSign(), task="classification",
            trials=trials, d=d, p=0.6, gamma=1e-3, T=T, record_every=25)
    res["meta"] = R.run_metadata(trials=trials, T=T, p=0.6, ds=DS)
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "fig7.json").write_text(json.dumps(res, indent=1))
    return res


if __name__ == "__main__":
    for k, v in run().items():
        if k == "meta":
            continue
        print(f"{k:16s} loss={v['loss'][-1]:.3f} test_acc={v['test_acc'][-1]:.3f}")
