"""Fig. 6: constant vs decaying learning rate for COCO-EF (Sign).
Protocol: p=0.5, d_k=2, constant gamma=2e-5 vs gamma_t=2e-5/sqrt(t+1).
Claim: constant is significantly better (error-vector staleness)."""
import json
import math
from pathlib import Path

from repro.core import compression as C

from . import _repro_common as R

OUT = Path(__file__).resolve().parents[1] / "results" / "repro"


def run(trials=5, T=400):
    res = {
        "constant": R.run_trials("cocoef", C.GroupedSign(), trials=trials,
                                 d=2, p=0.5, gamma=2e-5, T=T),
        "decaying": R.run_trials("cocoef", C.GroupedSign(), trials=trials,
                                 d=2, p=0.5, T=T,
                                 gamma_fn=lambda t: 2e-5 / math.sqrt(t + 1)),
    }
    res["meta"] = R.run_metadata(trials=trials, T=T, p=0.5, d=2)
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "fig6.json").write_text(json.dumps(res, indent=1))
    return res


if __name__ == "__main__":
    for k, v in run().items():
        print(f"{k:10s} final_loss={v['loss'][-1]:.1f}")
