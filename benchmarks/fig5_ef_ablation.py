"""Fig. 5: error-feedback ablation (COCO-EF vs COCO), sign and top-K.
Claim: COCO(TopK) stalls; COCO-EF converges; EF is essential."""
import json
from pathlib import Path

from repro.core import compression as C

from . import _repro_common as R

OUT = Path(__file__).resolve().parents[1] / "results" / "repro"

CASES = {
    "cocoef_sign": ("cocoef", C.GroupedSign()),
    "coco_sign": ("coco", C.GroupedSign()),
    "cocoef_topk": ("cocoef", C.TopK(k=2)),
    "coco_topk": ("coco", C.TopK(k=2)),
}


def run(trials=5, T=400):
    res = {}
    for name, (m, comp) in CASES.items():
        res[name] = R.run_trials(m, comp, trials=trials, d=5, p=0.2,
                                 gamma=1e-5, T=T)
    res["meta"] = R.run_metadata(trials=trials, T=T)
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "fig5.json").write_text(json.dumps(res, indent=1))
    return res


if __name__ == "__main__":
    for k, v in run().items():
        if k == "meta":
            continue
        print(f"{k:14s} final_loss={v['loss'][-1]:.1f}")
