"""Fig. 10 (beyond-paper): model-zoo time-to-accuracy on the REAL mesh
train step.

Every other figure trains through the (N, D) reference EF loop on linreg /
CNN toys; this sweep drives the PRODUCTION path end to end — `REGISTRY`
ArchSpecs -> `build_train_setup` -> the jitted shard_map train step with
Pallas-fused wires (`cocoef_update`) — over a matrix of

  model family   x  wire          x  straggler process
  (dense / MoE /    (sign /          (iid / markov / hetero)
   xLSTM)           block_topk /
                    dense SGC)

with synthetic token batches from `repro.data.pipeline` and the loss/step
histories joined to the `repro.sim` wall-clock cost model via
`attach_times`, exactly like fig8.  Two things fig8 cannot tell:

  * per-model step COMPUTE comes from the compiled step itself:
    `ComputeProfile.from_compiled_hlo` feeds `launch.hlo_cost`'s
    while-aware flop count of the optimized HLO into `from_flops`, so the
    simulated step time scales with the architecture instead of the cost
    model's fixed 5 ms default;
  * the dynamics are the production Algorithm 1 on non-convex transformer /
    MoE / xLSTM losses (the Beznosikov et al. biased-vs-unbiased and
    Song & Choi heterogeneous-rate questions beyond linreg).

`--parity` runs the reference-vs-mesh Algorithm-1 parity gate
(`repro.launch.parity`) instead of the sweep: the reference EF loop and
the mesh `cocoef_update`, same linreg task / masks / wire, must match
BIT-FOR-BIT for every wire in {sign, block_topk, dense} — the same check
tests/test_algorithm_parity.py enforces in the suite.

Emits results/repro/fig10.json.

  PYTHONPATH=src python benchmarks/fig10_model_zoo.py [--smoke] [--parity]
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh
from repro.configs import REGISTRY, SMOKE_TRAIN
from repro.core.collectives import DenseWire
from repro.core.plan import PlanSpec
from repro.launch.train import (TrainRun, build_train_setup,
                                make_batch_for_step)
from repro.sim import (DEFAULT_LINK, ComputeProfile, StepTimer, attach_times,
                      simulate_run)

try:
    from . import _repro_common as R
except ImportError:                      # run as a script
    import _repro_common as R

OUT = None                # optional override; default R.results_dir()

ARCHS = ("gemma2-2b", "olmoe-1b-7b", "xlstm-1.3b")   # dense / MoE / xLSTM
WIRES = ("sign", "block_topk", "dense")
STRAGGLERS = ("iid", "markov", "hetero")

P_STRAG = 0.2             # straggler probability baked into every cell
# simulated fleet device: 1 TFLOP/s at 40% MFU (edge-accelerator flavored,
# matching the cost model's WAN link profile); only the RATIO between
# architectures matters for the table — flops come from the compiled HLO
DEVICE_FLOPS = 1e12
MFU = 0.4

# coding knobs scaled to the smoke flat sizes (the production 512-group
# would swallow the whole padded vector of a toy model)
_SMOKE_CODING = dict(group_size=32, block_size=64, k_per_block=4,
                     straggler_p=P_STRAG)


def _train_run(wire_name: str, straggler: str, coding_cfg,
               num_buckets: int = 1, overlap: bool = False) -> TrainRun:
    if wire_name == "dense":
        return TrainRun(mode="dense", base_lr=1e-2, straggler=straggler,
                        straggler_burst=4.0, straggler_spread=0.5)
    # explicit PlanSpec, not the deprecated alias fields: the one plan
    # object carries wire + bucket schedule, so the schedule the cost
    # model prices is the one the mesh runs by construction
    plan = R.plan_from_args(base=PlanSpec(
        d=coding_cfg.redundancy, compressor=wire_name,
        group_size=coding_cfg.group_size,
        k_per_block=coding_cfg.k_per_block,
        block_size=coding_cfg.block_size, topk_k=coding_cfg.topk_k,
        value_dtype=coding_cfg.wire_dtype, num_buckets=num_buckets,
        bucket_schedule="pipelined" if overlap else "serial"))
    return TrainRun(mode="cocoef", plan=plan, base_lr=1e-2,
                    straggler=straggler, straggler_burst=4.0,
                    straggler_spread=0.5)


def _timer_wire(setup, wire_name: str):
    """The phase-1 wire format the cost model charges for this cell —
    derived from setup.plan, the very PlanSpec the mesh step was built
    from (dense mode carries no plan wire)."""
    if wire_name == "dense":
        return DenseWire()
    return setup.plan.wire(setup.flat_pad, 1)


def run_cell(arch: str, wire_name: str, straggler: str, mesh, shape, *,
             T: int, trials: int, link=DEFAULT_LINK,
             num_buckets: int = 1, overlap: bool = False) -> dict:
    """One (arch, wire, straggler) cell: compile the real train step,
    derive the per-model compute profile from its HLO, train `trials`
    runs of `T` steps, and join the loss histories to the simulated
    wall-clock."""
    spec = REGISTRY[arch]
    spec = dataclasses.replace(
        spec, coding=dataclasses.replace(spec.coding, **_SMOKE_CODING))
    cfg = spec.smoke
    if cfg.input_mode != "tokens":
        raise ValueError(f"{arch}: fig10 feeds token batches from "
                         f"data.pipeline (input_mode={cfg.input_mode!r})")
    run = _train_run(wire_name, straggler, spec.coding,
                     num_buckets=num_buckets, overlap=overlap)
    setup = build_train_setup(spec, mesh, shape, run, smoke=True)
    proc = setup.straggler_process
    assert proc is not None, "straggler_p > 0 must build a process"
    ndev = int(np.prod(mesh.devices.shape))

    specs = setup.input_specs()
    compiled = jax.jit(setup.train_step).lower(
        specs["params"], specs["e"], specs["opt"], specs["batch"],
        specs["step"], specs["key"]).compile()

    # per-model compute: while-aware flops of THIS compiled step (per
    # device), not the cost model's fixed 5 ms default
    compute = ComputeProfile.from_compiled_hlo(
        compiled.as_text(), ndev, device_flops=DEVICE_FLOPS, mfu=MFU)

    n_model = ndev // max(setup.n_code, 1)
    n_wire = setup.flat_pad * n_model          # coords/coding rank on wire
    wire = _timer_wire(setup, wire_name)
    # dense cells keep the single-shot aggregation: bucketing is a knob of
    # the coded cocoef path, and pricing it on an un-bucketed wire would
    # claim overlap the mesh step never performs
    nb = 1 if wire_name == "dense" else num_buckets
    timer = StepTimer(wire=wire, n=n_wire, link=link, compute=compute,
                      num_buckets=nb, overlap=overlap and nb > 1)

    per_trial = []
    for s in range(trials):
        key = jax.random.PRNGKey(1000 + s)
        params, e, opt = setup.init_state(jax.random.fold_in(key, 7))
        hist = {"step": [], "loss": []}
        for t in range(T):
            # THE production batch maker (pipeline.coded_train_batch under
            # the hood): the sweep trains on exactly the batches the
            # production entry point would feed this compiled step
            batch = make_batch_for_step(setup, spec, shape, key, t,
                                        smoke=True)
            batch = jax.device_put(batch, setup.batch_shardings)
            params, e, opt, m = compiled(params, e, opt, batch,
                                         jnp.int32(t), key)
            hist["step"].append(t)
            hist["loss"].append(float(m["loss"]))
        # the SAME key the train step's mask provider folds -> the cost
        # model replays the identical mask trace (shared timeline)
        sim = simulate_run(proc, timer, T, key)
        per_trial.append(attach_times(hist, sim))

    return {
        "curve": R.summarize_trials(per_trial),
        "flops_per_device": compute_flops(compute),
        "grad_s": compute.grad_s,
        "n_wire": n_wire,
        "bytes_up_per_rank": int(wire.wire_bytes(n_wire)),
        "n_code": setup.n_code,
        "flat_pad": setup.flat_pad,
        "plan": setup.plan.to_dict(),
    }


def compute_flops(compute: ComputeProfile) -> float:
    return compute.grad_s * DEVICE_FLOPS * MFU


def _cells(smoke: bool):
    """The sweep's cell list.  Smoke trims the matrix so CI compiles ~11
    train steps instead of 27: the full wire axis runs under iid for every
    arch, and the full straggler axis runs on the MoE arch's sign wire —
    every (axis value) still exercised, logged in meta as trimmed."""
    if not smoke:
        return [(a, w, p) for a in ARCHS for w in WIRES for p in STRAGGLERS]
    cells = [(a, w, "iid") for a in ARCHS for w in WIRES]
    cells += [("olmoe-1b-7b", "sign", p) for p in ("markov", "hetero")]
    return cells


def run(T=60, trials=2, smoke=False, link=DEFAULT_LINK,
        num_buckets=1, overlap=False, out_dir=None):
    if smoke:
        T, trials = 12, 1
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    shape = SMOKE_TRAIN
    cells = _cells(smoke)
    res = {"meta": {**R.run_metadata(), "T": T, "trials": trials,
                    "shape": dataclasses.asdict(
                        shape),
                    "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
                    "p_straggler": P_STRAG,
                    "device_flops": DEVICE_FLOPS, "mfu": MFU,
                    "num_buckets": num_buckets, "overlap": overlap,
                    "link": dataclasses.asdict(link),
                    "cells": [list(c) for c in cells],
                    "trimmed": smoke},
           "curves": {}, "compute": {}, "summary": {}}

    for arch, wire_name, strag in cells:
        print(f"[fig10] {arch} x {wire_name} x {strag} ...", flush=True)
        cell = run_cell(arch, wire_name, strag, mesh, shape, T=T,
                        trials=trials, link=link,
                        num_buckets=num_buckets, overlap=overlap)
        res["curves"].setdefault(arch, {}).setdefault(strag, {})[
            wire_name] = cell.pop("curve")
        # keyed per CELL: the straggler process is compiled into the step
        # (mask provider), so its flop count is part of the profile —
        # collapsing over stragglers would misattribute compute
        res["compute"].setdefault(arch, {}).setdefault(strag, {})[
            wire_name] = cell

    for arch, by_strag in res["curves"].items():
        for strag, curves in by_strag.items():
            target, t2t = R.drop_target_and_t2t(curves)
            res["summary"].setdefault(arch, {})[strag] = {
                "target_loss": target, "time_to_target_s": t2t}

    out = Path(out_dir) if out_dir else (OUT or R.results_dir())
    out.mkdir(parents=True, exist_ok=True)
    (out / "fig10.json").write_text(json.dumps(res, indent=1))
    return res


def run_parity_gate(T=25) -> bool:
    """The reference-vs-mesh Algorithm-1 parity gate over every wire."""
    from repro.launch.parity import (PARITY_COMPRESSORS, assert_parity,
                                     run_parity)
    ok = True
    for comp in PARITY_COMPRESSORS:
        rep = run_parity(comp, T=T)
        tag = "BIT-EXACT" if rep["bitexact"] else "DIVERGED"
        print(f"[parity] {comp:10s} ({rep['wire']}) T={rep['T']}: {tag}  "
              f"loss {rep['loss_start']:.1f} -> ref {rep['loss_ref']:.1f} "
              f"/ mesh {rep['loss_mesh']:.1f}")
        try:
            assert_parity(rep)
        except AssertionError as e:
            ok = False
            print(f"  {e}")
    return ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI configuration: 1 trial, 12 steps, trimmed "
                         "cell matrix (every axis value still exercised)")
    ap.add_argument("--parity", action="store_true",
                    help="run the reference-vs-mesh Algorithm-1 parity "
                         "gate (bit-for-bit, every wire) instead of the "
                         "sweep")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--trials", type=int, default=2)
    ap.add_argument("--num-buckets", type=int, default=1,
                    help="flat-vector buckets for the coded wires: the "
                         "mesh step runs the bucketed schedule AND the "
                         "cost model prices it")
    ap.add_argument("--overlap", action="store_true",
                    help="pipelined bucket schedule (train step) + "
                         "overlap-aware aggregation pricing (cost model)")
    ap.add_argument("--out", default=None,
                    help="output directory (default: $REPRO_RESULTS_DIR "
                         "or results/repro)")
    args = ap.parse_args()
    if args.parity:
        raise SystemExit(0 if run_parity_gate() else 1)
    res = run(T=args.steps, trials=args.trials, smoke=args.smoke,
              num_buckets=args.num_buckets, overlap=args.overlap,
              out_dir=args.out)
    for arch, by_strag in res["summary"].items():
        rng = R.fmt_ms_range(*R.compute_range_ms(res["compute"][arch]))
        print(f"{arch}: compute {rng}/step")
        for strag, s in by_strag.items():
            t2t = ", ".join(
                f"{w}={v*1e3:.1f}ms" if v is not None else f"{w}=never"
                for w, v in s["time_to_target_s"].items())
            print(f"  {strag:7s} target={s['target_loss']:.3f}  {t2t}")


if __name__ == "__main__":
    main()
