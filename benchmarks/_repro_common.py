"""Shared driver for the paper-reproduction experiments (Sec. V).

Runs the (N, D) simulation engine (repro.core.error_feedback) with the
paper's protocol: uniform random allocation approximating pairwise balance,
Bernoulli stragglers, 5 independent trials, mean +/- std reporting.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import coding, compression as C, error_feedback as EF
from repro.data import tasks
from repro.sim import IIDBernoulli, StragglerProcess

METHODS = {
    "cocoef": EF.cocoef_step,
    "coco": EF.coco_step,
    "unbiased": EF.unbiased_step,
    "unbiased_diff": EF.unbiased_diff_step,
    "uncompressed": None,
}


def run_trial(method: str, compressor, grad_fn, loss_fn, theta0, *,
              N=100, M=100, d=5, p=0.2, gamma=1e-5, T=400, seed=0,
              gamma_fn=None, record_every=20, diff_alpha=0.2,
              eval_fns: Optional[Dict[str, Callable]] = None,
              straggler: Optional[StragglerProcess] = None):
    """`straggler` (repro.sim.StragglerProcess) drives the per-step masks;
    None keeps the paper's iid Bernoulli(p) — bit-for-bit the legacy
    `coding.straggler_mask` sequence for the same seed."""
    alloc = coding.random_allocation(seed, N, M, d)
    W = coding.encode_weights(alloc, p)
    if straggler is None:
        straggler = IIDBernoulli(num_devices=N, p=p)
    elif straggler.num_devices != N:
        raise ValueError(f"straggler process has {straggler.num_devices} "
                         f"devices, trial has N={N}")
    mask_key = jax.random.PRNGKey(1000 + seed)
    comp_key = jax.random.PRNGKey(2000 + seed)
    needs_key = compressor is not None and compressor.unbiased

    if method == "unbiased_diff":
        st = EF.DiffState.init(theta0, N)
    else:
        st = EF.EFState.init(theta0, N)

    hist = {"step": [], "loss": []}
    if eval_fns:
        for k in eval_fns:
            hist[k] = []

    def record(t):
        hist["step"].append(t)
        hist["loss"].append(float(loss_fn(st.theta)))
        if eval_fns:
            for k, fn in eval_fns.items():
                hist[k].append(float(np.asarray(fn(st.theta))))

    for t in range(T):
        mask = straggler.mask(mask_key, t)
        g = float(gamma_fn(t)) if gamma_fn else gamma
        kk = jax.random.fold_in(comp_key, t) if needs_key else None
        if method == "uncompressed":
            st = EF.uncompressed_step(st, grad_fn, W, mask, g, step=t)
        elif method == "unbiased_diff":
            st = EF.unbiased_diff_step(st, grad_fn, W, mask, g, compressor,
                                       step=t, key=kk, alpha=diff_alpha)
        else:
            st = METHODS[method](st, grad_fn, W, mask, g, compressor,
                                 step=t, key=kk)
        if t % record_every == 0 or t == T - 1:
            record(t)
    return hist


def run_trials(method: str, compressor, task="linreg", trials=5,
               task_kwargs=None, **kw):
    """Mean/std over `trials` independent trials (paper protocol)."""
    curves = []
    extras = {}
    for s in range(trials):
        if task == "linreg":
            grad_fn, loss_fn, theta0, _ = tasks.linreg_task(
                seed=s, **(task_kwargs or {}))
            eval_fns = None
        else:
            grad_fn, loss_fn, theta0, ex = tasks.classification_task(
                seed=s, **(task_kwargs or {}))
            eval_fns = {"test_loss": lambda th: ex["test_metrics"](th)[0],
                        "test_acc": lambda th: ex["test_metrics"](th)[1],
                        "train_acc": lambda th: ex["train_metrics"](th)[1]}
        hist = run_trial(method, compressor, grad_fn, loss_fn, theta0,
                         seed=s, eval_fns=eval_fns, **kw)
        curves.append(hist)
    steps = curves[0]["step"]
    out = {"step": steps}
    for key in curves[0]:
        if key == "step":
            continue
        arr = np.array([c[key] for c in curves])
        out[key] = arr.mean(0).tolist()
        out[key + "_std"] = arr.std(0).tolist()
    return out


def final(curve, key="loss"):
    return curve[key][-1]
