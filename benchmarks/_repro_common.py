"""Shared driver for the paper-reproduction experiments (Sec. V).

Runs the (N, D) simulation engine (repro.core.error_feedback) with the
paper's protocol: uniform random allocation approximating pairwise balance,
Bernoulli stragglers, 5 independent trials, mean +/- std reporting.
"""
from __future__ import annotations

import os
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

import dataclasses

from repro.core import coding, compression as C, error_feedback as EF
from repro.core.plan import PlanSpec
from repro.data import tasks
from repro.sim import IIDBernoulli, StragglerProcess, plan_timer  # noqa: F401
# ^ plan_timer re-exported: benchmarks price StepTimers through the ONE
#   plan -> timer mapping ("the config priced is the config run")


def results_dir() -> Path:
    """Benchmark artifact root: $REPRO_RESULTS_DIR (CI / scratch runs) or
    the in-repo default <repo>/results/repro (gitignored)."""
    env = os.environ.get("REPRO_RESULTS_DIR")
    if env:
        return Path(env)
    return Path(__file__).resolve().parents[1] / "results" / "repro"

def plan_from_args(args=None, base: Optional[PlanSpec] = None,
                   **overrides) -> PlanSpec:
    """THE benchmark-side PlanSpec assembly (shared by fig8-fig12).

    Starts from `base` (a figure's METHODS-table plan, default PlanSpec()),
    folds the shared CLI knobs when present on `args` (--num-buckets,
    --overlap -> bucket_schedule, --backend, --compressor), then any
    explicit keyword overrides.  Every figure routes its knob plumbing
    through here so one PlanSpec object drives the mesh step, the
    StepTimer pricing (`plan_timer`), and the comm-volume accounting."""
    kw = {}
    if args is not None:
        if getattr(args, "num_buckets", None) is not None:
            kw["num_buckets"] = args.num_buckets
        if hasattr(args, "overlap"):
            kw["bucket_schedule"] = ("pipelined" if args.overlap
                                     else "serial")
        if getattr(args, "backend", None):
            kw["backend"] = args.backend
        if getattr(args, "compressor", None):
            kw["compressor"] = args.compressor
    kw.update(overrides)
    base = base if base is not None else PlanSpec()
    return dataclasses.replace(base, **kw) if kw else base


METHODS = {
    "cocoef": EF.cocoef_step,
    "coco": EF.coco_step,
    "unbiased": EF.unbiased_step,
    "unbiased_diff": EF.unbiased_diff_step,
    "uncompressed": None,
}


def run_trial(method: str, compressor, grad_fn, loss_fn, theta0, *,
              N=100, M=100, d=5, p=0.2, gamma=1e-5, T=400, seed=0,
              gamma_fn=None, record_every=20, diff_alpha=0.2,
              eval_fns: Optional[Dict[str, Callable]] = None,
              straggler: Optional[StragglerProcess] = None,
              rate_aware: bool = False,
              allocation: Optional[coding.Allocation] = None):
    """`straggler` (repro.sim.StragglerProcess) drives the per-step masks;
    None keeps the paper's iid Bernoulli(p) — bit-for-bit the legacy
    `coding.straggler_mask` sequence for the same seed.

    `rate_aware=True` builds the encode weights from the straggler
    process's per-rank rates q_i (unbiased under non-iid participation)
    instead of the scalar mean rate p (eq. 3; identical for uniform rates).
    `allocation` overrides the paper's uniform random allocation (e.g.
    `coding.rate_aware_allocation` for heterogeneity-aware redundancy)."""
    alloc = allocation if allocation is not None else \
        coding.random_allocation(seed, N, M, d)
    if alloc.num_devices != N or alloc.num_subsets != M:
        raise ValueError(f"allocation is {alloc.S.shape}, trial wants "
                         f"(N={N}, M={M})")
    if straggler is None:
        straggler = IIDBernoulli(num_devices=N, p=p)
    elif straggler.num_devices != N:
        raise ValueError(f"straggler process has {straggler.num_devices} "
                         f"devices, trial has N={N}")
    W = (coding.encode_weights(alloc, rates=np.asarray(straggler.rates()))
         if rate_aware else coding.encode_weights(alloc, p))
    mask_key = jax.random.PRNGKey(1000 + seed)
    comp_key = jax.random.PRNGKey(2000 + seed)
    needs_key = compressor is not None and compressor.unbiased

    if method == "unbiased_diff":
        st = EF.DiffState.init(theta0, N)
    else:
        st = EF.EFState.init(theta0, N)

    hist = {"step": [], "loss": []}
    if eval_fns:
        for k in eval_fns:
            hist[k] = []

    def record(t):
        hist["step"].append(t)
        hist["loss"].append(float(loss_fn(st.theta)))
        if eval_fns:
            for k, fn in eval_fns.items():
                hist[k].append(float(np.asarray(fn(st.theta))))

    for t in range(T):
        mask = straggler.mask(mask_key, t)
        g = float(gamma_fn(t)) if gamma_fn else gamma
        kk = jax.random.fold_in(comp_key, t) if needs_key else None
        if method == "uncompressed":
            st = EF.uncompressed_step(st, grad_fn, W, mask, g, step=t)
        elif method == "unbiased_diff":
            st = EF.unbiased_diff_step(st, grad_fn, W, mask, g, compressor,
                                       step=t, key=kk, alpha=diff_alpha)
        else:
            st = METHODS[method](st, grad_fn, W, mask, g, compressor,
                                 step=t, key=kk)
        if t % record_every == 0 or t == T - 1:
            record(t)
    return hist


def run_trials(method: str, compressor, task="linreg", trials=5,
               task_kwargs=None, **kw):
    """Mean/std over `trials` independent trials (paper protocol)."""
    curves = []
    extras = {}
    for s in range(trials):
        if task == "linreg":
            grad_fn, loss_fn, theta0, _ = tasks.linreg_task(
                seed=s, **(task_kwargs or {}))
            eval_fns = None
        else:
            grad_fn, loss_fn, theta0, ex = tasks.classification_task(
                seed=s, **(task_kwargs or {}))
            eval_fns = {"test_loss": lambda th: ex["test_metrics"](th)[0],
                        "test_acc": lambda th: ex["test_metrics"](th)[1],
                        "train_acc": lambda th: ex["train_metrics"](th)[1]}
        hist = run_trial(method, compressor, grad_fn, loss_fn, theta0,
                         seed=s, eval_fns=eval_fns, **kw)
        curves.append(hist)
    # route through the ONE trial-averaging convention (summarize_trials,
    # shared with the fig8/fig9 time-axis sweeps): every recorded column
    # gets a mean + a _std companion, exactly the legacy JSON keys
    keys = tuple(k for k in curves[0] if k != "step")
    return summarize_trials(curves, keys=keys, std_keys=keys)


def final(curve, key="loss"):
    return curve[key][-1]


def summarize_trials(per_trial,
                     keys=("loss", "time_s", "bytes_up_cum",
                           "bytes_down_cum"),
                     std_keys=("loss",)):
    """THE trial-averaging convention: mean the per-trial histories into one
    curve dict; every key in `std_keys` also gets a `<key>_std` column
    (right after its mean, preserving the historical JSON key order).
    Shared by `run_trials` (fig2-fig7) and the time-axis sweeps
    (fig8 / fig9 / fig10) so the averaging cannot drift between figures."""
    curve = {"step": per_trial[0]["step"]}
    for key in keys:
        arr = np.array([c[key] for c in per_trial])
        curve[key] = arr.mean(0).tolist()
        if key in std_keys:
            curve[key + "_std"] = arr.std(0).tolist()
    return curve


def target_and_t2t(curves, margin=1.05):
    """The shared target-loss convention: `margin` above the
    slowest-converging method's final mean loss (reachable by every
    curve), plus each method's time-to-target."""
    from repro.sim import time_to_target
    target = margin * max(c["loss"][-1] for c in curves.values())
    return target, {m: time_to_target(c["time_s"], c["loss"], target)
                    for m, c in curves.items()}


def drop_target_and_t2t(curves, frac=0.8):
    """Relative-drop target for slow-moving (LM) losses, fig10's
    convention: the level `frac` of the way down from the shared initial
    recorded loss to the worst method's best-achieved loss.  Unlike the
    fig8 margin convention (built for toy losses that fall orders of
    magnitude), this sits BELOW every curve's starting point yet is
    reachable by every curve, so time-to-target is non-degenerate even
    when a smoke run only shaves a few percent off the loss."""
    from repro.sim import time_to_target
    loss0 = max(c["loss"][0] for c in curves.values())
    floor = max(min(c["loss"]) for c in curves.values())
    target = loss0 - frac * (loss0 - floor)
    return target, {m: time_to_target(c["time_s"], c["loss"], target)
                    for m, c in curves.items()}


def compute_range_ms(by_strag) -> tuple:
    """(min, max) grad_s in ms over one arch's {straggler: {wire: cell}}
    record of fig10.json — the honest per-model compute summary (each
    cell's compiled step differs slightly by wire kernels and
    mask-provider flops).  Lives here, not in fig10_model_zoo, so the
    artifact consumers (run.py, emit_tables) never import the sweep
    module and its XLA_FLAGS / launch-stack side effects."""
    vals = [c["grad_s"] * 1e3
            for by_wire in by_strag.values() for c in by_wire.values()]
    return min(vals), max(vals)


def fmt_ms_range(lo: float, hi: float) -> str:
    """One convention for printing a compute range: collapsed when flat."""
    return f"{lo:.3f}ms" if lo == hi else f"{lo:.3f}-{hi:.3f}ms"


def hetero_spread(p: float, spread: float) -> float:
    """Largest spread <= `spread` keeping every p_i = p*(1 +/- s) inside
    [0, 1) — the registry now validates the profile instead of silently
    clipping it, so sweeps over p must shrink the spread at the edges."""
    if p <= 0.0:
        return min(spread, 1.0)
    return min(spread, 1.0, 0.99 * (1.0 - p) / p)


def markov_burst(p: float, mean_burst: float) -> float:
    """Smallest feasible mean burst >= `mean_burst` for stationary straggle
    probability p: the two-state chain needs its entry rate
    r = p*q/(1-p) <= 1-q (q = 1/mean_burst), i.e. mean_burst >= 1/(1-p) —
    sweeps over p must lengthen the burst at the high end."""
    return max(mean_burst, 1.0 / (1.0 - p) + 1e-9)


def run_metadata(**knobs) -> dict:
    """Provenance stamp embedded in every benchmark results JSON (and the
    telemetry JSONL's run_meta record): git sha, jax version, backend,
    device count — plus whatever run knobs the caller passes (seed,
    straggler process, backend requested/ran, config overrides).  A
    results file then identifies the exact code + environment that
    produced it without consulting the shell history."""
    import platform
    import subprocess
    import sys

    sha = None
    try:
        r = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=Path(__file__).resolve().parents[1],
            capture_output=True, text=True, timeout=10)
        if r.returncode == 0:
            sha = r.stdout.strip()
    except Exception:
        pass
    meta = {
        "git_sha": sha,
        "jax_version": jax.__version__,
        "python": sys.version.split()[0],
        "platform": platform.platform(),
        "jax_backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
    }
    meta.update(knobs)
    return meta
