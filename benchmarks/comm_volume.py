"""Communication-volume tables: per-iteration bits/bytes per method.

Table 1 — the paper's D=100 linreg accounting (Sec. V):
  Equal-overhead pairs used throughout:
    COCO-EF(Sign)  == Unbiased(Sign)   (1 bit/coord + scales)
    COCO-EF(TopK)  == Unbiased(RandK)  (K values + K indices)
  vs the uncompressed SGC baseline (32 bits/coord).

Table 2 — phase-1 wire bytes/step/rank at production model scale, straight
from the WireFormat layer that the coded collective actually transmits
(`repro.core.collectives`): sign vs block top-K vs dense.
"""
from repro.core import compression as C
from repro.core.collectives import DenseWire, SignWire, SparseWire

D = 100          # paper's linreg dimensionality
N_MODEL = 1 << 22  # 4M-coord flat gradient slice (production scale)


def run():
    rows = []
    for name, comp in [
        ("sign (biased/unbiased)", C.GroupedSign()),
        ("topk-2 / randk-2", C.TopK(k=2)),
        ("uncompressed", C.Identity()),
    ]:
        bits = comp.wire_bits(D)
        rows.append((name, bits, 32 * D / bits))
    return rows


WIRE_TABLE = [
    ("sign g=512", SignWire(group_size=512)),
    ("topk 8/512 f32", SparseWire(k_per_block=8, block_size=512)),
    ("topk 8/512 bf16", SparseWire(k_per_block=8, block_size=512,
                                   value_dtype="bfloat16")),
    ("topk 32/512 f32", SparseWire(k_per_block=32, block_size=512)),
    ("dense bf16", DenseWire(value_dtype="bfloat16")),
    ("dense f32", DenseWire()),
]


def run_wires(n: int = N_MODEL):
    """(name, bytes/step/rank, compression vs dense f32) per wire format."""
    dense = DenseWire().wire_bytes(n)
    return [(name, w.wire_bytes(n), dense / w.wire_bytes(n))
            for name, w in WIRE_TABLE]


def audit_wire_bytes(n: int = 4096):
    """Single-source-of-truth audit: for every wire in the table,
    `WireFormat.wire_bytes(n)` (what this table prints) must equal (a) the
    actual byte count of the packed payload the coded collective transmits
    and (b) the uplink accounting the sim cost model charges
    (`repro.sim.StepTimer.bytes_up`).  A per-rank-budget sparse wire is
    audited rank by rank: `rank_wire_bytes` must equal the packed payload
    of the scalar wire each rank semantically transmits (`for_rank`) AND
    the cost model's per-rank charge (`StepTimer.bytes_up_ranks`).
    Raises on any drift."""
    import jax.numpy as jnp

    from repro.sim import StepTimer

    drift = []
    for name, wire in WIRE_TABLE:
        payload = wire.pack(jnp.zeros((n,), jnp.float32))
        actual = sum(int(p.size) * p.dtype.itemsize for p in payload)
        declared = int(wire.wire_bytes(n))
        timer = StepTimer(wire=wire, n=n).bytes_up()
        if not declared == actual == timer:
            drift.append((name, declared, actual, timer))

    budgets = (2, 4, 8, 16)
    pr_name = f"topk per-rank {budgets}/512"
    pr_wire = SparseWire(k_per_block=budgets, block_size=512)
    declared_r = pr_wire.rank_wire_bytes(n, len(budgets))
    model_r = StepTimer(wire=pr_wire, n=n).bytes_up_ranks(len(budgets))
    for i in range(len(budgets)):
        payload = pr_wire.for_rank(i).pack(jnp.zeros((n,), jnp.float32))
        actual = sum(int(p.size) * p.dtype.itemsize for p in payload)
        if not int(declared_r[i]) == actual == int(model_r[i]):
            drift.append((f"{pr_name}[rank {i}]", int(declared_r[i]),
                          actual, int(model_r[i])))
    if drift:
        raise AssertionError(
            f"wire_bytes drift (declared, packed, cost-model): {drift}")
    return [name for name, _ in WIRE_TABLE] + [pr_name]


if __name__ == "__main__":
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write the tables (+ run_metadata provenance) "
                         "as JSON to this path")
    args = ap.parse_args()

    print(f"-- paper accounting (D={D}) --")
    paper_rows = run()
    for name, bits, ratio in paper_rows:
        print(f"{name:24s} bits/iter/device={bits:6d}  compression x{ratio:.1f}")
    print(f"\n-- wire formats on the coded collective (n={N_MODEL}) --")
    wire_rows = run_wires()
    for name, nbytes, ratio in wire_rows:
        print(f"{name:18s} bytes/step/rank={nbytes:10d}  vs dense f32 "
              f"x{ratio:5.1f}")
    audited = audit_wire_bytes()
    print(f"\nwire_bytes audit OK: declared == packed-payload == cost-model "
          f"for {len(audited)} wires")
    if args.json:
        try:
            from . import _repro_common as R
        except ImportError:
            import _repro_common as R
        artifact = {
            "meta": R.run_metadata(D=D, n_model=N_MODEL),
            "paper": [{"name": n, "bits_per_iter": int(b),
                       "compression": float(r)} for n, b, r in paper_rows],
            "wires": [{"name": n, "bytes_per_step_rank": int(b),
                       "vs_dense_f32": float(r)} for n, b, r in wire_rows],
            "audited": audited,
        }
        with open(args.json, "w") as f:
            json.dump(artifact, f, indent=1)
        print(f"wrote {args.json}")
