"""Communication-volume table: bits per device per iteration, per method.

Equal-overhead pairs used throughout Sec. V:
  COCO-EF(Sign)  == Unbiased(Sign)   (1 bit/coord + scales)
  COCO-EF(TopK)  == Unbiased(RandK)  (K values + K indices)
vs the uncompressed SGC baseline (32 bits/coord).
"""
from repro.core import compression as C

D = 100  # paper's linreg dimensionality


def run():
    rows = []
    for name, comp in [
        ("sign (biased/unbiased)", C.GroupedSign()),
        ("topk-2 / randk-2", C.TopK(k=2)),
        ("uncompressed", C.Identity()),
    ]:
        bits = comp.wire_bits(D)
        rows.append((name, bits, 32 * D / bits))
    return rows


if __name__ == "__main__":
    for name, bits, ratio in run():
        print(f"{name:24s} bits/iter/device={bits:6d}  compression x{ratio:.1f}")
