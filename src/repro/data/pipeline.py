"""Deterministic, shardable synthetic data pipeline for the LM architectures.

Production shape: an infinite stream of (tokens, targets, loss_weight)
batches, derived from a counter-based PRNG so that
  * any (step, dp_rank) pair regenerates its shard without coordination
    (restart/elasticity: the "data cursor" is just the step counter),
  * the gradient-coding subset structure is explicit: the global batch of a
    step is partitioned into M subsets; subset k is materialized on every DP
    rank that holds it (redundant computation, Sec. III of the paper).

The synthetic token distribution is a mixture of Zipfian unigrams with a
deterministic per-position Markov perturbation — enough structure that the
loss decreases during smoke training, with zero I/O.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SyntheticLMConfig", "synthetic_lm_batch", "subset_batch_for_rank",
           "coded_train_batch", "coded_batch_stream", "prefetch_to_device",
           "host_stream"]


@dataclasses.dataclass(frozen=True)
class SyntheticLMConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    num_subsets: int = 0          # 0 => one subset per DP rank (plain DP)
    seed: int = 0

    def subsets(self, num_dp_ranks: int) -> int:
        return self.num_subsets or num_dp_ranks


def synthetic_lm_batch(key: jax.Array, step: int, batch: int, seq_len: int,
                       vocab: int) -> jnp.ndarray:
    """(batch, seq_len+1) int32 tokens, deterministic in (key, step)."""
    k = jax.random.fold_in(key, jnp.asarray(step, jnp.uint32))
    # Zipf-ish unigram sampling via inverse-CDF on exponential ranks
    u = jax.random.uniform(k, (batch, seq_len + 1), minval=1e-6, maxval=1.0)
    ranks = jnp.floor(jnp.exp(u * jnp.log(float(vocab)))) - 1.0
    toks = jnp.clip(ranks.astype(jnp.int32), 0, vocab - 1)
    # Markov perturbation: with prob .25 copy previous token (adds structure)
    k2 = jax.random.fold_in(k, 1)
    copy = jax.random.uniform(k2, toks.shape) < 0.25
    toks = jnp.where(copy, jnp.roll(toks, 1, axis=-1), toks)
    return toks


def subset_batch_for_rank(key: jax.Array, step, subset_ids: np.ndarray,
                          subset_weights: np.ndarray, per_subset: int,
                          seq_len: int, vocab: int
                          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Materialize the union of a rank's subsets for one step.

    subset_ids: (n_local,) static subset indices held by this rank (from the
    allocation matrix S); subset_weights: 1/(d_k (1-p)) per local subset.
    Returns (tokens (B, L+1), targets implicit, per-example weight (B,)).
    The per-example weights implement the coded sum  sum_k w_k grad f_k  as a
    single weighted backward pass (DESIGN.md Sec. 2).
    """
    batches, weights = [], []
    for sid, w in zip(subset_ids.tolist(), subset_weights.tolist()):
        sk = jax.random.fold_in(key, np.uint32(sid))
        toks = synthetic_lm_batch(sk, step, per_subset, seq_len, vocab)
        batches.append(toks)
        weights.append(jnp.full((per_subset,), w, jnp.float32))
    return jnp.concatenate(batches, 0), jnp.concatenate(weights, 0)


def coded_train_batch(key: jax.Array, step, allocation, W, per_subset: int,
                      seq_len: int, vocab: int
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One GLOBAL coded batch for the mesh train step, straight from the
    synthetic pipeline: (tokens (N_code, b_loc, L+1) i32,
    weights (N_code, b_loc) f32).

    Rank i's rows are the union of its allocated subsets
    (`subset_batch_for_rank`); subset k's tokens are keyed by the subset id
    alone, so every rank holding k regenerates the IDENTICAL rows without
    coordination (the redundant computation of Sec. III), and the
    per-example weight folds the encode weight W[i, k] / per_subset so
    stage 1's weighted backward pass IS the coded sum of eq. 3.  Feed the
    SAME W the trainer aggregates with (rate-aware or mean-rate)."""
    Wn = np.asarray(W)
    toks, wts = [], []
    for i in range(allocation.num_devices):
        sids = allocation.subsets_of(i)
        t, w = subset_batch_for_rank(key, step, sids,
                                     Wn[i, sids] / per_subset,
                                     per_subset, seq_len, vocab)
        toks.append(t)
        wts.append(w)
    return jnp.stack(toks), jnp.stack(wts)


def coded_batch_stream(key: jax.Array, allocation, W, per_subset: int,
                       seq_len: int, vocab: int, start_step: int = 0
                       ) -> Iterator[Tuple[jnp.ndarray, jnp.ndarray]]:
    """Infinite iterator of `coded_train_batch(key, t, ...)` for
    t = start_step, start_step+1, ... — the generator half of the
    prefetched train loop (`prefetch_to_device`).  Deterministic in
    (key, step), so prefetching cannot change what any step trains on."""
    step = start_step
    while True:
        yield coded_train_batch(key, step, allocation, W, per_subset,
                                seq_len, vocab)
        step += 1


def prefetch_to_device(it: Iterator, size: int = 2,
                       shardings=None) -> Iterator:
    """Host -> device prefetcher: a background thread pulls from `it`,
    `jax.device_put`s each item (against `shardings` when given), and
    parks up to `size` device-resident items in a bounded queue.

    With size=2 (double buffer) the host is generating + transferring step
    t+1's coded batch while the mesh executes step t, hiding the
    host-side batch construction behind device compute — the step-ahead
    pipeline of ROADMAP open item 3.  Ordering is preserved exactly and
    items are never dropped, so consuming this iterator is
    indistinguishable from mapping device_put over `it`.

    The worker thread is a daemon and also honors a stop event set when
    the consumer abandons the iterator (generator close/GC), so partial
    consumption cannot leak a blocked thread; closing the iterator also
    JOINS the worker (a daemon still inside jax.device_put at interpreter
    exit aborts from XLA's C++ teardown).  Exceptions raised by `it` or
    by the transfer re-raise at the consumer's next pull.

    CAVEAT (XLA:CPU fake devices): the worker issues jax client calls
    (device_put, and any jax ops inside `it`) concurrently with whatever
    the consumer thread executes.  On the CPU backend's in-process
    collectives this can race the all-participant rendezvous of a mesh
    step and stall it (observed as `collective_ops_utils` "may be stuck"
    spam), so the train loop keeps prefetch OPT-IN (TrainRun.prefetch=0)
    until an accelerator backend lands; single-device streams (no
    collectives) are unaffected."""
    if size < 1:
        raise ValueError("prefetch size must be >= 1")
    q: "queue.Queue" = queue.Queue(maxsize=size)
    stop = threading.Event()
    sentinel = object()
    err: list = []

    def worker():
        try:
            for item in it:
                item = (jax.device_put(item, shardings)
                        if shardings is not None else jax.device_put(item))
                while not stop.is_set():
                    try:
                        q.put(item, timeout=0.1)
                        break
                    except queue.Full:
                        continue
                if stop.is_set():
                    return
        except BaseException as exc:   # re-raised on the consumer side
            err.append(exc)
        finally:
            while not stop.is_set():
                try:
                    q.put(sentinel, timeout=0.1)
                    break
                except queue.Full:
                    continue

    th = threading.Thread(target=worker, daemon=True,
                          name="repro-prefetch")
    th.start()
    try:
        while True:
            item = q.get()
            if item is sentinel:
                if err:
                    raise err[0]
                return
            yield item
    finally:
        stop.set()
        # unblock a worker stuck on q.put, then wait for it to wind down:
        # a daemon thread still inside jax.device_put at interpreter exit
        # aborts the process from XLA's C++ teardown
        while True:
            try:
                q.get_nowait()
            except queue.Empty:
                break
        th.join(timeout=5.0)


def host_stream(cfg: SyntheticLMConfig, start_step: int = 0
                ) -> Iterator[jnp.ndarray]:
    """Host-side infinite stream of global batches (single-host testing)."""
    key = jax.random.PRNGKey(cfg.seed)
    step = start_step
    while True:
        yield synthetic_lm_batch(key, step, cfg.global_batch, cfg.seq_len,
                                 cfg.vocab_size)
        step += 1
