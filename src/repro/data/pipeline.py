"""Deterministic, shardable synthetic data pipeline for the LM architectures.

Production shape: an infinite stream of (tokens, targets, loss_weight)
batches, derived from a counter-based PRNG so that
  * any (step, dp_rank) pair regenerates its shard without coordination
    (restart/elasticity: the "data cursor" is just the step counter),
  * the gradient-coding subset structure is explicit: the global batch of a
    step is partitioned into M subsets; subset k is materialized on every DP
    rank that holds it (redundant computation, Sec. III of the paper).

The synthetic token distribution is a mixture of Zipfian unigrams with a
deterministic per-position Markov perturbation — enough structure that the
loss decreases during smoke training, with zero I/O.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SyntheticLMConfig", "synthetic_lm_batch", "subset_batch_for_rank",
           "coded_train_batch", "elastic_train_batch", "coded_batch_stream",
           "prefetch_to_device", "PrefetchStats", "host_stream"]


@dataclasses.dataclass(frozen=True)
class SyntheticLMConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    num_subsets: int = 0          # 0 => one subset per DP rank (plain DP)
    seed: int = 0

    def subsets(self, num_dp_ranks: int) -> int:
        return self.num_subsets or num_dp_ranks


def synthetic_lm_batch(key: jax.Array, step: int, batch: int, seq_len: int,
                       vocab: int) -> jnp.ndarray:
    """(batch, seq_len+1) int32 tokens, deterministic in (key, step)."""
    k = jax.random.fold_in(key, jnp.asarray(step, jnp.uint32))
    # Zipf-ish unigram sampling via inverse-CDF on exponential ranks
    u = jax.random.uniform(k, (batch, seq_len + 1), minval=1e-6, maxval=1.0)
    ranks = jnp.floor(jnp.exp(u * jnp.log(float(vocab)))) - 1.0
    toks = jnp.clip(ranks.astype(jnp.int32), 0, vocab - 1)
    # Markov perturbation: with prob .25 copy previous token (adds structure)
    k2 = jax.random.fold_in(k, 1)
    copy = jax.random.uniform(k2, toks.shape) < 0.25
    toks = jnp.where(copy, jnp.roll(toks, 1, axis=-1), toks)
    return toks


def subset_batch_for_rank(key: jax.Array, step, subset_ids: np.ndarray,
                          subset_weights: np.ndarray, per_subset: int,
                          seq_len: int, vocab: int
                          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Materialize the union of a rank's subsets for one step.

    subset_ids: (n_local,) static subset indices held by this rank (from the
    allocation matrix S); subset_weights: 1/(d_k (1-p)) per local subset.
    Returns (tokens (B, L+1), targets implicit, per-example weight (B,)).
    The per-example weights implement the coded sum  sum_k w_k grad f_k  as a
    single weighted backward pass (DESIGN.md Sec. 2).
    """
    batches, weights = [], []
    for sid, w in zip(subset_ids.tolist(), subset_weights.tolist()):
        sk = jax.random.fold_in(key, np.uint32(sid))
        toks = synthetic_lm_batch(sk, step, per_subset, seq_len, vocab)
        batches.append(toks)
        weights.append(jnp.full((per_subset,), w, jnp.float32))
    return jnp.concatenate(batches, 0), jnp.concatenate(weights, 0)


def coded_train_batch(key: jax.Array, step, allocation, W, per_subset: int,
                      seq_len: int, vocab: int
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One GLOBAL coded batch for the mesh train step, straight from the
    synthetic pipeline: (tokens (N_code, b_loc, L+1) i32,
    weights (N_code, b_loc) f32).

    Rank i's rows are the union of its allocated subsets
    (`subset_batch_for_rank`); subset k's tokens are keyed by the subset id
    alone, so every rank holding k regenerates the IDENTICAL rows without
    coordination (the redundant computation of Sec. III), and the
    per-example weight folds the encode weight W[i, k] / per_subset so
    stage 1's weighted backward pass IS the coded sum of eq. 3.  Feed the
    SAME W the trainer aggregates with (rate-aware or mean-rate)."""
    Wn = np.asarray(W)
    toks, wts = [], []
    for i in range(allocation.num_devices):
        sids = allocation.subsets_of(i)
        t, w = subset_batch_for_rank(key, step, sids,
                                     Wn[i, sids] / per_subset,
                                     per_subset, seq_len, vocab)
        toks.append(t)
        wts.append(w)
    return jnp.stack(toks), jnp.stack(wts)


def elastic_train_batch(key: jax.Array, step, allocation, per_subset: int,
                        seq_len: int, vocab: int
                        ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """`coded_train_batch` with the encode weights left OUT of the batch:
    (tokens (N_code, b_loc, L+1) i32, weights (N_code, b_loc) f32 = 1,
    subset_ids (N_code, b_loc) i32).

    The dynamic coding plane folds W in-graph instead:
    `take_along_axis(W / per_subset, subset_ids, 1)`, with the division
    applied HOST-side by `launch.train.elastic_coding_state` — the
    identical IEEE f32 division the static path does here, so with the
    same W the two paths produce bit-for-bit equal per-example weights
    while W stays free to change every step without a retrace.  Tokens
    are generated subset-by-subset exactly as `coded_train_batch` does,
    so the examples themselves are bit-identical too.

    Requires a uniform per-rank subset count (the stacked shape must be
    rectangular AND stable across re-allocations):
    `rate_aware_allocation(..., exact_load=True)` or `cyclic_allocation`
    with N | d*M guarantee it.
    """
    counts = np.asarray(allocation.S).sum(axis=1)
    if np.any(counts != counts[0]):
        raise ValueError(
            f"elastic batches need a uniform per-rank subset count, got "
            f"loads {counts.tolist()} — use rate_aware_allocation("
            f"exact_load=True)")
    toks, sids_out = [], []
    for i in range(allocation.num_devices):
        sids = allocation.subsets_of(i)
        rows = []
        for sid in sids.tolist():
            sk = jax.random.fold_in(key, np.uint32(sid))
            rows.append(synthetic_lm_batch(sk, step, per_subset, seq_len,
                                           vocab))
        toks.append(jnp.concatenate(rows, 0))
        sids_out.append(np.repeat(sids.astype(np.int32), per_subset))
    b_loc = int(counts[0]) * per_subset
    weights = jnp.ones((allocation.num_devices, b_loc), jnp.float32)
    return jnp.stack(toks), weights, jnp.asarray(np.stack(sids_out))


def coded_batch_stream(key: jax.Array, allocation, W, per_subset: int,
                       seq_len: int, vocab: int, start_step: int = 0
                       ) -> Iterator[Tuple[jnp.ndarray, jnp.ndarray]]:
    """Infinite iterator of `coded_train_batch(key, t, ...)` for
    t = start_step, start_step+1, ... — the generator half of the
    prefetched train loop (`prefetch_to_device`).  Deterministic in
    (key, step), so prefetching cannot change what any step trains on."""
    step = start_step
    while True:
        yield coded_train_batch(key, step, allocation, W, per_subset,
                                seq_len, vocab)
        step += 1


@dataclasses.dataclass
class PrefetchStats:
    """Host-side counters for one `prefetch_to_device` stream.

    Single-writer per field (the worker owns producer-side counters, the
    consumer thread the rest), so reads are safe snapshots without a lock:

      put_count        batches staged (device_put done, parked in queue)
      get_count        batches the consumer pulled
      producer_wait_s  worker time blocked on a FULL queue (consumer is
                       the bottleneck — prefetch is doing its job)
      consumer_wait_s  consumer time blocked on an EMPTY queue (host batch
                       construction is on the critical path — the stall
                       prefetch exists to remove; ~0 once warmed up)
      device_put_s     worker time inside the host->device transfer
      max_depth        high-water queue occupancy (<= size)
      depth_sum        sum of occupancies seen at each get (mean depth =
                       depth_sum / get_count)
    """

    size: int = 0
    put_count: int = 0
    get_count: int = 0
    producer_wait_s: float = 0.0
    consumer_wait_s: float = 0.0
    device_put_s: float = 0.0
    max_depth: int = 0
    depth_sum: int = 0

    def snapshot(self) -> dict:
        """Plain-dict copy (the `prefetch` JSONL record's `stats` body)."""
        return dataclasses.asdict(self)


class _DevicePrefetch:
    """Iterator form of `prefetch_to_device` exposing `.stats`.

    Matches the previous generator's observable behavior exactly: same
    order/values as mapping device_put over the source, exceptions
    re-raised at the consumer's next pull, `.close()` (and exhaustion)
    stops + JOINS the worker."""

    def __init__(self, it: Iterator, size: int, shardings):
        if size < 1:
            raise ValueError("prefetch size must be >= 1")
        self.stats = PrefetchStats(size=size)
        self._q: "queue.Queue" = queue.Queue(maxsize=size)
        self._stop = threading.Event()
        self._sentinel = object()
        self._err: list = []
        self._done = False
        self._it = it
        self._shardings = shardings
        self._th = threading.Thread(target=self._worker, daemon=True,
                                    name="repro-prefetch")
        self._th.start()

    def _worker(self):
        q, stop, stats = self._q, self._stop, self.stats
        try:
            for item in self._it:
                t0 = time.perf_counter()
                item = (jax.device_put(item, self._shardings)
                        if self._shardings is not None
                        else jax.device_put(item))
                stats.device_put_s += time.perf_counter() - t0
                t0 = time.perf_counter()
                while not stop.is_set():
                    try:
                        q.put(item, timeout=0.1)
                        stats.put_count += 1
                        break
                    except queue.Full:
                        continue
                stats.producer_wait_s += time.perf_counter() - t0
                if stop.is_set():
                    return
        except BaseException as exc:   # re-raised on the consumer side
            self._err.append(exc)
        finally:
            while not stop.is_set():
                try:
                    q.put(self._sentinel, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> "_DevicePrefetch":
        return self

    def __next__(self):
        if self._done:
            raise StopIteration
        stats = self.stats
        depth = self._q.qsize()
        stats.max_depth = max(stats.max_depth, depth)
        stats.depth_sum += depth
        t0 = time.perf_counter()
        item = self._q.get()
        stats.consumer_wait_s += time.perf_counter() - t0
        if item is self._sentinel:
            self._done = True
            self.close()
            if self._err:
                raise self._err[0]
            raise StopIteration
        stats.get_count += 1
        return item

    def close(self) -> None:
        """Stop + join the worker (idempotent).  Abandoning the stream
        mid-flight must not leak a blocked thread; a daemon still inside
        jax.device_put at interpreter exit aborts from XLA's C++
        teardown, hence the join."""
        self._done = True
        self._stop.set()
        # unblock a worker stuck on q.put, then wait for it to wind down
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._th.join(timeout=5.0)

    def __del__(self):
        try:
            if not self._done:
                self.close()
        except Exception:
            pass


def prefetch_to_device(it: Iterator, size: int = 2,
                       shardings=None) -> _DevicePrefetch:
    """Host -> device prefetcher: a background thread pulls from `it`,
    `jax.device_put`s each item (against `shardings` when given), and
    parks up to `size` device-resident items in a bounded queue.

    With size=2 (double buffer) the host is generating + transferring step
    t+1's coded batch while the mesh executes step t, hiding the
    host-side batch construction behind device compute — the step-ahead
    pipeline of ROADMAP open item 3.  Ordering is preserved exactly and
    items are never dropped, so consuming this iterator is
    indistinguishable from mapping device_put over `it`.

    The returned iterator exposes `.stats` (a `PrefetchStats`) counting
    queue depth and producer/consumer blocked time — `consumer_wait_s`
    rising above ~0 after warmup is the regression signature of the
    worker stall the PR 6 perf pass chased (host batch construction back
    on the step's critical path); `repro.obs.MetricsLogger.log_prefetch`
    takes `.stats.snapshot()` verbatim.

    The worker thread is a daemon and also honors a stop event set when
    the consumer abandons the iterator (`.close()`), so partial
    consumption cannot leak a blocked thread; closing the iterator also
    JOINS the worker (a daemon still inside jax.device_put at interpreter
    exit aborts from XLA's C++ teardown).  Exceptions raised by `it` or
    by the transfer re-raise at the consumer's next pull.

    CAVEAT (XLA:CPU fake devices): the worker issues jax client calls
    (device_put, and any jax ops inside `it`) concurrently with whatever
    the consumer thread executes.  On the CPU backend's in-process
    collectives this can race the all-participant rendezvous of a mesh
    step and stall it (observed as `collective_ops_utils` "may be stuck"
    spam), so the train loop keeps prefetch OPT-IN (TrainRun.prefetch=0)
    until an accelerator backend lands; single-device streams (no
    collectives) are unaffected."""
    return _DevicePrefetch(it, size, shardings)


def host_stream(cfg: SyntheticLMConfig, start_step: int = 0
                ) -> Iterator[jnp.ndarray]:
    """Host-side infinite stream of global batches (single-host testing)."""
    key = jax.random.PRNGKey(cfg.seed)
    step = start_step
    while True:
        yield synthetic_lm_batch(key, step, cfg.global_batch, cfg.seq_len,
                                 cfg.vocab_size)
        step += 1
