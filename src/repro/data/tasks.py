"""The paper's two experimental tasks (Sec. V), built as grad_fn factories.

Both return `(grad_fn, loss_fn, theta0, extras)` where
  grad_fn(theta) -> (M, D) per-subset gradient stack  (feeds eq. 3)
  loss_fn(theta) -> scalar F(theta) = sum_k f_k(theta)

Task A (Sec. V.A): linear regression on synthetic data.
  N = M = 100, z_k ~ N(0, 100) in R^100, y_k ~ N(<z_k, theta_hat>, 1),
  f_k(theta) = 0.5 (<theta, z_k> - y_k)^2.

Task B (Sec. V.B): heterogeneous image classification.  The paper uses MNIST
with label-sharded subsets; MNIST is not available offline, so we generate a
synthetic 10-class image set with the same *heterogeneity protocol* (every
subset holds a single class) and train a small CNN with cross-entropy.  The
claims being validated (biased+EF > unbiased at equal bits; improvement with
d_k) are protocol-level, not dataset-specific.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["linreg_task", "classification_task", "ClassificationModel"]


def linreg_task(seed: int = 0, num_subsets: int = 100, dim: int = 100):
    """Paper Sec. V.A synthetic linear regression."""
    rng = np.random.default_rng(seed)
    Z = rng.normal(0.0, 10.0, size=(num_subsets, dim))  # N(0, var=100)
    theta_hat = rng.normal(0.0, 1.0, size=(dim,))
    y = Z @ theta_hat + rng.normal(0.0, 1.0, size=(num_subsets,))
    theta0 = rng.normal(0.0, 1.0, size=(dim,))

    Zj = jnp.asarray(Z, jnp.float32)
    yj = jnp.asarray(y, jnp.float32)

    def grad_fn(theta: jnp.ndarray) -> jnp.ndarray:
        resid = Zj @ theta - yj                      # (M,)
        return resid[:, None] * Zj                   # (M, D)

    def loss_fn(theta: jnp.ndarray) -> jnp.ndarray:
        resid = Zj @ theta - yj
        return 0.5 * jnp.sum(resid ** 2)

    return grad_fn, loss_fn, jnp.asarray(theta0, jnp.float32), dict(Z=Zj, y=yj)


# --------------------------------------------------------------------------
# Task B: heterogeneous classification with a small CNN
# --------------------------------------------------------------------------

class ClassificationModel(NamedTuple):
    """Tiny CNN: conv(1->8, 3x3) - relu - pool2 - conv(8->16, 3x3) - relu -
    pool2 - dense(10).  Parameters are handled as a flat vector so the coding
    layer (which is per-coordinate) applies unchanged."""

    img: int
    unravel: Callable
    dim: int


def _init_cnn(key, img: int):
    k1, k2, k3 = jax.random.split(key, 3)
    params = {
        "w1": jax.random.normal(k1, (3, 3, 1, 8)) * (2.0 / 9) ** 0.5,
        "b1": jnp.zeros((8,)),
        "w2": jax.random.normal(k2, (3, 3, 8, 16)) * (2.0 / 72) ** 0.5,
        "b2": jnp.zeros((16,)),
        "w3": jax.random.normal(k3, ((img // 4) ** 2 * 16, 10)) * 0.05,
        "b3": jnp.zeros((10,)),
    }
    from jax.flatten_util import ravel_pytree
    flat, unravel = ravel_pytree(params)
    return flat, unravel


def _cnn_logits(params, x):
    """x: (B, img, img, 1) -> (B, 10)."""
    h = jax.lax.conv_general_dilated(
        x, params["w1"], (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + params["b1"]
    h = jax.nn.relu(h)
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    h = jax.lax.conv_general_dilated(
        h, params["w2"], (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + params["b2"]
    h = jax.nn.relu(h)
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    h = h.reshape(h.shape[0], -1)
    return h @ params["w3"] + params["b3"]


def classification_task(seed: int = 0, num_subsets: int = 100,
                        samples_per_subset: int = 16, img: int = 14,
                        test_samples: int = 512):
    """Synthetic heterogeneous 10-class image classification (Sec. V.B
    protocol: every subset single-class => maximal label heterogeneity)."""
    rng = np.random.default_rng(seed)
    # class templates: smooth random blobs
    templates = rng.normal(0, 1, size=(10, img, img))
    # low-pass each template so classes are distinguishable but overlapping
    kern = np.ones((3, 3)) / 9.0
    for c in range(10):
        t = templates[c]
        for _ in range(2):
            t = np.pad(t, 1, mode="edge")
            t = sum(t[i:i + img, j:j + img] * kern[i, j]
                    for i in range(3) for j in range(3))
        templates[c] = t / (np.abs(t).max() + 1e-9)

    subset_class = np.arange(num_subsets) % 10
    rng.shuffle(subset_class)
    noise = 0.6

    def make_split(n_per, classes):
        xs, ys = [], []
        for c in classes:
            x = templates[c][None] + noise * rng.normal(0, 1, (n_per, img, img))
            xs.append(x)
            ys.append(np.full((n_per,), c))
        return (np.concatenate(xs).astype(np.float32),
                np.concatenate(ys).astype(np.int32))

    X = np.stack([templates[c][None] + noise * rng.normal(0, 1, (samples_per_subset, img, img))
                  for c in subset_class])                   # (M, S, img, img)
    Y = np.stack([np.full((samples_per_subset,), c) for c in subset_class])
    Xte, Yte = make_split(test_samples // 10, np.arange(10))

    Xj = jnp.asarray(X[..., None])      # (M, S, img, img, 1)
    Yj = jnp.asarray(Y)
    Xte_j = jnp.asarray(Xte[..., None])
    Yte_j = jnp.asarray(Yte)

    key = jax.random.PRNGKey(seed + 1)
    theta0, unravel = _init_cnn(key, img)
    model = ClassificationModel(img=img, unravel=unravel, dim=theta0.shape[0])

    def subset_loss(theta, x, y):
        logits = _cnn_logits(unravel(theta), x)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

    def grad_fn(theta):
        return jax.vmap(lambda x, y: jax.grad(subset_loss)(theta, x, y))(Xj, Yj)

    def loss_fn(theta):
        return jnp.sum(jax.vmap(lambda x, y: subset_loss(theta, x, y))(Xj, Yj))

    def test_metrics(theta):
        logits = _cnn_logits(unravel(theta), Xte_j)
        logp = jax.nn.log_softmax(logits)
        loss = -jnp.mean(jnp.take_along_axis(logp, Yte_j[:, None], axis=1))
        acc = jnp.mean((jnp.argmax(logits, -1) == Yte_j).astype(jnp.float32))
        return loss, acc

    def train_metrics(theta):
        logits = _cnn_logits(unravel(theta), Xj.reshape(-1, img, img, 1))
        yflat = Yj.reshape(-1)
        logp = jax.nn.log_softmax(logits)
        loss = -jnp.mean(jnp.take_along_axis(logp, yflat[:, None], axis=1))
        acc = jnp.mean((jnp.argmax(logits, -1) == yflat).astype(jnp.float32))
        return loss, acc

    return grad_fn, loss_fn, theta0, dict(model=model, test_metrics=test_metrics,
                                          train_metrics=train_metrics)
