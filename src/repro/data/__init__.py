from . import tasks, pipeline  # noqa: F401
