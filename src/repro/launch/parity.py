"""Reference-vs-production parity gate for Algorithm 1.

The repo carries two implementations of the paper's Algorithm 1:

  * the (N, D) REFERENCE EF loop (`repro.core.error_feedback.cocoef_step`)
    that every paper figure (fig2-fig9) trains through, and
  * the PRODUCTION mesh step (`repro.core.cocoef.cocoef_update` inside the
    fully-manual shard_map of `repro.launch.train`) whose performance the
    kernel/cost-model numbers describe.

Nothing used to tie their dynamics together beyond one-step oracle checks,
so the two could silently diverge and every emitted figure would describe
an algorithm the production system does not run.  This module trains BOTH
on the same linreg task, with the same allocation/encode weights, the same
per-step straggler masks, and the same wire arithmetic — the reference
loop's compressor is `compression.WireCompressor(wire)`, i.e. bit-for-bit
the reconstruction the coded collective's receivers decode — and demands
the theta / error trajectories stay BIT-FOR-BIT identical for the whole
trained run.  Any drift between the two Algorithm-1 implementations is a
test failure (tests/test_algorithm_parity.py) and a benchmark failure
(benchmarks/fig10_model_zoo.py --parity) instead of a wrong figure.

Requires `N * shards` jax devices (set
`XLA_FLAGS=--xla_force_host_platform_device_count=...` before jax
initializes; the tests run this in a subprocess like tests/test_distributed).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import coding, error_feedback as EF
from repro.core.cocoef import CocoEFConfig, cocoef_update
from repro.core.compression import WireCompressor

__all__ = ["PARITY_COMPRESSORS", "run_parity", "assert_parity"]

# the wires the gate covers: sign / block top-K / dense (identity), the
# three wire families of the ISSUE.  Global top-K is excluded by design:
# its per-chunk block layout depends on the all_to_all chunk count, so the
# full-vector reference view and the per-device production view compress
# with different block boundaries (documented approximation).
PARITY_COMPRESSORS = ("sign", "block_topk", "identity")

_GROUP, _BLOCK, _K = 32, 64, 4


def _records(theta: jnp.ndarray, e: np.ndarray) -> Dict[str, np.ndarray]:
    return {"theta": np.asarray(theta).copy(), "e": np.asarray(e).copy()}


def run_parity(compressor: str = "sign", T: int = 20, N: int = 4,
               shards: int = 2, dim: int = 1024, gamma: float = 2e-6,
               p: float = 0.25, d: int = 2, seed: int = 0,
               backend: str = "jnp", num_buckets: int = 1,
               bucket_schedule: str = "pipelined",
               dynamic_state: bool = False) -> Dict:
    """Train the reference EF loop and the mesh `cocoef_update` step on the
    same linreg task / masks / wire for `T` steps and compare trajectories.

    Returns a report dict; `bitexact` is True iff theta AND the error
    vectors match bit-for-bit at EVERY recorded step.

    dynamic_state=True runs a THIRD trajectory through the same mesh step
    with the encode weights coming from a live `core.coding_state`
    CodingPlan pinned to the oracle rates — W is recomputed by
    `maybe_replan` every step and fed as a jit ARGUMENT instead of a
    closure constant.  The elastic coding plane's acceptance criterion is
    that this trajectory is bit-for-bit the static one.
    """
    if compressor not in PARITY_COMPRESSORS:
        raise ValueError(f"parity covers {PARITY_COMPRESSORS}, "
                         f"got {compressor!r}")
    from repro.data import tasks   # lazy: keeps launch import-light

    n_loc = dim // shards
    ccfg = CocoEFConfig(coding_axes=("data",), group_size=_GROUP,
                        compressor=compressor, block_size=_BLOCK,
                        k_per_block=_K, backend=backend,
                        num_buckets=num_buckets,
                        bucket_schedule=bucket_schedule)
    wire = ccfg.wire_format(n_loc, N)
    wire.check(n_loc, N)               # dim must need no padding: the
    #   reference loop compresses the raw (dim,) vector, so any pad would
    #   change the group/block partition between the two sides
    comp = WireCompressor(wire=wire)

    grad_fn, loss_fn, theta0, _ = tasks.linreg_task(
        seed=seed, num_subsets=N, dim=dim)
    alloc = coding.cyclic_allocation(N, N, d)
    W = coding.encode_weights(alloc, p)

    mask_key = jax.random.PRNGKey(1000 + seed)
    masks = [coding.straggler_mask(mask_key, t, N, p) for t in range(T)]

    # ---- reference: the (N, D) EF loop of figs. 2-9 -----------------------
    st = EF.EFState.init(theta0, N)
    ref: List[Dict[str, np.ndarray]] = []
    for t in range(T):
        st = EF.cocoef_step(st, grad_fn, W, masks[t], gamma, comp, step=t)
        ref.append(_records(st.theta, st.e))

    # ---- production: cocoef_update inside shard_map on a (N, shards) mesh -
    mesh = compat.make_mesh((N, shards), ("data", "model"))

    def agg(gg, ee, mm):
        return cocoef_update(gg, ee, mm, gamma, ccfg)

    step_fn = jax.jit(compat.shard_map(
        agg, mesh,
        in_specs=(P(("data", "model")), P(("data", "model")), P()),
        out_specs=(P("model"), P(("data", "model"))),
        axis_names={"data", "model"}, check=False))
    coded = jax.jit(lambda th: W @ grad_fn(th))      # (N, dim), same eq. 3

    theta = np.asarray(theta0)
    e_flat = np.zeros((N * dim,), np.float32)
    mesh_rec: List[Dict[str, np.ndarray]] = []
    for t in range(T):
        # theta/e stay host-side between steps: feeding the sharded step
        # outputs back into `coded` would GSPMD-partition the stage-1
        # matmul and change its reduction order (not what the production
        # loop does either — stage 1 recomputes from replicated params)
        g = coded(jnp.asarray(theta))
        ghat, e_out = step_fn(g.reshape(-1), jnp.asarray(e_flat), masks[t])
        theta = theta - np.asarray(ghat)
        e_flat = np.asarray(e_out)
        mesh_rec.append(_records(theta, e_flat.reshape(N, dim)))

    # ---- dynamic CodingState: W from maybe_replan, as a jit argument ------
    dyn_rec: List[Dict[str, np.ndarray]] = []
    if dynamic_state:
        from repro.core.coding_state import CodingPlan, maybe_replan
        # oracle rates of the iid Bernoulli process: uniform 1-p, which
        # hits encode_weights' eq.-3 branch -> W identical to the static
        # encode_weights(alloc, p) above, every step, bit-for-bit
        oracle = np.full((N,), 1.0 - p)
        plan = CodingPlan.create(oracle, N, d, allocation=alloc)
        coded_dyn = jax.jit(lambda th, Wt: Wt @ grad_fn(th))
        theta = np.asarray(theta0)
        e_flat = np.zeros((N * dim,), np.float32)
        for t in range(T):
            cs, info = maybe_replan(plan, oracle)
            assert not info["reallocated"], "pinned rates must never drift"
            g = coded_dyn(jnp.asarray(theta), cs.W)
            ghat, e_out = step_fn(g.reshape(-1), jnp.asarray(e_flat),
                                  masks[t])
            theta = theta - np.asarray(ghat)
            e_flat = np.asarray(e_out)
            dyn_rec.append(_records(theta, e_flat.reshape(N, dim)))

    # ---- compare ----------------------------------------------------------
    first_div: Optional[Dict] = None
    max_dtheta = max_de = 0.0
    sides = [("mesh", mesh_rec)] + ([("dynamic", dyn_rec)]
                                    if dynamic_state else [])
    for t in range(T):
        for side, rec in sides:
            for field in ("theta", "e"):
                a, b = ref[t][field], rec[t][field]
                if not np.array_equal(a, b):
                    diff = float(np.max(np.abs(a - b)))
                    if field == "theta":
                        max_dtheta = max(max_dtheta, diff)
                    else:
                        max_de = max(max_de, diff)
                    if first_div is None:
                        first_div = {"step": t, "field": field,
                                     "side": side, "max_abs_diff": diff}
    return {
        "compressor": compressor, "wire": type(wire).__name__,
        "T": T, "N": N, "shards": shards, "dim": dim, "gamma": gamma,
        "p": p, "d": d, "backend": backend,
        "dynamic_state": dynamic_state,
        "bitexact": first_div is None,
        "first_divergence": first_div,
        "max_abs_diff_theta": max_dtheta,
        "max_abs_diff_e": max_de,
        "loss_start": float(loss_fn(theta0)),
        "loss_ref": float(loss_fn(ref[-1]["theta"])),
        "loss_mesh": float(loss_fn(mesh_rec[-1]["theta"])),
    }


def assert_parity(report: Dict) -> None:
    if not report["bitexact"]:
        raise AssertionError(
            f"reference EF loop and mesh cocoef_update DIVERGED on "
            f"{report['compressor']} ({report['wire']}): first at "
            f"step {report['first_divergence']['step']} in "
            f"{report['first_divergence']['field']} "
            f"(|diff| up to theta={report['max_abs_diff_theta']:.3e}, "
            f"e={report['max_abs_diff_e']:.3e}) — the two Algorithm-1 "
            f"implementations no longer agree")
