import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:
  * jax.jit(step).lower(**ShapeDtypeStruct inputs) -> .compile() must
    succeed on the (16,16) single-pod AND (2,16,16) multi-pod meshes,
  * memory_analysis() proves the per-device working set,
  * cost_analysis() + HLO collective parsing feed EXPERIMENTS.md §Roofline.

Results are cached as JSON under results/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k --mesh both
  python -m repro.launch.dryrun --all [--mesh single|multi|both] [--force]
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY, STANDARD_SHAPES, get_arch
from repro.launch import hlo_analysis, hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.launch.serve import build_serve_setup
from repro.launch.train import TrainRun, build_train_setup

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _jsonable(x):
    if isinstance(x, (np.integer,)):
        return int(x)
    if isinstance(x, (np.floating,)):
        return float(x)
    return x


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             mode: str = "cocoef", extra_run: dict | None = None) -> dict:
    spec = get_arch(arch_id)
    mesh_name = "multi" if multi_pod else "single"
    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
           "mode": mode, "status": "unknown"}
    if shape_name in spec.skip_shapes:
        rec.update(status="skipped", reason=spec.skip_shapes[shape_name])
        return rec
    shape = spec.shapes[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    ndev = int(np.prod(mesh.devices.shape))
    t0 = time.time()
    try:
        if shape.is_train:
            run = TrainRun(mode=mode, **(extra_run or {}))
            setup = build_train_setup(spec, mesh, shape, run)
            specs = setup.input_specs()
            lowered = jax.jit(setup.train_step).lower(
                specs["params"], specs["e"], specs["opt"], specs["batch"],
                specs["step"], specs["key"])
            rec["n_code"] = setup.n_code
            rec["b_loc"] = setup.b_loc
            rec["flat_pad"] = setup.flat_pad
            rec["effective_mode"] = setup.cocoef_cfg.mode
        else:
            setup = build_serve_setup(spec, mesh, shape)
            kind = "decode" if shape.kind == "decode" else "prefill"
            specs = setup.input_specs(kind)
            if kind == "decode":
                lowered = jax.jit(
                    setup.decode_step,
                    out_shardings=setup.decode_out_shardings,
                    donate_argnums=(1,)).lower(
                    specs["params"], specs["caches"], specs["inputs"],
                    specs["pos"])
            else:
                lowered = jax.jit(
                    setup.prefill_step,
                    out_shardings=setup.prefill_out_shardings).lower(
                    specs["params"], specs["inputs"])
            rec["cache_len"] = setup.cache_len
        rec["lower_s"] = time.time() - t0

        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = time.time() - t1

        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": (mem.argument_size_in_bytes
                                    + mem.output_size_in_bytes
                                    + mem.temp_size_in_bytes
                                    - mem.alias_size_in_bytes),
        }
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):   # older jax: one dict per device
            ca = ca[0] if ca else {}
        rec["cost_xla_raw"] = {k: _jsonable(v) for k, v in ca.items()
                               if k in ("flops", "bytes accessed",
                                        "transcendentals")}
        txt = compiled.as_text()
        # while-aware cost model (XLA's cost_analysis counts loop bodies
        # once — see repro.launch.hlo_cost)
        cost = hlo_cost.analyze(txt, ndev)
        rec["cost"] = {"flops": cost.flops, "bytes accessed": cost.bytes,
                       "n_while": cost.n_while,
                       "unknown_trip": cost.unknown_trip}
        rec["collectives"] = {
            "wire_bytes_per_device": cost.wire_bytes,
            "by_op": cost.coll_by_op,
        }
        rec["roofline"] = hlo_analysis.roofline_terms(
            cost.flops, cost.bytes, cost.wire_bytes)
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record, don't crash the sweep
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = time.time() - t0
    return rec


def cell_path(arch_id, shape_name, mesh_name, mode="cocoef",
              tag="") -> Path:
    sfx = f"_{tag}" if tag else ""
    return RESULTS / f"{arch_id}__{shape_name}__{mesh_name}__{mode}{sfx}.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=("single", "multi", "both"))
    ap.add_argument("--mode", default="cocoef",
                    choices=("cocoef", "coco", "dense"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--run-json", default=None,
                    help='JSON overrides for TrainRun, e.g. '
                         '\'{"ef_dtype": "bfloat16"}\'')
    args = ap.parse_args()
    extra_run = json.loads(args.run_json) if args.run_json else None

    RESULTS.mkdir(parents=True, exist_ok=True)
    archs = list(REGISTRY) if (args.all or not args.arch) else [args.arch]
    shapes = list(STANDARD_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        for shp in shapes:
            for mp in meshes:
                mname = "multi" if mp else "single"
                path = cell_path(arch, shp, mname, args.mode, args.tag)
                if path.exists() and not args.force:
                    rec = json.loads(path.read_text())
                    print(f"[cached] {arch} {shp} {mname}: {rec['status']}")
                    continue
                rec = run_cell(arch, shp, mp, args.mode, extra_run)
                path.write_text(json.dumps(rec, indent=1))
                s = rec["status"]
                n_ok += s == "ok"
                n_fail += s == "fail"
                n_skip += s == "skipped"
                extra = ""
                if s == "ok":
                    r = rec["roofline"]
                    extra = (f" dominant={r['dominant']}"
                             f" comp={r['compute_s']*1e3:.2f}ms"
                             f" mem={r['memory_s']*1e3:.2f}ms"
                             f" coll={r['collective_s']*1e3:.2f}ms"
                             f" peakMB={rec['memory']['peak_estimate_bytes']/2**20:.0f}")
                elif s == "fail":
                    extra = " " + rec["error"][:160]
                print(f"[{s}] {arch} {shp} {mname}"
                      f" ({rec.get('total_s', 0):.0f}s){extra}", flush=True)
    print(f"done: ok={n_ok} fail={n_fail} skipped={n_skip}")


if __name__ == "__main__":
    main()
