"""Serving (prefill / decode) steps on the production mesh.

No gradient traffic here — the paper's technique is train-side — so these
cells exercise the TP/DP serving shardings: batch over the dp axes, KV/state
caches sharded per repro.sharding.rules.cache_specs (batch over dp, trailing
feature dim over model).

gemma2 @ long_500k: every layer's ring cache is capped at the sliding
window (the global-attention half is a documented deviation, DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.common import ArchSpec, ShapeCfg
from repro.nn import Model
from repro.sharding import ctx, rules

__all__ = ["ServeSetup", "build_serve_setup", "instrument_steps"]

LONG_SEQ = 1 << 19


@dataclasses.dataclass
class ServeSetup:
    mesh: Mesh
    model: Model
    cache_len: int
    batch: int
    seq_len: int
    param_shardings: Any
    cache_shardings: Any
    batch_sharding: Any
    decode_step: Any
    prefill_step: Any
    decode_out_shardings: Any
    prefill_out_shardings: Any
    input_specs: Any          # (kind) -> kwargs of ShapeDtypeStruct


def _dp_spec(mesh: Mesh, batch: int) -> Optional[Any]:
    """Largest dp-axes prefix that divides the batch."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    axes = [a for a in ("pod", "data") if a in sizes]
    total = int(np.prod([sizes[a] for a in axes])) if axes else 1
    while axes and batch % total != 0:
        axes.pop(0)
        total = int(np.prod([sizes[a] for a in axes]))
    if not axes:
        return None
    return tuple(axes) if len(axes) > 1 else axes[0]


def build_serve_setup(spec: ArchSpec, mesh: Mesh, shape: ShapeCfg,
                      smoke: bool = False) -> ServeSetup:
    cfg = spec.smoke if smoke else spec.config
    model = Model(cfg)
    B, S = shape.global_batch, shape.seq_len

    cache_len = S
    if (cfg.family in ("dense", "moe") and cfg.sliding_window
            and S >= LONG_SEQ):
        cache_len = cfg.sliding_window      # window-capped rings (gemma2)

    pshapes = model.param_shapes()
    pspecs = rules.param_specs(pshapes, cfg, mesh, fsdp=spec.coding.fsdp)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)

    cshapes = jax.eval_shape(lambda: model.init_caches(B, cache_len))
    bspec = _dp_spec(mesh, B)
    batch_axes = (bspec if isinstance(bspec, tuple) else
                  ((bspec,) if bspec else ()))
    cspecs = rules.cache_specs(cshapes, cfg, mesh, batch_axes, B)
    cshard = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs)

    def decode_step(params, caches, inputs, pos):
        with ctx.use_mesh(mesh):
            logits, new_caches = model.decode_step(params, caches, inputs, pos)
        return logits, new_caches

    def prefill_step(params, inputs):
        with ctx.use_mesh(mesh):
            return model.prefill(params, inputs)

    # ---- output shardings (pin, or GSPMD may replicate the big caches) ----
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    vshard = NamedSharding(
        mesh, P(bspec, "model" if cfg.vocab_size % sizes.get("model", 1) == 0
                else None))
    decode_out_shardings = (vshard, cshard)

    if cfg.input_mode == "tokens":
        inp_s = jax.ShapeDtypeStruct((B, S), jnp.int32)
    else:
        inp_s = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
    pre_cshapes = jax.eval_shape(lambda p, i: prefill_step(p, i)[1],
                                 pshapes, inp_s)
    pre_cspecs = rules.cache_specs(pre_cshapes, cfg, mesh, batch_axes, B)
    pre_cshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pre_cspecs)
    prefill_out_shardings = (vshard, pre_cshard)

    def input_specs(kind: str):
        pd = jax.tree.map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
            pshapes, pshard)
        if kind == "decode":
            cd = jax.tree.map(
                lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
                cshapes, cshard)
            if cfg.input_mode == "tokens":
                tok = jax.ShapeDtypeStruct(
                    (B, 1), jnp.int32,
                    sharding=NamedSharding(mesh, P(bspec, None)))
            else:
                tok = jax.ShapeDtypeStruct(
                    (B, 1, cfg.d_model), jnp.bfloat16,
                    sharding=NamedSharding(mesh, P(bspec, None, None)))
            return {"params": pd, "caches": cd, "inputs": tok,
                    "pos": jax.ShapeDtypeStruct((), jnp.int32)}
        # prefill
        if cfg.input_mode == "tokens":
            inp = jax.ShapeDtypeStruct(
                (B, S), jnp.int32,
                sharding=NamedSharding(mesh, P(bspec, None)))
        else:
            inp = jax.ShapeDtypeStruct(
                (B, S, cfg.d_model), jnp.bfloat16,
                sharding=NamedSharding(mesh, P(bspec, None, None)))
        return {"params": pd, "inputs": inp}

    return ServeSetup(mesh=mesh, model=model, cache_len=cache_len, batch=B,
                      seq_len=S, param_shardings=pshard,
                      cache_shardings=cshard,
                      batch_sharding=bspec, decode_step=decode_step,
                      prefill_step=prefill_step,
                      decode_out_shardings=decode_out_shardings,
                      prefill_out_shardings=prefill_out_shardings,
                      input_specs=input_specs)


def instrument_steps(setup: ServeSetup, telemetry) -> Tuple[Any, Any]:
    """Jitted prefill/decode wrappers feeding a `repro.obs.ServeTelemetry`.

    Returns (prefill, decode) callables with the same signatures as
    `setup.prefill_step` / `setup.decode_step`; each call BLOCKS on the
    result (block_until_ready on the first output leaf) and records the
    wall time as one prefill sample / one decode-token sample, inside a
    `SpanRecorder` span ("serve/prefill", "serve/decode") so the samples
    land in the Chrome-trace export too.  The blocking wait is the point:
    the latency histograms price the step's real device time, not the
    dispatch.  Use only on measurement paths — a throughput loop should
    keep the async dispatch of the raw jitted steps."""
    jprefill = jax.jit(setup.prefill_step,
                       out_shardings=setup.prefill_out_shardings)
    jdecode = jax.jit(setup.decode_step,
                      out_shardings=setup.decode_out_shardings)
    rec = telemetry.recorder

    def prefill(params, inputs):
        with rec.span("serve/prefill", tid="serve"):
            out = jprefill(params, inputs)
            jax.tree.leaves(out)[0].block_until_ready()
        telemetry.add_prefill(rec.spans[-1]["t1"] - rec.spans[-1]["t0"])
        return out

    def decode(params, caches, inputs, pos):
        with rec.span("serve/decode", tid="serve"):
            out = jdecode(params, caches, inputs, pos)
            jax.tree.leaves(out)[0].block_until_ready()
        telemetry.add_decode_token(rec.spans[-1]["t1"] - rec.spans[-1]["t0"])
        return out

    return prefill, decode
