"""Production mesh builders.

Functions (not module-level constants) so importing never touches jax
device state; `dryrun.py` sets XLA_FLAGS before calling these.
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests / local smoke)."""
    n = len(jax.devices())
    dp = n // model_parallel
    return make_mesh((dp, model_parallel), ("data", "model"))
