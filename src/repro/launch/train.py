"""Distributed COCO-EF training step on the production mesh.

Two-stage structure (DESIGN.md Sec. 2/5):

  Stage 1 — per-coding-rank coded gradients, plain GSPMD:
    the global batch carries a leading coding dimension (N_code, B_loc, ...)
    sharded over the coding axes; `vmap(grad)` over that dimension yields
    each rank's coded gradient  g_i = sum_{k in S_i} grad f_k / (d_k (1-p))
    (the per-example weights fold the coding weights, so the coded sum is a
    single weighted backward pass).  TP/FSDP sharding inside is handled by
    GSPMD via the rules in repro.sharding.rules + activation constraints.

  Stage 2 — Algorithm 1 aggregation, fully-manual shard_map:
    every device flattens its local gradient slice, applies
    error-feedback + biased sign compression, and participates in the
    two-phase wire-compressed collective (repro.core.collectives).  The
    server update theta <- theta - ghat runs redundantly (replicated) on
    every coding rank — bitwise identical to the paper's server.

`mode`: cocoef (paper) | coco (no EF ablation) | dense (SGC [31] baseline).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.common import ArchSpec, ShapeCfg
from repro.core import coding
from repro.core.coding_state import CodingPlan, CodingState
from repro.core.cocoef import (CocoEFConfig, FlatMeta, cocoef_update,
                               flatten_local, padded_size, unflatten_local)
from repro.core.plan import PlanSpec
from repro.nn import Model
from repro.obs.metrics import (MetricsFrame, frame_out_specs,
                               reduce_frame_grid)
from repro.optim import OptimizerConfig, apply_update, init_opt_state, \
    lr_schedule
from repro.sharding import ctx, rules
from repro.sim import stragglers

__all__ = ["TrainRun", "build_train_setup", "setup_encode_weights",
           "elastic_coding_state", "batch_stream"]


@dataclasses.dataclass(frozen=True)
class TrainRun:
    mode: str = "cocoef"             # cocoef | coco | dense
    base_lr: float = 1e-3
    schedule: str = "constant"       # constant | rsqrt | cosine
    schedule_total: Optional[int] = None  # cosine: decay horizon (steps)
    warmup: int = 0
    optimizer: OptimizerConfig = OptimizerConfig()
    plan: Optional[PlanSpec] = None  # THE deployment config (core.plan):
    #   d, allocation mode, wire knobs, buckets, backend.  When set it is
    #   the single source of truth and the deprecated alias fields below
    #   (compressor / k_budgets / num_buckets / bucket_schedule / backend)
    #   must stay at their defaults; when None, `resolve_plan` assembles
    #   the identical PlanSpec from those aliases + spec.coding, so every
    #   pre-plan caller keeps working bit-for-bit
    compressor: Optional[str] = None  # DEPRECATED alias -> plan.compressor
    ef_dtype: str = "float32"
    phase2_dtype: str = "float32"
    phase2_sign: bool = False
    num_buckets: int = 1             # DEPRECATED alias -> plan.num_buckets
    bucket_schedule: str = "pipelined"  # DEPRECATED alias ->
    #   plan.bucket_schedule.  pipelined | serial bucket issue order
    #   (CocoEFConfig.bucket_schedule): pipelined double-buffers the
    #   per-bucket collectives so bucket i's wire transfer overlaps bucket
    #   i+1's fused local step; bit-for-bit equal to serial
    prefetch: int = 0                # host->device batches staged ahead of
    #   the step (data.pipeline.prefetch_to_device); 0 = synchronous.
    #   Opt-in: on XLA:CPU the worker thread's concurrent client calls can
    #   race the fake-device collective rendezvous (see prefetch_to_device)
    backend: str = "auto"            # DEPRECATED alias -> plan.backend
    #   (auto | pallas | jnp kernel dispatch)
    straggler: str = "iid"           # iid | markov | hetero | trace
    straggler_burst: float = 8.0     # markov: mean slow-burst length (steps)
    straggler_spread: float = 0.5    # hetero: p_i in p*(1 +/- spread)
    straggler_trace: Optional[str] = None  # trace: recorded-mask JSON or
    #   per-rank availability CSV path (sim.TraceReplay.from_file)
    rate_aware: bool = True          # encode weights from per-rank rates
    #   q_i (StragglerProcess.rates()) instead of the scalar mean rate p —
    #   identical to eq. 3 for uniform rates, unbiased under non-iid
    #   stragglers; False = the paper-faithful mean-rate eq. 3
    k_budgets: Optional[Tuple[int, ...]] = None
    #   DEPRECATED alias -> plan.k_per_block tuple: per-coding-rank
    #   block-top-K wire budgets (sim.solve_k_budgets); overrides
    #   spec.coding.k_per_block when compressor="block_topk"
    elastic: bool = False            # dynamic coding plane: the train step
    #   takes an explicit CodingState (rates_estimate, W, epoch) argument
    #   and folds W in-graph via the batch's subset_ids, so online rate
    #   estimates (obs.MetricsLogger.rates -> CodingPlan.maybe_replan) can
    #   update the encode weights every step without retracing; False = W
    #   baked into the batch weights at construction (the static path)
    replan_threshold: float = 0.1    # elastic: max |q_est - q_planned|
    #   before the host recomputes the allocation (epoch bump)
    seed: int = 0
    aux_weight: float = 0.01
    param_dtype: Optional[str] = None   # override cfg (e.g. "bfloat16")
    metrics: bool = False            # in-graph telemetry (repro.obs): the
    #   train step additionally returns metrics["telemetry"], the reduced
    #   MetricsFrame (per-rank wire bytes, participation, EF/compression
    #   norms).  Adds device-local FLOPs only — no host callbacks, no extra
    #   collectives; False traces the exact pre-telemetry HLO (pinned by
    #   tests/test_obs.py)

    def __post_init__(self):
        # validate at construction: bad straggler / coding knobs used to
        # surface as NaNs or cryptic shape errors deep inside jit
        if self.mode not in ("cocoef", "coco", "dense"):
            raise ValueError(f"unknown mode {self.mode!r}; "
                             f"have ('cocoef', 'coco', 'dense')")
        # schedule knobs validate at construction (lr_schedule re-checks):
        # a TrainRun that would die inside jit tracing is rejected here
        lr_schedule(self.schedule, self.base_lr, self.warmup,
                    self.schedule_total)
        if self.straggler not in stragglers.STRAGGLER_PROCESSES:
            raise ValueError(
                f"unknown straggler process {self.straggler!r}; "
                f"have {stragglers.STRAGGLER_PROCESSES}")
        if self.straggler_burst < 1.0:
            raise ValueError(f"straggler_burst={self.straggler_burst} must "
                             f"be >= 1 step")
        if self.straggler_spread < 0.0:
            raise ValueError(f"straggler_spread={self.straggler_spread} "
                             f"must be >= 0")
        if self.backend not in ("auto", "pallas", "jnp"):
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"have ('auto', 'pallas', 'jnp')")
        if self.num_buckets < 1:
            raise ValueError(f"num_buckets={self.num_buckets} must be >= 1")
        if self.bucket_schedule not in ("serial", "pipelined"):
            raise ValueError(f"unknown bucket_schedule "
                             f"{self.bucket_schedule!r}; have "
                             f"('serial', 'pipelined')")
        if self.prefetch < 0:
            raise ValueError(f"prefetch={self.prefetch} must be >= 0")
        if self.k_budgets is not None and \
                any(k < 1 for k in self.k_budgets):
            raise ValueError("every per-rank k budget must be >= 1")
        if self.k_budgets is not None and len(self.k_budgets) == 0:
            raise ValueError("k_budgets must be non-empty (one per-rank "
                             "block-top-K budget per coding rank)")
        if self.plan is not None:
            # the deprecated alias cluster and an explicit PlanSpec are
            # mutually exclusive: a plan that silently loses to a stray
            # alias would un-do the "one source of truth" guarantee
            _alias_defaults = {"compressor": None, "k_budgets": None,
                               "num_buckets": 1,
                               "bucket_schedule": "pipelined",
                               "backend": "auto"}
            clash = [f for f, dflt in _alias_defaults.items()
                     if getattr(self, f) != dflt]
            if clash:
                raise ValueError(
                    f"TrainRun(plan=...) conflicts with deprecated alias "
                    f"field(s) {clash}: the plan already carries those "
                    f"knobs — set them on the PlanSpec instead")
        if not self.replan_threshold > 0.0:
            raise ValueError(f"replan_threshold={self.replan_threshold} "
                             f"must be > 0")
        if self.elastic and self.prefetch:
            raise ValueError(
                "elastic runs need synchronous batches (prefetch=0): a "
                "replan changes the subset placement between batch "
                "generation and consumption")

    def resolve_plan(self, coding_cfg, n_code: int) -> PlanSpec:
        """The effective PlanSpec of this run on `n_code` coding ranks.

        With an explicit `plan`, binds/validates its `num_ranks` against the
        mesh.  Otherwise assembles the identical PlanSpec the pre-plan code
        path implied: deprecated alias fields override `coding_cfg`
        (configs.common.CodingCfg) exactly as `build_train_setup` used to do
        inline — the equivalence every legacy caller relies on."""
        m = max(n_code, 1)
        if self.plan is not None:
            if self.plan.num_ranks is None:
                return dataclasses.replace(self.plan, num_ranks=m)
            if self.plan.num_ranks != m:
                raise ValueError(
                    f"plan targets num_ranks={self.plan.num_ranks} coding "
                    f"ranks but the mesh has {m}")
            return self.plan
        comp = self.compressor or coding_cfg.compressor
        k_per_block = coding_cfg.k_per_block
        if self.k_budgets is not None:
            if comp != "block_topk":
                raise ValueError(
                    f"k_budgets rides the block-top-K sparse wire; the "
                    f"effective compressor is {comp!r} (pass "
                    f"compressor='block_topk' or drop k_budgets)")
            if len(self.k_budgets) != m:
                raise ValueError(f"k_budgets has {len(self.k_budgets)} "
                                 f"entries, the run has {m} coding ranks")
            k_per_block = self.k_budgets
        return PlanSpec(
            d=min(coding_cfg.redundancy, m), allocation="uniform",
            compressor=comp, group_size=coding_cfg.group_size,
            k_per_block=k_per_block, block_size=coding_cfg.block_size,
            topk_k=coding_cfg.topk_k, value_dtype=coding_cfg.wire_dtype,
            num_buckets=self.num_buckets,
            bucket_schedule=self.bucket_schedule, backend=self.backend,
            num_ranks=m)


@dataclasses.dataclass
class TrainSetup:
    """Everything needed to lower/run the step: shardings + callables."""
    mesh: Mesh
    model: Model
    coding_axes: Tuple[str, ...]
    n_code: int
    b_loc: int
    seq_len: int
    flat_pad: int
    param_specs: Any
    param_shardings: Any
    grads_shardings: Any
    state_sharding: NamedSharding
    batch_shardings: Any
    train_step: Any                  # jit-able fn
    input_specs: Any                 # () -> kwargs of ShapeDtypeStruct
    init_state: Any                  # (key) -> (params, e, opt) real arrays
    allocation: coding.Allocation
    cocoef_cfg: CocoEFConfig
    plan: PlanSpec = PlanSpec()      # the resolved deployment plan (num_ranks
    #   bound to the mesh); "the config priced is the config run": price
    #   StepTimer with plan.wire(...)/plan.rank_wire_bytes and you priced
    #   exactly what train_step ships
    straggler_process: Optional[stragglers.StragglerProcess] = None
    coding_plan: Optional[CodingPlan] = None   # elastic runs: the host-side
    #   replan controller; its CURRENT allocation is what the batch maker
    #   uses (setup.allocation stays the epoch-0 placement)
    per_subset: int = 1              # examples per subset (the batch-maker
    #   1/per_subset fold elastic_coding_state applies host-side)


def _local_flat_size(shapes_tree, specs_tree, mesh: Mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    total = 0
    for leaf, spec in zip(jax.tree.leaves(shapes_tree),
                          jax.tree.leaves(specs_tree, is_leaf=lambda s: isinstance(s, P))):
        n = 1
        for dim, entry in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if entry is None:
                n *= dim
            else:
                axes = (entry,) if isinstance(entry, str) else entry
                f = int(np.prod([sizes[a] for a in axes]))
                n *= dim // f
        total += n
    return total


def build_train_setup(spec: ArchSpec, mesh: Mesh, shape: ShapeCfg,
                      run: TrainRun = TrainRun(), smoke: bool = False,
                      mode: Optional[str] = None) -> TrainSetup:
    cfg = spec.smoke if smoke else spec.config
    if run.param_dtype:
        cfg = dataclasses.replace(cfg, param_dtype=run.param_dtype)
    mode = mode or run.mode
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    coding_axes = tuple(a for a in spec.coding.coding_axes
                        if a in mesh.axis_names)
    n_code = int(np.prod([axis_sizes[a] for a in coding_axes])) \
        if coding_axes else 1
    if n_code <= 1:
        mode = "dense"               # coding degenerates (documented)
        p_strag = 0.0
    else:
        p_strag = spec.coding.straggler_p

    # ---- the effective deployment plan (single source of truth) ----------
    # `plan` carries every (d, wire, k, schedule, backend) knob from here
    # on; the deprecated TrainRun aliases and spec.coding were already
    # folded into it, so nothing below re-derives a knob from two places.
    plan = run.resolve_plan(spec.coding, n_code)

    # straggler process feeding the mask-provider hook (repro.sim): the
    # legacy fast path (iid with p=0 -> all-ones mask, no PRNG work) is
    # preserved by constructing no process at all in that case
    straggler_proc = None
    if n_code > 1 and (run.straggler != "iid" or p_strag > 0):
        straggler_proc = stragglers.get_straggler_process(
            run.straggler, n_code, p_strag, mean_burst=run.straggler_burst,
            spread=run.straggler_spread, trace=run.straggler_trace)

    # rate-aware encode weights: divide by the expected participating
    # holders sum_j S[j,k] q_j (unbiased for ANY per-rank rates) instead of
    # d_k (1-p); bit-for-bit eq. 3 when the rates are uniform (iid/markov)
    straggler_rates = None
    if run.rate_aware and straggler_proc is not None:
        straggler_rates = tuple(float(x) for x in straggler_proc.rates())

    # ---- gradient coding allocation (static, host-side) -------------------
    M = n_code                        # one subset per coding rank by default
    d = plan.d
    if n_code <= 1:
        alloc = coding.Allocation(S=np.ones((1, 1), np.int8))
    elif plan.allocation == "uniform":
        alloc = coding.cyclic_allocation(n_code, M, d)
    else:
        # heterogeneity-aware placement from the same rates the encode
        # weights use (planned rates when no process is attached)
        q = np.asarray(straggler_rates, np.float64) \
            if straggler_rates is not None \
            else np.full((n_code,), 1.0 - p_strag)
        alloc = coding.rate_aware_allocation(
            q, M, d, exact_load=(plan.allocation == "exact_load"))

    gb, seq = shape.global_batch, shape.seq_len
    per_subset = max(1, gb // M)
    b_loc = per_subset * d            # redundancy multiplies per-rank batch

    model = Model(cfg)
    pshapes = model.param_shapes()
    fsdp = spec.coding.fsdp
    pspecs = rules.param_specs(pshapes, cfg, mesh, fsdp=fsdp)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    gspecs = rules.grads_specs(pshapes, cfg, mesh, coding_axes, fsdp=fsdp)
    gshard = jax.tree.map(lambda s: NamedSharding(mesh, s), gspecs)

    # wire / compressor / schedule knobs all come from the resolved plan
    nd_chunk = axis_sizes[coding_axes[-1]] if coding_axes else 1

    cocoef_cfg = CocoEFConfig(
        coding_axes=coding_axes if coding_axes else ("data",),
        group_size=plan.group_size, straggler_p=p_strag,
        straggler_rates=straggler_rates, mode=mode,
        compressor=plan.compressor,
        topk_k=plan.topk_k, k_per_block=plan.k_per_block,
        block_size=plan.block_size, wire_dtype=plan.value_dtype,
        ef_dtype=run.ef_dtype, phase2_dtype=run.phase2_dtype,
        phase2_sign=run.phase2_sign, num_buckets=plan.num_buckets,
        bucket_schedule=plan.bucket_schedule, backend=plan.backend)

    # device-local flat size (uniform across devices by construction);
    # padding alignment comes from the active wire format, not just the
    # sign group (block top-K needs lcm(group, block))
    loc = _local_flat_size(pshapes, pspecs, mesh)
    flat_pad = padded_size(loc, nd_chunk, cocoef_cfg.pad_multiple,
                           plan.num_buckets)

    mesh_shape = tuple(mesh.devices.shape)
    state_shape = mesh_shape + (flat_pad,)
    state_spec = P(*mesh.axis_names, None)
    state_sharding = NamedSharding(mesh, state_spec)

    gamma_fn = lr_schedule(run.schedule, run.base_lr, run.warmup,
                           run.schedule_total)
    n_opt = len(init_opt_state(run.optimizer, 1))

    # ---- batch specs -------------------------------------------------------
    inner_axes = tuple(a for a in ("pod", "data")
                       if a in mesh.axis_names and a not in coding_axes)
    lead = (coding_axes if len(coding_axes) > 1 else
            (coding_axes[0] if coding_axes else None))
    inner = (inner_axes if len(inner_axes) > 1 else
             (inner_axes[0] if inner_axes else None))
    if cfg.input_mode == "tokens":
        batch_specs = {"inputs": P(lead, inner, None),
                       "weights": P(lead, inner)}
        batch_shapes = {"inputs": jax.ShapeDtypeStruct(
            (n_code, b_loc, seq + 1), jnp.int32),
            "weights": jax.ShapeDtypeStruct((n_code, b_loc), jnp.float32)}
    else:
        batch_specs = {"inputs": P(lead, inner, None, None),
                       "targets": P(lead, inner, None),
                       "weights": P(lead, inner)}
        batch_shapes = {
            "inputs": jax.ShapeDtypeStruct((n_code, b_loc, seq, cfg.d_model),
                                           jnp.bfloat16),
            "targets": jax.ShapeDtypeStruct((n_code, b_loc, seq), jnp.int32),
            "weights": jax.ShapeDtypeStruct((n_code, b_loc), jnp.float32)}
    if run.elastic:
        # per-example subset ids ride the batch (same layout as weights);
        # the step looks the live W up through them in-graph
        batch_specs["subset_ids"] = P(lead, inner)
        batch_shapes["subset_ids"] = jax.ShapeDtypeStruct(
            (n_code, b_loc), jnp.int32)
    batch_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                   batch_specs)

    # ---- dynamic coding plane (elastic runs) -------------------------------
    coding_plan = None
    if run.elastic:
        # initial estimate = whatever the static path would bake in, so
        # epoch 0 of the dynamic path is bit-for-bit the static path
        # (uniform rates hit encode_weights' eq.-3 branch)
        init_rates = np.asarray(straggler_rates, np.float64) \
            if straggler_rates is not None \
            else np.full((max(n_code, 1),), 1.0 - p_strag)
        coding_plan = CodingPlan.create(
            init_rates, M, d, drift_threshold=run.replan_threshold,
            exact_load=(plan.allocation != "rate_aware"), allocation=alloc)

    # =======================================================================
    # stage 2 body (fully manual)
    # =======================================================================
    all_axes = set(mesh.axis_names)
    n_leaves = len(jax.tree.leaves(pshapes))

    def agg_body(params, grads, e, opt, step, key):
        # local leaf blocks; grads leaves carry leading coding dims of size 1
        p_leaves = jax.tree.leaves(params)
        g_leaves = jax.tree.leaves(grads)
        p_flat, p_meta = flatten_local(p_leaves, nd_chunk,
                                       cocoef_cfg.pad_multiple,
                                       plan.num_buckets)
        g_flat, _ = flatten_local(g_leaves, nd_chunk, cocoef_cfg.pad_multiple,
                                  plan.num_buckets)
        e_loc = e.reshape(-1)
        opt_loc = tuple(o.reshape(-1) for o in opt)

        gamma = gamma_fn(step)
        mask_fn = straggler_proc.mask if straggler_proc is not None else \
            (lambda k, s: jnp.ones((max(n_code, 1),), jnp.float32))

        if run.metrics:
            ghat, e_new, frame = cocoef_update(
                g_flat, e_loc, None, gamma, cocoef_cfg,
                mask_provider=mask_fn, key=key, step=step, want_metrics=True)
            p_new_flat, opt_new, onorms = apply_update(
                run.optimizer, p_flat, ghat, opt_loc, step, gamma,
                want_norms=True)
            frame = frame.replace(update_norm_sq=onorms["update_norm_sq"],
                                  param_norm_sq=onorms["param_norm_sq"])
        else:
            ghat, e_new = cocoef_update(g_flat, e_loc, None, gamma,
                                        cocoef_cfg, mask_provider=mask_fn,
                                        key=key, step=step)
            p_new_flat, opt_new = apply_update(run.optimizer, p_flat, ghat,
                                               opt_loc, step, gamma)
        new_leaves = unflatten_local(p_new_flat, p_meta)
        params_new = jax.tree.unflatten(jax.tree.structure(params), new_leaves)
        gnorm = jnp.sqrt(jnp.sum(ghat * ghat))          # local-slice norm
        shape1 = (1,) * len(mesh_shape)
        out = (params_new, e_new.reshape(shape1 + (flat_pad,)),
               tuple(o.reshape(shape1 + (flat_pad,)) for o in opt_new),
               gnorm.reshape(shape1))
        if run.metrics:
            # the gnorm idiom per leaf: grid-position dims of size 1 so the
            # replicated frame lands as a (mesh..., leaf)-shaped output
            out += (jax.tree.map(lambda l: l.reshape(shape1 + l.shape),
                                 frame),)
        return out

    grads_in_specs = gspecs
    params_in_specs = pspecs
    opt_specs = tuple(state_spec for _ in range(n_opt))

    out_specs = (params_in_specs, state_spec, opt_specs,
                 P(*mesh.axis_names))
    if run.metrics:
        frame_abs = MetricsFrame.abstract(max(n_code, 1), plan.num_buckets)
        out_specs += (frame_out_specs(frame_abs, mesh.axis_names),)

    agg = compat.shard_map(
        agg_body, mesh,
        in_specs=(params_in_specs, grads_in_specs, state_spec, opt_specs,
                  P(), P()),
        out_specs=out_specs,
        axis_names=all_axes, check=False)

    # =======================================================================
    # full train step
    # =======================================================================
    # FSDP archs: register ZeRO-3-style just-in-time weight gathering —
    # inside each layer scan the fsdp-sharded f32 weight slice is cast to
    # bf16 and re-constrained to its TP-only sharding, so the data-axis
    # all-gather moves bf16 weights instead of f32 activation partials
    # (EXPERIMENTS.md §Perf).
    weight_gather = None
    if fsdp:
        sizes_wg = dict(zip(mesh.axis_names, mesh.devices.shape))

        def weight_gather(tree, ct):
            from jax.sharding import PartitionSpec as _P

            def f(path, leaf):
                if leaf.ndim < 2:
                    return leaf
                spec = rules._check_divisible(
                    rules._leaf_rule(path, leaf, cfg, False), leaf.shape,
                    sizes_wg)
                # barrier: stop XLA hoisting the bf16 cast past the gather.
                # (Forcing reduce-scatter on the cotangent via custom_vjp
                # was tried and REFUTED: under remat the extra constraint
                # duplicates the per-layer grad all-reduce — §Perf.)
                w16 = jax.lax.optimization_barrier(leaf.astype(ct))
                return jax.lax.with_sharding_constraint(
                    w16, NamedSharding(mesh, _P(*spec)))
            return jax.tree_util.tree_map_with_path(f, tree)

    def base_step(params, e, opt, batch, step, key):
        def loss_one(p, b):
            loss, per_ex = model.loss(p, b)
            return loss

        def grad_one(b):
            l, g = jax.value_and_grad(lambda p: loss_one(p, b))(params)
            return g, l

        with ctx.use_mesh(mesh, weight_gather=weight_gather):
            grads, losses = jax.vmap(grad_one)(batch)
        grads = jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, s)), grads, gspecs)
        if run.metrics:
            params_new, e_new, opt_new, gnorm, frame_grid = agg(
                params, grads, e, opt, step, key)
        else:
            params_new, e_new, opt_new, gnorm = agg(params, grads, e, opt,
                                                    step, key)
        metrics = {"loss": losses.mean(), "gnorm_local": gnorm.max()}
        if run.metrics:
            # grid-replicated frame -> per-coding-rank / global step
            # telemetry; runs outside the shard_map, adds no collectives
            metrics["telemetry"] = reduce_frame_grid(
                frame_grid, mesh.axis_names, coding_axes)
        return params_new, e_new, opt_new, metrics

    if run.elastic:
        def train_step(params, e, opt, batch, step, key, coding_state):
            # fold the LIVE encode weights in-graph.  coding_state.W here
            # is ALREADY W/per_subset (elastic_coding_state divides on the
            # host): the per-example weight must be the identical f32
            # value the static batch maker bakes in, and an in-graph
            # divide-by-constant is strength-reduced by XLA to a
            # reciprocal multiply (off by an ulp for non-pow2
            # per_subset).  W is a pytree leaf: new value, no retrace.
            coef = jnp.take_along_axis(
                coding_state.W, batch["subset_ids"], axis=1)
            b = {k: v for k, v in batch.items() if k != "subset_ids"}
            b["weights"] = b["weights"] * coef
            p_new, e_new, opt_new, metrics = base_step(params, e, opt, b,
                                                       step, key)
            # echo the plane's state so drivers can donate coding_state
            # (every leaf is an output -> XLA aliases the buffers)
            metrics = dict(metrics, coding_epoch=coding_state.epoch,
                           coding_W=coding_state.W,
                           rates_estimate=coding_state.rates_estimate)
            return p_new, e_new, opt_new, metrics
    else:
        train_step = base_step

    # ---- specs / init ------------------------------------------------------
    def input_specs():
        cs = {}
        if run.elastic:
            cs["coding_state"] = CodingState(
                rates_estimate=jax.ShapeDtypeStruct((max(n_code, 1),),
                                                    jnp.float32),
                W=jax.ShapeDtypeStruct((max(n_code, 1), M), jnp.float32),
                epoch=jax.ShapeDtypeStruct((), jnp.int32))
        return {
            "params": jax.tree.map(
                lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
                pshapes, pshard),
            "e": jax.ShapeDtypeStruct(state_shape, jnp.dtype(run.ef_dtype),
                                      sharding=state_sharding),
            "opt": tuple(jax.ShapeDtypeStruct(state_shape, jnp.float32,
                                              sharding=state_sharding)
                         for _ in range(n_opt)),
            "batch": jax.tree.map(
                lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
                batch_shapes, batch_shardings),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
            "key": jax.ShapeDtypeStruct((2,), jnp.uint32),
            **cs,
        }

    def init_state(key):
        params = jax.jit(model.init, out_shardings=pshard)(key)
        e = jnp.zeros(state_shape, jnp.dtype(run.ef_dtype))
        e = jax.device_put(e, state_sharding)
        opt = tuple(jax.device_put(jnp.zeros(state_shape, jnp.float32),
                                   state_sharding) for _ in range(n_opt))
        return params, e, opt

    return TrainSetup(
        mesh=mesh, model=model, coding_axes=coding_axes, n_code=n_code,
        b_loc=b_loc, seq_len=seq, flat_pad=flat_pad, param_specs=pspecs,
        param_shardings=pshard, grads_shardings=gshard,
        state_sharding=state_sharding, batch_shardings=batch_shardings,
        train_step=train_step, input_specs=input_specs, init_state=init_state,
        allocation=alloc, cocoef_cfg=cocoef_cfg, plan=plan,
        straggler_process=straggler_proc, coding_plan=coding_plan,
        per_subset=per_subset)


def setup_encode_weights(setup: TrainSetup) -> jnp.ndarray:
    """THE (N_code, M) encode weights the trainer aggregates with:
    rate-aware (per-rank q_i) when the setup carries straggler rates, else
    mean-rate eq. 3.  Every batch maker (make_batch_for_step, the fig10
    model-zoo sweep) must fold THIS W so stage 1 weights the examples with
    exactly the coding the stage-2 aggregation assumes."""
    if setup.cocoef_cfg.straggler_rates is not None:
        return coding.encode_weights(
            setup.allocation, rates=setup.cocoef_cfg.straggler_rates)
    return coding.encode_weights(setup.allocation,
                                 setup.cocoef_cfg.straggler_p)


def elastic_coding_state(setup: TrainSetup, rates=None):
    """One coding-plane control tick for the elastic train loop.

    Runs `CodingPlan.maybe_replan` on the latest rate estimates (None —
    e.g. `MetricsLogger.rates` before the first step — keeps the planned
    rates), then applies the batch maker's 1/per_subset fold HOST-side
    (numpy f32, the exact division the static path bakes into its batch
    weights; an in-graph divide would be strength-reduced by XLA and lose
    the last ulp).  Returns (CodingState ready to feed the jitted step,
    replan info dict for `MetricsLogger.log_replan`).
    """
    from repro.core import coding_state as cs
    if setup.coding_plan is None:
        raise ValueError("setup was built without TrainRun.elastic")
    st, info = cs.maybe_replan(setup.coding_plan, rates)
    W_scaled = jnp.asarray(np.asarray(st.W) / setup.per_subset)
    return st._replace(W=W_scaled), info


def make_batch_for_step(setup: TrainSetup, spec: ArchSpec, shape: ShapeCfg,
                        key, step: int, smoke: bool = False):
    """Materialize a real global batch (smoke/integration runs).

    Tokens and the coded per-example weights come from ONE batch maker —
    `data.pipeline.coded_train_batch` — so the W/per_subset folding that
    realizes eq. 3 in stage 1 lives in a single place (shared with the
    fig10 model-zoo sweep) and cannot drift between entry points."""
    from repro.data import pipeline

    cfg = spec.smoke if smoke else spec.config
    n_code, b_loc, seq = setup.n_code, setup.b_loc, setup.seq_len
    per_subset = max(1, shape.global_batch // setup.allocation.num_subsets)
    if setup.coding_plan is not None:
        # elastic: weights stay OUT of the batch (the step folds the live
        # CodingState.W in-graph via subset_ids); the plan's CURRENT
        # allocation decides the placement, so an epoch bump takes effect
        # at the next batch without retracing (uniform load keeps shapes)
        toks, wts, sids = pipeline.elastic_train_batch(
            key, step, setup.coding_plan.allocation, per_subset, seq,
            cfg.vocab_size)
        extra = {"subset_ids": sids}
    else:
        W = setup_encode_weights(setup)
        toks, wts = pipeline.coded_train_batch(
            key, step, setup.allocation, W, per_subset, seq, cfg.vocab_size)
        extra = {}
    if cfg.input_mode == "tokens":
        return {"inputs": toks, "weights": wts, **extra}
    emb = jax.random.normal(key, (n_code, b_loc, seq, cfg.d_model),
                            jnp.bfloat16) * 0.02
    tgt = toks[..., :-1]
    return {"inputs": emb, "targets": tgt, "weights": wts, **extra}


def batch_stream(setup: TrainSetup, spec: ArchSpec, shape: ShapeCfg, key,
                 start_step: int = 0, smoke: bool = False, prefetch: int = 0):
    """Device-resident batch iterator for the serial train loop: yields the
    `make_batch_for_step` batches in step order, already `device_put`
    against `setup.batch_shardings`.

    With prefetch >= 1 a background thread stages that many batches ahead
    (`data.pipeline.prefetch_to_device`), so while the mesh executes step
    t the host is generating + transferring step t+1's coded batch — the
    host-side batch construction disappears from the step's critical path.
    prefetch=0 (the default) is a synchronous generate-then-put per pull
    (identical batches either way: the maker is deterministic in
    (key, step)).  Prefetch is OPT-IN here because on XLA:CPU fake
    devices the worker's concurrent client calls can race the in-process
    collective rendezvous of the mesh step — see prefetch_to_device."""
    from repro.data import pipeline

    def gen():
        t = start_step
        while True:
            yield make_batch_for_step(setup, spec, shape, key, t, smoke=smoke)
            t += 1

    if prefetch < 1:
        return (jax.device_put(b, setup.batch_shardings) for b in gen())
    return pipeline.prefetch_to_device(gen(), size=prefetch,
                                       shardings=setup.batch_shardings)
