"""Parse compiled HLO for collective traffic + assemble roofline terms.

collective_bytes is not in cost_analysis(), so we regex the optimized HLO:
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (incl. async -start forms) contributes its result bytes,
converted to per-device *wire* bytes with standard ring-algorithm factors:

  all-gather       out * (g-1)/g          (receives everyone else's shard)
  all-reduce       out * 2(g-1)/g         (reduce-scatter + all-gather ring)
  reduce-scatter   out * (g-1)            (out is the scattered shard)
  all-to-all       out * (g-1)/g
  collective-permute  out                 (one hop)

Hardware constants (TPU v5e target): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<rtype>\([^)]*\)|\S+?)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_ARRAY_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _array_bytes(type_str: str) -> int:
    total = 0
    for m in _ARRAY_RE.finditer(type_str):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        first = m.group(1)
        return len([x for x in first.split(",") if x.strip() != ""])
    return default


_WIRE_FACTOR = {
    "all-gather": lambda g: (g - 1) / g,
    "all-reduce": lambda g: 2 * (g - 1) / g,
    "reduce-scatter": lambda g: float(g - 1),
    "all-to-all": lambda g: (g - 1) / g,
    "collective-permute": lambda g: 1.0,
}


@dataclasses.dataclass
class CollectiveStats:
    ops: List[Dict]                  # per-op records
    wire_bytes: float                # per-device wire bytes (ring model)
    result_bytes: float              # sum of result sizes (raw)

    def by_op(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for o in self.ops:
            out[o["op"]] = out.get(o["op"], 0.0) + o["wire_bytes"]
        return out


def parse_collectives(hlo_text: str, num_devices: int) -> CollectiveStats:
    ops = []
    wire = 0.0
    raw = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        nbytes = _array_bytes(m.group("rtype"))
        g = _group_size(line, num_devices)
        w = nbytes * _WIRE_FACTOR[op](max(g, 1))
        ops.append({"op": op, "result_bytes": nbytes, "group": g,
                    "wire_bytes": w})
        wire += w
        raw += nbytes
    return CollectiveStats(ops=ops, wire_bytes=wire, result_bytes=raw)


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   wire_bytes_per_device: float) -> Dict[str, float]:
    compute_s = flops_per_device / PEAK_FLOPS
    memory_s = bytes_per_device / HBM_BW
    collective_s = wire_bytes_per_device / ICI_BW
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s), key=lambda kv: kv[1])[0]
    total = max(compute_s, memory_s, collective_s)
    return {
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "bound_s": total,
        "roofline_fraction": (compute_s / total) if total > 0 else 0.0,
    }
