"""While-aware HLO cost model (flops / HBM bytes / collective wire bytes).

XLA's `compiled.cost_analysis()` counts each while-loop body ONCE, so any
scan-over-layers model is undercounted by ~num_layers x (verified in
tests/test_hlo_cost.py).  This module re-derives the three roofline inputs
from `compiled.as_text()` with `known_trip_count` scaling:

  flops   — 2 * prod(result dims) * prod(contracting dims) per dot op
            (matmuls dominate; elementwise flops are ignored, consistent
            with MXU rooflines)
  bytes   — per executed op: result bytes + operand bytes (each optimized-
            HLO op line is an execution unit on the target; tuples /
            bitcasts / parameters / constants excluded)
  wire    — collective result bytes with ring-algorithm factors
            (see hlo_analysis._WIRE_FACTOR)

While bodies and conditions are multiplied by their known_trip_count;
fusion-called computations are charged through the fusion op itself
(not double-counted); scalar `to_apply` reducers are ignored.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from .hlo_analysis import _DTYPE_BYTES, _WIRE_FACTOR, _group_size

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*{")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(?P<rtype>\([^)]*\)|[^\s]+)\s+"
    r"(?P<kind>[\w\-]+)\(")
_ARRAY = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_TRIP = re.compile(r'known_trip_count[": {]+n[": ]+(\d+)')
_CALLS = re.compile(r"(?:calls|body)=%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")
_OPERANDS = re.compile(r"%([\w\.\-]+)")
_PARAM = re.compile(r"%?([\w\.\-]+):\s*(\([^)]*\)|[^\s,)]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_SKIP_KINDS = {"tuple", "get-tuple-element", "parameter", "constant",
               "bitcast", "after-all", "partition-id", "replica-id"}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _arrays(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _ARRAY.finditer(type_str):
        if m.group(1) not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((m.group(1), dims))
    return out


def _nbytes(type_str: str) -> int:
    total = 0
    for dt, dims in _arrays(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class OpRec:
    kind: str
    rtype: str
    line: str
    operands: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[OpRec]
    symbols: Dict[str, str]          # %name -> type string


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if cur is None:
            m = _COMP_HDR.match(line)
            if m:
                cur = Computation(m.group(1), [], {})
                # parameters into the symbol table
                hdr_args = line[line.find("(") + 1:line.rfind(") ->")]
                for pm in _PARAM.finditer(hdr_args):
                    cur.symbols[pm.group(1)] = pm.group(2)
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        name, rtype, kind = m.group(1), m.group("rtype"), m.group("kind")
        cur.symbols[name] = rtype
        paren = line.find(f"{kind}(") + len(kind) + 1
        depth = 1
        j = paren
        while j < len(line) and depth > 0:
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
            j += 1
        operands = _OPERANDS.findall(line[paren:j - 1])
        cur.ops.append(OpRec(kind=kind, rtype=rtype, line=line,
                             operands=operands))
    return comps


def _dot_flops(op: OpRec, comp: Computation) -> float:
    res = _arrays(op.rtype)
    if not res:
        return 0.0
    rn = 1
    for d in res[0][1]:
        rn *= d
    cm = _CONTRACT.search(op.line)
    contract = 1
    if cm and op.operands:
        lhs_t = comp.symbols.get(op.operands[0], "")
        lhs = _arrays(lhs_t)
        if lhs:
            dims = lhs[0][1]
            for idx in (int(x) for x in cm.group(1).split(",") if x):
                if idx < len(dims):
                    contract *= dims[idx]
    return 2.0 * rn * contract


def _op_bytes_fusion(op: OpRec, comp: Computation,
                     comps: Dict[str, Computation]) -> float:
    """Bytes for a fusion op: result + operands, except
      * parameters whose only internal use is dynamic-slice are charged at
        the slice size (a loop body reads one step of a stacked array),
      * a parameter that is only the in-place target of the root
        dynamic-update-slice is not re-read,
      * a dynamic-update-slice root writes its update, not the buffer."""
    m = _CALLS.search(op.line)
    inner = comps.get(m.group(1)) if m else None
    if inner is None:
        b = _nbytes(op.rtype)
        for o in op.operands:
            t = comp.symbols.get(o)
            if t:
                b += _nbytes(t)
        return b

    header_params = [n for n in inner.symbols if n.startswith("param_")]
    uses: Dict[str, list] = {pn: [] for pn in header_params}
    for iop in inner.ops:
        for o in iop.operands:
            if o in uses:
                uses[o].append(iop)

    root = inner.ops[-1] if inner.ops else None
    res = _nbytes(op.rtype)
    if root is not None and root.kind == "dynamic-update-slice" \
            and len(root.operands) > 1:
        res = 2.0 * _nbytes(inner.symbols.get(root.operands[1], ""))

    b = res
    for i, o in enumerate(op.operands):
        t = comp.symbols.get(o)
        if t is None or i >= len(header_params):
            if t:
                b += _nbytes(t)
            continue
        pn = header_params[i]
        consumers = uses.get(pn, [])
        kinds = {c.kind for c in consumers}
        if consumers and kinds == {"dynamic-slice"}:
            b += sum(_nbytes(c.rtype) for c in consumers)
        elif (root is not None and root.kind == "dynamic-update-slice"
              and consumers == [root] and root.operands
              and root.operands[0] == pn):
            pass  # in-place DUS target: not re-read
        else:
            b += _nbytes(t)
    return b


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    wire_bytes: float = 0.0
    coll_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)
    n_while: int = 0
    unknown_trip: int = 0

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.wire_bytes += o.wire_bytes
        for k, v in o.coll_by_op.items():
            self.coll_by_op[k] = self.coll_by_op.get(k, 0.0) + v
        self.n_while += o.n_while
        self.unknown_trip += o.unknown_trip
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes * f, self.wire_bytes * f,
                    {k: v * f for k, v in self.coll_by_op.items()},
                    self.n_while, self.unknown_trip)


def _cost_of(comp_name: str, comps: Dict[str, Computation],
             ndev: int, memo: Dict[str, Cost]) -> Cost:
    if comp_name in memo:
        return memo[comp_name]
    memo[comp_name] = Cost()            # cycle guard
    comp = comps.get(comp_name)
    if comp is None:
        return memo[comp_name]
    total = Cost()
    for op in comp.ops:
        k = op.kind
        if k in _SKIP_KINDS:
            continue
        if k == "while":
            trip = 1
            m = _TRIP.search(op.line)
            unknown = 0
            if m:
                trip = int(m.group(1))
            else:
                unknown = 1
            sub = Cost()
            bm = _CALLS.search(op.line)
            if bm:
                sub += _cost_of(bm.group(1), comps, ndev, memo)
            cm = _COND.search(op.line)
            if cm:
                sub += _cost_of(cm.group(1), comps, ndev, memo)
            sub = sub.scaled(trip)
            sub.n_while += 1
            sub.unknown_trip += unknown
            total += sub
            continue
        if k in ("call", "conditional"):
            bm = _CALLS.search(op.line)
            if bm:
                total += _cost_of(bm.group(1), comps, ndev, memo)
            continue
        # leaf op: bytes (result + operands).  Slicing ops are charged at
        # slice granularity — a loop body that dynamic-slices one step out
        # of a stacked array reads the SLICE, not the whole array, and a
        # dynamic-update-slice writes in place (tests/test_hlo_cost.py).
        flops = 0.0
        if k == "dynamic-slice":
            b = 2.0 * _nbytes(op.rtype)
        elif k == "dynamic-update-slice":
            upd = (comp.symbols.get(op.operands[1], "")
                   if len(op.operands) > 1 else "")
            b = 2.0 * _nbytes(upd)
        elif k == "fusion":
            b = _op_bytes_fusion(op, comp, comps)
            bm = _CALLS.search(op.line)
            if bm:
                inner = comps.get(bm.group(1))
                if inner:
                    for iop in inner.ops:
                        if iop.kind == "dot":
                            flops += _dot_flops(iop, inner)
        else:
            b = _nbytes(op.rtype)
            for o in op.operands:
                t = comp.symbols.get(o)
                if t:
                    b += _nbytes(t)
        if k == "dot":
            flops = _dot_flops(op, comp)
        base = k.split("-start")[0]
        wire = 0.0
        coll = {}
        if base in _COLLECTIVES:
            g = _group_size(op.line, ndev)
            wire = _nbytes(op.rtype) * _WIRE_FACTOR[base](max(g, 1))
            coll = {base: wire}
        c = Cost(flops=flops, bytes=b, wire_bytes=wire, coll_by_op=coll)
        total += c
    memo[comp_name] = total
    return total


def analyze(hlo_text: str, ndev: int) -> Cost:
    comps = parse_computations(hlo_text)
    # exclude computations only reachable via fusion `calls=` from the
    # entry walk — _cost_of only recurses through while/call/conditional,
    # so that's automatic.  Find the entry computation:
    entry = None
    for raw in hlo_text.splitlines():
        if raw.startswith("ENTRY"):
            m = _COMP_HDR.match(raw.strip())
            if m:
                entry = m.group(1)
                break
    if entry is None:
        # fall back to the last computation
        entry = list(comps)[-1] if comps else ""
    return _cost_of(entry, comps, ndev, {})
