"""Fault-tolerant checkpointing for (params, EF state, optimizer, cursor).

Format: one compressed msgpack-framed .npz-style file per step (zstd when
the optional `zstandard` package is installed, raw bytes otherwise — the
header records the codec so files restore across environments), written
atomically (tmp + rename) so a crash mid-write never corrupts the latest
checkpoint.  The data cursor is just the step counter (the synthetic
pipeline is counter-addressable, repro.data.pipeline), so restart resumes
exactly.

Elasticity: COCO-EF's per-rank error vectors are tied to the coding-rank
count N.  `elastic_rescale_ef` maps an EF state saved at N_old onto N_new
ranks — kept ranks carry their error, new ranks start at e=0 (Theorem 1 is
invariant to e_i^0 = 0 re-initialization; DESIGN.md Sec. 5).
"""
from __future__ import annotations

import io
import json
import os
import struct
import tempfile
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

try:  # optional: fall back to an uncompressed payload codec without it
    import zstandard
except ModuleNotFoundError:
    zstandard = None

MAGIC = b"RPR1"


def _encode_payload(payload: bytes) -> tuple:
    """-> (codec_name, wire_bytes).  zstd when available, raw otherwise."""
    if zstandard is not None:
        return "zstd", zstandard.ZstdCompressor(level=3).compress(payload)
    return "raw", payload


def _decode_payload(codec: str, blob: bytes) -> bytes:
    if codec == "raw":
        return blob
    if codec == "zstd":
        if zstandard is None:
            raise ModuleNotFoundError(
                "checkpoint was written with the zstd codec but the "
                "'zstandard' package is not installed; pip install zstandard "
                "to restore it")
        return zstandard.ZstdDecompressor().decompress(blob)
    raise ValueError(f"unknown checkpoint codec {codec!r}")


def _tree_to_bufs(tree) -> Tuple[Dict, list]:
    leaves, treedef = jax.tree.flatten(tree)
    metas = []
    bufs = []
    for leaf in leaves:
        arr = np.asarray(leaf)
        metas.append({"shape": list(arr.shape), "dtype": str(arr.dtype)})
        bufs.append(arr.tobytes())
    return {"leaves": metas, "treedef": str(treedef)}, bufs


def save_checkpoint(directory: str | Path, step: int, state: Dict[str, Any],
                    extra: Optional[Dict] = None) -> Path:
    """state: arbitrary pytree dict, e.g. {params, e, opt}.  Atomic."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    trees = {}
    blobs = []
    for name, tree in state.items():
        meta, bufs = _tree_to_bufs(tree)
        meta["offsets"] = []
        for b in bufs:
            meta["offsets"].append(sum(len(x) for x in blobs))
            blobs.append(b)
        trees[name] = meta
    payload = b"".join(blobs)
    codec, comp = _encode_payload(payload)
    header = json.dumps({"step": int(step), "trees": trees,
                         "codec": codec, "extra": extra or {}}).encode()
    final = directory / f"ckpt_{step:010d}.rpr"
    with tempfile.NamedTemporaryFile(dir=directory, delete=False) as tmp:
        tmp.write(MAGIC)
        tmp.write(struct.pack("<QQ", len(header), len(comp)))
        tmp.write(header)
        tmp.write(comp)
        tmp.flush()
        os.fsync(tmp.fileno())
        tmp_path = tmp.name
    os.replace(tmp_path, final)               # atomic on POSIX
    return final


def latest_step(directory: str | Path) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [int(p.stem.split("_")[1]) for p in directory.glob("ckpt_*.rpr")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str | Path, templates: Dict[str, Any],
                       step: Optional[int] = None,
                       shardings: Optional[Dict[str, Any]] = None
                       ) -> Tuple[int, Dict[str, Any]]:
    """templates: {name: pytree} giving structure; arrays are re-created
    (and device_put with `shardings[name]` pytrees when given)."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = directory / f"ckpt_{step:010d}.rpr"
    raw = path.read_bytes()
    assert raw[:4] == MAGIC, "corrupt checkpoint"
    hlen, clen = struct.unpack("<QQ", raw[4:20])
    header = json.loads(raw[20:20 + hlen])
    payload = _decode_payload(header.get("codec", "zstd"),
                              raw[20 + hlen:20 + hlen + clen])

    out = {}
    for name, template in templates.items():
        meta = header["trees"][name]
        leaves_t, treedef = jax.tree.flatten(template)
        arrs = []
        for lm, off, lt in zip(meta["leaves"], meta["offsets"], leaves_t):
            n = int(np.prod(lm["shape"])) if lm["shape"] else 1
            a = np.frombuffer(payload, dtype=np.dtype(lm["dtype"]),
                              count=n, offset=off).reshape(lm["shape"])
            arrs.append(a)
        tree = jax.tree.unflatten(treedef, arrs)
        if shardings and name in shardings:
            tree = jax.tree.map(jax.device_put, tree, shardings[name])
        out[name] = tree
    return header["step"], out


def elastic_rescale_ef(e_old: np.ndarray, mesh_shape_old: Tuple[int, ...],
                       mesh_shape_new: Tuple[int, ...],
                       flat_pad_new: int) -> np.ndarray:
    """Map EF state (devices..., flat) across a device-count change.

    Coding ranks present in both grids keep their error vectors (truncated /
    zero-padded to the new local flat size); new ranks start at zero.  The
    sum over surviving e_i is preserved for surviving ranks, which is what
    the virtual-sequence argument (Appendix C) needs.
    """
    e_old = np.asarray(e_old)
    old_flat = e_old.shape[-1]
    new = np.zeros(tuple(mesh_shape_new) + (flat_pad_new,), e_old.dtype)
    common = tuple(min(a, b) for a, b in zip(mesh_shape_old, mesh_shape_new))
    sl_old = tuple(slice(0, c) for c in common)
    sl_new = tuple(slice(0, c) for c in common)
    m = min(old_flat, flat_pad_new)
    new[sl_new + (slice(0, m),)] = e_old[sl_old + (slice(0, m),)]
    return new
