from .checkpoint import (save_checkpoint, restore_checkpoint,
                         latest_step, elastic_rescale_ef)  # noqa: F401
