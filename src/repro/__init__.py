"""repro: COCO-EF (biased compression in gradient coding) as a JAX framework."""
__version__ = "0.1.0"
