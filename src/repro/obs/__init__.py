"""Step-level telemetry plane (PR 8): in-graph metrics, span tracing, and
Chrome-trace timeline export for the train, sim, and serve paths.

  metrics       MetricsFrame pytree built inside the jitted step (no host
                callbacks, no extra collectives) + grid reduction helpers
  logger        MetricsLogger JSONL sink (schema repro.obs/v1), EWMA
                per-rank participation rates, record validation
  tracing       jax.named_scope re-export + host-side SpanRecorder
  trace_export  Chrome-trace JSON for measured spans and simulated
                sim.StepTimer schedules (serial + pipelined buckets)
  serving       ServeTelemetry: queue wait + prefill/decode p50/p99

See src/repro/obs/README.md for the JSONL schema.
"""
from .logger import MetricsLogger, SCHEMA, read_jsonl, validate_record
from .metrics import (MetricsFrame, frame_out_specs, frame_to_host, norm_sq,
                      reduce_frame_grid)
from .serving import RequestRecord, ServeTelemetry
from .trace_export import (chrome_trace, span_events, steptimer_timeline,
                           validate_chrome_trace, write_chrome_trace)
from .tracing import SpanRecorder, scope

__all__ = [
    "MetricsFrame", "frame_out_specs", "frame_to_host", "norm_sq",
    "reduce_frame_grid",
    "MetricsLogger", "SCHEMA", "read_jsonl", "validate_record",
    "SpanRecorder", "scope",
    "chrome_trace", "span_events", "steptimer_timeline",
    "validate_chrome_trace", "write_chrome_trace",
    "ServeTelemetry", "RequestRecord",
]
