"""Host-side metrics sink: schema-versioned JSONL + rolling aggregates.

`MetricsLogger` drains reduced `MetricsFrame`s (see `repro.obs.metrics`)
OUTSIDE the jit boundary into an append-only JSONL file.  Every line is a
self-describing record carrying `schema` + `kind`; `validate_record`
enforces the per-kind required fields (the CI metrics-smoke job and the
tests run every emitted line through it).

Record kinds (schema `repro.obs/v1`):

  run_meta      {"meta": {...}}                — provenance, first line
                (git sha / jax version / knobs via
                `benchmarks._repro_common.run_metadata`)
  train_step    per-step telemetry: the reduced frame fields
                (participation, wire_bytes_rank, norms, cosine, ...) plus
                "step", "t_wall_s", "ewma_participation" and optional
                host-span durations under "spans"
  serve_request one served request (queue wait / prefill / decode)
  serve_summary latency histogram summary (p50/p99, queue wait)
  prefetch      a `data.pipeline.PrefetchStats` snapshot
  replan        one coding-plane control tick (`CodingPlan.maybe_replan`):
                epoch / drift / reallocated / rates_estimate

The logger also maintains the bias-corrected per-rank EWMA participation
rates over the observed masks — the online rate estimate ROADMAP item 4
needs as input (`MetricsLogger.rates` feeds
`core.coding_state.CodingPlan.maybe_replan`, which refits
`coding.encode_weights` and re-allocates on drift).  The correction is
implemented inline (not via `core.coding_state.RateEstimator`) because
`repro.core` imports `repro.obs`; a test pins the two implementations to
bit-identical outputs.
"""
from __future__ import annotations

import json
import numbers
import os
import time
from typing import Dict, IO, Iterable, List, Optional

import numpy as np

__all__ = ["SCHEMA", "MetricsLogger", "validate_record", "read_jsonl"]

SCHEMA = "repro.obs/v1"

_KINDS = ("run_meta", "train_step", "serve_request", "serve_summary",
          "prefetch", "replan")

# required per-kind fields and their coarse types (beyond schema/kind)
_REQUIRED = {
    "run_meta": {"meta": dict},
    "train_step": {"step": numbers.Number, "t_wall_s": numbers.Number,
                   "participation": list, "participants": numbers.Number,
                   "wire_bytes_rank": list, "bytes_up_total": numbers.Number,
                   "bytes_down": numbers.Number,
                   "ewma_participation": list,
                   "grad_norm_rank": list, "ef_norm_rank": list,
                   "compress_cosine_rank": list,
                   "compress_contraction_rank": list,
                   "ghat_norm": numbers.Number,
                   "update_norm": numbers.Number},
    "serve_request": {"request_id": numbers.Number,
                      "queue_wait_s": numbers.Number,
                      "prefill_s": numbers.Number,
                      "decode_s": numbers.Number,
                      "tokens": numbers.Number},
    "serve_summary": {"requests": numbers.Number,
                      "queue_wait_ms": dict, "prefill_ms": dict,
                      "decode_token_ms": dict},
    "prefetch": {"stats": dict},
    "replan": {"step": numbers.Number, "epoch": numbers.Number,
               "drift": numbers.Number, "reallocated": bool,
               "rates_estimate": list},
}

_HIST_KEYS = ("p50", "p99", "mean", "count")


def validate_record(rec: dict) -> None:
    """Raise ValueError unless `rec` is a well-formed schema-v1 record."""
    if not isinstance(rec, dict):
        raise ValueError(f"record must be a dict, got {type(rec).__name__}")
    if rec.get("schema") != SCHEMA:
        raise ValueError(f"record schema {rec.get('schema')!r} != {SCHEMA!r}")
    kind = rec.get("kind")
    if kind not in _KINDS:
        raise ValueError(f"unknown record kind {kind!r}; have {_KINDS}")
    for field, typ in _REQUIRED[kind].items():
        if field not in rec:
            raise ValueError(f"{kind} record missing field {field!r}")
        if not isinstance(rec[field], typ):
            raise ValueError(
                f"{kind}.{field} must be {typ.__name__}, got "
                f"{type(rec[field]).__name__}")
    if kind == "train_step":
        n = len(rec["participation"])
        for field in ("wire_bytes_rank", "ewma_participation",
                      "grad_norm_rank", "ef_norm_rank",
                      "compress_cosine_rank", "compress_contraction_rank"):
            if len(rec[field]) != n:
                raise ValueError(f"train_step.{field} has "
                                 f"{len(rec[field])} entries, expected {n}")
    if kind == "serve_summary":
        for field in ("queue_wait_ms", "prefill_ms", "decode_token_ms"):
            missing = [k for k in _HIST_KEYS if k not in rec[field]]
            if missing:
                raise ValueError(f"serve_summary.{field} missing "
                                 f"histogram keys {missing}")


def read_jsonl(path: str) -> List[dict]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def _to_plain(v):
    a = np.asarray(v)
    if a.dtype == object:
        return v
    return a.tolist() if a.ndim else float(a)


class MetricsLogger:
    """Append-only JSONL sink + EWMA participation aggregator.

    ewma_alpha: weight of the newest mask in the per-rank participation
    EWMA (`rates`), the online estimate of q_i = P[rank i participates].
    Every record is validated before it is written, so a schema drift
    fails at the producer, not in some later reader.
    """

    def __init__(self, path: str, *, run_metadata: Optional[dict] = None,
                 ewma_alpha: float = 0.1):
        if not (0.0 < ewma_alpha <= 1.0):
            raise ValueError(f"ewma_alpha={ewma_alpha} must be in (0, 1]")
        self.path = path
        self.ewma_alpha = float(ewma_alpha)
        self._ewma: Optional[np.ndarray] = None
        self._steps = 0
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f: Optional[IO[str]] = open(path, "w")
        if run_metadata is not None:
            self.write({"kind": "run_meta", "meta": dict(run_metadata)})

    # ---- low-level ---------------------------------------------------------

    def write(self, rec: dict) -> dict:
        """Stamp schema, validate, append one JSONL line; returns the
        record as written."""
        rec = {"schema": SCHEMA, **rec}
        validate_record(rec)
        if self._f is None:
            raise ValueError(f"MetricsLogger({self.path}) is closed")
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()
        return rec

    # ---- train path --------------------------------------------------------

    def log_step(self, step: int, telemetry: Dict[str, object],
                 loss: Optional[float] = None,
                 spans: Optional[Dict[str, float]] = None,
                 t_wall_s: Optional[float] = None) -> dict:
        """One reduced `MetricsFrame` (see `metrics.reduce_frame_grid`) ->
        one train_step record; updates the participation EWMA."""
        tel = {k: _to_plain(v) for k, v in telemetry.items()}
        mask = np.asarray(tel["participation"], np.float64)
        a = self.ewma_alpha
        if self._ewma is None:
            self._ewma = np.zeros_like(mask)
        # zero-init accumulator + Adam-style bias correction (divide by
        # 1 - (1-a)^t): the reported estimate is an exact weighted average
        # of the masks seen so far.  Seeding from the first mask instead
        # left early estimates dominated by step-0 noise for ~1/a steps.
        self._ewma = (1.0 - a) * self._ewma + a * mask
        self._steps += 1
        rec = {"kind": "train_step", "step": int(step),
               "t_wall_s": float(t_wall_s if t_wall_s is not None
                                 else time.time()),
               "ewma_participation": self._corrected().tolist(), **tel}
        if loss is not None:
            rec["loss"] = float(loss)
        if spans:
            rec["spans"] = {k: float(v) for k, v in spans.items()}
        return self.write(rec)

    def _corrected(self) -> np.ndarray:
        # np.power, NOT python **: the two differ in the last ulp and this
        # must match core.coding_state.RateEstimator bit-for-bit
        corr = 1.0 - np.power(1.0 - self.ewma_alpha, float(self._steps))
        return self._ewma / corr

    @property
    def rates(self) -> Optional[np.ndarray]:
        """(N,) bias-corrected EWMA per-rank participation rates over the
        logged steps — the online q_i estimate that feeds
        `core.coding_state.CodingPlan.maybe_replan` (ROADMAP item 4).
        None before the first step."""
        return None if self._ewma is None else self._corrected()

    def log_replan(self, step: int, info: Dict[str, object]) -> dict:
        """One `CodingPlan.maybe_replan` host event -> a replan record
        (epoch / drift / reallocated / rates_estimate)."""
        return self.write({"kind": "replan", "step": int(step),
                           "epoch": int(info["epoch"]),
                           "drift": float(info["drift"]),
                           "reallocated": bool(info["reallocated"]),
                           "rates_estimate":
                               [float(x) for x in info["rates_estimate"]]})

    @property
    def steps_logged(self) -> int:
        return self._steps

    # ---- other planes ------------------------------------------------------

    def log_prefetch(self, stats: Dict[str, object]) -> dict:
        return self.write({"kind": "prefetch", "stats": dict(stats)})

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self) -> "MetricsLogger":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def percentiles_ms(samples_s: Iterable[float]) -> Dict[str, float]:
    """Latency histogram summary in milliseconds: p50/p99/mean/count
    (the serve_summary building block)."""
    xs = np.asarray(list(samples_s), np.float64) * 1e3
    if xs.size == 0:
        return {"p50": 0.0, "p99": 0.0, "mean": 0.0, "count": 0}
    return {"p50": float(np.percentile(xs, 50)),
            "p99": float(np.percentile(xs, 99)),
            "mean": float(xs.mean()), "count": int(xs.size)}
