"""Serve-plane telemetry: per-request queue wait + latency histograms.

`ServeTelemetry` is the host-side sink for the serving path
(`repro.launch.serve.instrument_steps` feeds it): per-call prefill and
per-token decode latencies (measured around the blocking jitted step),
plus per-request queue wait recorded by the request loop.  Summaries are
p50/p99/mean histograms (`repro.obs.logger.percentiles_ms`), emitted as
schema-validated `serve_summary` / `serve_request` JSONL records, and the
underlying spans render to the same Chrome-trace JSON as the train plane
(`repro.obs.trace_export`).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from .logger import percentiles_ms
from .tracing import SpanRecorder

__all__ = ["ServeTelemetry", "RequestRecord"]


@dataclasses.dataclass(frozen=True)
class RequestRecord:
    request_id: int
    queue_wait_s: float
    prefill_s: float
    decode_s: float
    tokens: int

    def to_record(self) -> dict:
        return {"kind": "serve_request", "request_id": int(self.request_id),
                "queue_wait_s": float(self.queue_wait_s),
                "prefill_s": float(self.prefill_s),
                "decode_s": float(self.decode_s),
                "tokens": int(self.tokens)}


class ServeTelemetry:
    """Latency samples + spans for one serving session.

    prefill_s:      one sample per prefill call (blocking wall clock)
    decode_token_s: one sample per decode step (one generated token)
    queue_wait_s:   one sample per request (arrival -> service start)
    """

    def __init__(self, recorder: Optional[SpanRecorder] = None):
        self.recorder = recorder or SpanRecorder()
        self.prefill_s: List[float] = []
        self.decode_token_s: List[float] = []
        self.queue_wait_s: List[float] = []
        self.requests: List[RequestRecord] = []

    # ---- samples (instrument_steps feeds the first two) --------------------

    def add_prefill(self, seconds: float) -> None:
        self.prefill_s.append(float(seconds))

    def add_decode_token(self, seconds: float) -> None:
        self.decode_token_s.append(float(seconds))

    def add_request(self, request_id: int, queue_wait_s: float,
                    prefill_s: float, decode_s: float, tokens: int
                    ) -> RequestRecord:
        """One completed request (the loop computes queue wait = service
        start - arrival).  Does NOT re-add prefill/decode samples — those
        arrive per call via the instrumented steps."""
        rec = RequestRecord(request_id, queue_wait_s, prefill_s, decode_s,
                            tokens)
        self.queue_wait_s.append(float(queue_wait_s))
        self.requests.append(rec)
        return rec

    # ---- reporting ---------------------------------------------------------

    def summary(self) -> dict:
        """A `serve_summary` record body (validated by the logger)."""
        return {"kind": "serve_summary", "requests": len(self.requests),
                "queue_wait_ms": percentiles_ms(self.queue_wait_s),
                "prefill_ms": percentiles_ms(self.prefill_s),
                "decode_token_ms": percentiles_ms(self.decode_token_s)}

    def request_records(self) -> List[dict]:
        return [r.to_record() for r in self.requests]

    def log_to(self, logger) -> dict:
        """Write every per-request record + the summary to a
        `MetricsLogger`; returns the summary record."""
        for rec in self.request_records():
            logger.write(rec)
        return logger.write(self.summary())

    def format_summary(self) -> str:
        s = self.summary()

        def one(name, h):
            return (f"{name}: p50={h['p50']:.2f}ms p99={h['p99']:.2f}ms "
                    f"mean={h['mean']:.2f}ms n={h['count']}")
        return "\n".join([
            f"serve telemetry over {s['requests']} request(s)",
            "  " + one("queue_wait  ", s["queue_wait_ms"]),
            "  " + one("prefill     ", s["prefill_ms"]),
            "  " + one("decode/token", s["decode_token_ms"])])
