"""In-graph telemetry frame for the coded train step.

`MetricsFrame` is a pytree of small per-device arrays produced INSIDE the
jit/shard_map scope (no host callbacks, no extra collectives) by
`repro.core.cocoef.cocoef_update(..., want_metrics=True)` and
`repro.optim.apply_update(..., want_norms=True)`:

  participation     (N,)  the straggler mask I^t (replicated on every device)
  wire_bytes_rank   (N,)  phase-1 bytes ACTUALLY sent per coding rank this
                          step: mask_i * wire.rank_wire_bytes(n)[i], summed
                          over buckets — the same per-rank accounting
                          `sim.StepTimer.bytes_up_ranks` prices and
                          `benchmarks/comm_volume.audit_wire_bytes` audits
  bucket_wire_bytes (B,)  THIS rank's shipped bytes per bucket (x its mask)
  bytes_down        ()    phase-2 broadcast bytes received per rank
  grad_norm_sq      ()    |g_local|^2 of this device's flat gradient slice
  ef_norm_sq        ()    |e_new|^2 — the error vector AFTER the update
  acc_norm_sq       ()    |gamma*g + e|^2 (the compressor input)
  c_norm_sq         ()    |C(acc)|^2 (the transmitted reconstruction)
  acc_dot_c         ()    <acc, C(acc)> — with the two norms this gives the
                          compressed-vs-raw cosine and the contraction
                          |acc - C(acc)|^2 / |acc|^2 (the delta of
                          Assumption 5, the paper's bias proxy)
  ghat_norm_sq      ()    |ghat_local|^2 of the aggregated update slice
  update_norm_sq    ()    |theta_new - theta|^2 (optimizer, incl. decay)
  param_norm_sq     ()    |theta_new|^2

Scalar leaves are DEVICE-LOCAL partial sums over that device's slice of
the flat vector; `reduce_frame_grid` turns the (mesh-grid)-shaped output
of the aggregation shard_map into per-coding-rank / global quantities on
which the host-side `repro.obs.logger.MetricsLogger` operates.

This module deliberately imports nothing from `repro.core` (the core
imports it), and every helper is shape-static so the frame is safe to
return from a shard_map without adding communication.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["MetricsFrame", "norm_sq", "frame_out_specs", "reduce_frame_grid"]


def norm_sq(x: jnp.ndarray) -> jnp.ndarray:
    """Sum of squares in f32 (the frame's scalar accumulator)."""
    xf = x.astype(jnp.float32)
    return jnp.sum(xf * xf)


@dataclasses.dataclass
class MetricsFrame:
    """One step's in-graph telemetry (see module docstring for fields)."""

    participation: jnp.ndarray        # (N,) f32
    wire_bytes_rank: jnp.ndarray      # (N,) f32
    bucket_wire_bytes: jnp.ndarray    # (B,) f32
    bytes_down: jnp.ndarray           # ()  f32
    grad_norm_sq: jnp.ndarray         # ()  f32
    ef_norm_sq: jnp.ndarray           # ()  f32
    acc_norm_sq: jnp.ndarray          # ()  f32
    c_norm_sq: jnp.ndarray            # ()  f32
    acc_dot_c: jnp.ndarray            # ()  f32
    ghat_norm_sq: jnp.ndarray         # ()  f32
    update_norm_sq: jnp.ndarray       # ()  f32
    param_norm_sq: jnp.ndarray        # ()  f32

    def replace(self, **kw) -> "MetricsFrame":
        return dataclasses.replace(self, **kw)

    @classmethod
    def abstract(cls, n_ranks: int, num_buckets: int) -> "MetricsFrame":
        """ShapeDtypeStruct skeleton (builds shard_map out_specs)."""
        f32 = jnp.float32
        s = jax.ShapeDtypeStruct
        return cls(
            participation=s((n_ranks,), f32),
            wire_bytes_rank=s((n_ranks,), f32),
            bucket_wire_bytes=s((num_buckets,), f32),
            bytes_down=s((), f32),
            grad_norm_sq=s((), f32), ef_norm_sq=s((), f32),
            acc_norm_sq=s((), f32), c_norm_sq=s((), f32),
            acc_dot_c=s((), f32), ghat_norm_sq=s((), f32),
            update_norm_sq=s((), f32), param_norm_sq=s((), f32))


jax.tree_util.register_dataclass(
    MetricsFrame,
    data_fields=[f.name for f in dataclasses.fields(MetricsFrame)],
    meta_fields=[])


# How each field aggregates across the device grid (reduce_frame_grid):
#   corner     identical on every device -> take grid corner
#   rank_sum   per-device partial sum    -> sum over non-coding axes
#              (one total per coding rank)
#   rank_vec   per-coding-rank vector, replicated over non-coding axes
#   repl_mean  per-device partial, replicated across coding ranks after the
#              collective -> sum over non-coding axes, mean over coding
_CORNER = ("participation", "wire_bytes_rank", "bytes_down")
_RANK_SUM = ("grad_norm_sq", "ef_norm_sq", "acc_norm_sq", "c_norm_sq",
             "acc_dot_c")
_RANK_VEC = ("bucket_wire_bytes",)
_REPL_MEAN = ("ghat_norm_sq", "update_norm_sq", "param_norm_sq")


def frame_out_specs(frame_abs: MetricsFrame, axis_names: Sequence[str]):
    """shard_map out_specs for a frame whose leaves were reshaped to
    (1,)*len(axis_names) + leaf.shape inside the body (the same idiom the
    train step uses for its per-device gnorm scalar)."""
    from jax.sharding import PartitionSpec as P
    return jax.tree.map(
        lambda l: P(*axis_names, *([None] * l.ndim)), frame_abs)


def reduce_frame_grid(frame: MetricsFrame, mesh_axis_names: Sequence[str],
                      coding_axes: Sequence[str]
                      ) -> Dict[str, jnp.ndarray]:
    """Grid-shaped frame (every leaf leading with the mesh shape, as
    returned by the aggregation shard_map) -> host-friendly step metrics.

    Per-rank entries are ordered by `cocoef.coding_rank_index` (row-major
    over `coding_axes` in the order given).  Runs OUTSIDE the shard_map
    (plain jit or eager) — reductions here are over the replicated grid
    output, never over the mesh, so metrics add no collectives.
    """
    names = tuple(mesh_axis_names)
    m = len(names)
    code_pos = [names.index(a) for a in coding_axes]
    other_pos = [i for i in range(m) if i not in code_pos]
    # byte counters are computed per DEVICE from its local flat slice; a
    # coding rank spans every non-coding (tp/fsdp) mesh position, so rank
    # totals scale by that grid size (1 on a pure coding mesh)
    grid = frame.bytes_down.shape
    shards = int(np.prod([grid[i] for i in other_pos])) if other_pos else 1

    def corner(leaf):
        return leaf[(0,) * m]

    def rank_sum(leaf):                       # (mesh...,) -> (N,)
        t = jnp.transpose(leaf, code_pos + other_pos)
        t = t.sum(axis=tuple(range(len(code_pos), m)))
        return t.reshape(-1)

    def rank_vec(leaf):                       # (mesh..., k) -> (N, k)
        t = jnp.transpose(leaf, code_pos + other_pos + [m])
        t = t[(slice(None),) * len(code_pos) + (0,) * len(other_pos)]
        return t.reshape((-1,) + leaf.shape[m:])

    def repl_mean(leaf):                      # (mesh...,) -> ()
        r = rank_sum(leaf)
        return r.mean()

    def safe_div(a, b):
        return a / jnp.where(b == 0, 1.0, b)

    participation = corner(frame.participation)
    wire_bytes_rank = corner(frame.wire_bytes_rank) * shards
    acc_sq = rank_sum(frame.acc_norm_sq)
    c_sq = rank_sum(frame.c_norm_sq)
    dot = rank_sum(frame.acc_dot_c)
    out = {
        "participation": participation,
        "participants": participation.sum(),
        "wire_bytes_rank": wire_bytes_rank,
        "bytes_up_total": wire_bytes_rank.sum(),
        "bucket_wire_bytes_rank": rank_vec(frame.bucket_wire_bytes) * shards,
        "bytes_down": corner(frame.bytes_down) * shards,
        "grad_norm_rank": jnp.sqrt(rank_sum(frame.grad_norm_sq)),
        "ef_norm_rank": jnp.sqrt(rank_sum(frame.ef_norm_sq)),
        # compressed-vs-raw cosine and EF contraction |acc-c|^2/|acc|^2
        # per coding rank (all-zero acc reports cosine 0, contraction 0)
        "compress_cosine_rank": safe_div(dot, jnp.sqrt(acc_sq) *
                                         jnp.sqrt(c_sq)),
        "compress_contraction_rank": safe_div(acc_sq + c_sq - 2.0 * dot,
                                              acc_sq),
        "ghat_norm": jnp.sqrt(repl_mean(frame.ghat_norm_sq)),
        "update_norm": jnp.sqrt(repl_mean(frame.update_norm_sq)),
        "param_norm": jnp.sqrt(repl_mean(frame.param_norm_sq)),
    }
    return out


def frame_to_host(reduced: Dict[str, jnp.ndarray]) -> Dict[str, object]:
    """Device -> plain-python (lists/floats) for JSONL logging."""
    out = {}
    for k, v in reduced.items():
        a = np.asarray(v)
        out[k] = a.tolist() if a.ndim else float(a)
    return out
