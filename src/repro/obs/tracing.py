"""Span tracing: in-graph named scopes + host wall-clock span timers.

Two complementary planes:

  * Device plane — `scope(name)` is `jax.named_scope`: zero-cost HLO op
    metadata so profiler dumps (and `jax.profiler.trace`) show the
    pack / all_to_all / decode-reduce / optimizer phases of the coded
    step.  The scopes are applied unconditionally on the hot path — they
    change op *names* only, never the computation.

  * Host plane — `SpanRecorder` measures the phases jit cannot see:
    batch wait, prefetch queue occupancy, device put, step dispatch, the
    blocking result fetch.  Each `span()` also enters a
    `jax.profiler.TraceAnnotation` so host spans line up with device
    traces when the profiler is on.  Spans render to Chrome-trace JSON
    via `repro.obs.trace_export.chrome_trace`.
"""
from __future__ import annotations

import contextlib
import time
from typing import Dict, List, Optional

import jax

__all__ = ["scope", "SpanRecorder"]

# in-graph phase annotation (op-metadata only; safe inside jit/shard_map)
scope = jax.named_scope


class SpanRecorder:
    """Wall-clock host spans + counter samples for one run.

    spans:    [{"name", "tid", "t0", "t1", "args"}] seconds since `t0_s`
    counters: [{"name", "t", "value"}] point samples (queue depth etc.)
    """

    def __init__(self):
        self.t0_s = time.perf_counter()
        self.spans: List[dict] = []
        self.counters: List[dict] = []

    def now(self) -> float:
        return time.perf_counter() - self.t0_s

    @contextlib.contextmanager
    def span(self, name: str, tid: str = "host", **args):
        """Time a host-side phase; also a profiler TraceAnnotation."""
        t0 = self.now()
        with jax.profiler.TraceAnnotation(name):
            try:
                yield
            finally:
                self.spans.append({"name": name, "tid": tid, "t0": t0,
                                   "t1": self.now(),
                                   "args": {k: v for k, v in args.items()}})

    def counter(self, name: str, value: float) -> None:
        self.counters.append({"name": name, "t": self.now(),
                              "value": float(value)})

    def durations(self, name: Optional[str] = None) -> List[float]:
        """Span durations in seconds (optionally for one span name)."""
        return [s["t1"] - s["t0"] for s in self.spans
                if name is None or s["name"] == name]

    def summary_s(self) -> Dict[str, float]:
        """Total seconds per span name (the per-step host-phase budget)."""
        out: Dict[str, float] = {}
        for s in self.spans:
            out[s["name"]] = out.get(s["name"], 0.0) + (s["t1"] - s["t0"])
        return out
