"""Chrome-trace / Perfetto JSON export: measured spans AND simulated steps.

Two producers, one format, so predicted and measured timelines load side
by side in chrome://tracing / ui.perfetto.dev:

  * `chrome_trace(span_events(recorder.spans))` — the measured host spans
    of a real run (`repro.obs.tracing.SpanRecorder`).
  * `chrome_trace(steptimer_timeline(timer, trace))` — the simulated
    schedule of a `repro.sim.cost_model.StepTimer` over a (T, N) mask
    trace: per-rank compute lanes, then the pack -> uplink -> downlink
    bucket stages laid out serially or as the 3-stage pipeline
    (`overlap=True`), mirroring `StepTimer.steps` EXACTLY — each step's
    span extent equals the closed-form step time (tested).

All event timestamps/durations are microseconds ("X" complete events, the
stable subset of the trace-event spec).  `validate_chrome_trace` is the
schema gate the tests and the CI metrics-smoke job run on every emitted
file.
"""
from __future__ import annotations

import json
import math
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["chrome_trace", "span_events", "steptimer_timeline",
           "validate_chrome_trace", "write_chrome_trace"]

TRACE_SCHEMA = "repro.obs.trace/v1"


# --------------------------------------------------------------------------
# trace-event assembly
# --------------------------------------------------------------------------

def _event(name: str, ts_s: float, dur_s: float, pid: int, tid: str,
           args: Optional[dict] = None) -> dict:
    return {"name": name, "ph": "X", "ts": ts_s * 1e6, "dur": dur_s * 1e6,
            "pid": pid, "tid": tid, "args": dict(args or {})}


def span_events(spans: Sequence[dict], pid: int = 0,
                counters: Sequence[dict] = ()) -> List[dict]:
    """`SpanRecorder.spans` (+ optional counter samples) -> trace events."""
    ev = [_event(s["name"], s["t0"], s["t1"] - s["t0"], pid,
                 s.get("tid", "host"), s.get("args")) for s in spans]
    for c in counters:
        ev.append({"name": c["name"], "ph": "C", "ts": c["t"] * 1e6,
                   "pid": pid, "args": {"value": c["value"]}})
    return ev


def chrome_trace(events: Sequence[dict],
                 metadata: Optional[dict] = None) -> dict:
    """Wrap events in the Chrome-trace JSON object form."""
    return {"traceEvents": list(events), "displayTimeUnit": "ms",
            "otherData": {"schema": TRACE_SCHEMA, **(metadata or {})}}


def write_chrome_trace(path: str, events: Sequence[dict],
                       metadata: Optional[dict] = None) -> dict:
    obj = chrome_trace(events, metadata)
    validate_chrome_trace(obj)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(obj, f)
    return obj


def validate_chrome_trace(obj) -> None:
    """Raise ValueError unless `obj` is a loadable Chrome-trace object
    (object form, complete/counter events, finite non-negative times)."""
    if not isinstance(obj, dict):
        raise ValueError("trace must be a JSON object (object form)")
    if obj.get("otherData", {}).get("schema") != TRACE_SCHEMA:
        raise ValueError(f"trace otherData.schema != {TRACE_SCHEMA!r}")
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError("traceEvents must be a list")
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            raise ValueError(f"traceEvents[{i}] is not an object")
        for field in ("name", "ph", "ts", "pid"):
            if field not in e:
                raise ValueError(f"traceEvents[{i}] missing {field!r}")
        if e["ph"] not in ("X", "C", "M"):
            raise ValueError(f"traceEvents[{i}].ph {e['ph']!r} not in "
                             f"('X', 'C', 'M')")
        ts = e["ts"]
        if not (isinstance(ts, (int, float)) and math.isfinite(ts)
                and ts >= 0):
            raise ValueError(f"traceEvents[{i}].ts must be finite >= 0")
        if e["ph"] == "X":
            dur = e.get("dur")
            if not (isinstance(dur, (int, float)) and math.isfinite(dur)
                    and dur >= 0):
                raise ValueError(f"traceEvents[{i}].dur must be finite >= 0")
            if "tid" not in e:
                raise ValueError(f"traceEvents[{i}] missing tid")
    json.dumps(obj)   # everything must be JSON-serializable


# --------------------------------------------------------------------------
# simulated StepTimer schedule
# --------------------------------------------------------------------------

def steptimer_timeline(timer, trace, pid: int = 1
                       ) -> Tuple[List[dict], np.ndarray]:
    """Lay a `sim.StepTimer` schedule out as trace events.

    trace: (T, N) participation masks.  Returns (events, step_times_s);
    step_times_s[t] is the laid-out extent of step t and equals
    `timer.steps(trace)[0][t]` exactly — the timeline IS the cost model,
    just unrolled into spans (serial buckets, or the pack/uplink/downlink
    pipeline when `timer.overlap` and num_buckets > 1).
    """
    trace = np.asarray(trace, np.float64)
    if trace.ndim != 2:
        raise ValueError(f"trace must be (T, N), got shape {trace.shape}")
    T, N = trace.shape
    comp = timer.compute.rank_seconds(N)                     # (N,)
    b_up_r = timer.bytes_up_ranks(N).astype(np.float64)      # (N,)
    up_r = timer.link.up_s_ranks(b_up_r)                     # (N,)
    lat = timer.link.latency_s
    B = timer.num_buckets
    xfer_r = up_r - lat
    down_xfer = timer.link.down_s(timer.bytes_down()) - lat

    events: List[dict] = []
    step_times = np.zeros((T,), np.float64)
    cursor = 0.0
    for t in range(T):
        row = trace[t]
        participants = float(row.sum())
        has_up = participants > 0
        if has_up:
            t_comp = float(np.max(np.where(row > 0, comp, 0.0)))
            xfer_max = float(np.max(np.where(row > 0, xfer_r, 0.0)))
        else:
            t_comp = float(comp.max())     # all-straggler: timeout window
            xfer_max = 0.0
        f = timer.link.server_fanin
        waves = math.ceil(participants / f) if (f > 0 and has_up) else 1.0

        t0 = cursor
        for i in range(N):
            if row[i] > 0:
                events.append(_event("compute", t0, comp[i], pid,
                                     f"rank{i}", {"step": t}))
        if not has_up:
            events.append(_event("compute_timeout", t0, t_comp, pid,
                                 "server", {"step": t}))
        agg0 = t0 + t_comp

        if timer.overlap and B > 1:
            # 3-stage pipeline over B buckets (mirrors StepTimer's
            # pack_b + up_b + down_b + (B-1) * bottleneck closed form)
            pack_b = timer.pack_s / B
            up_b = (waves * (lat + xfer_max / B)) if has_up else 0.0
            down_b = lat + down_xfer / B
            pack_end = up_end = down_end = agg0
            for b in range(B):
                p0 = pack_end
                if pack_b > 0:
                    events.append(_event("pack", p0, pack_b, pid, "pack",
                                         {"step": t, "bucket": b}))
                pack_end = p0 + pack_b
                u0 = max(pack_end, up_end)
                if up_b > 0:
                    events.append(_event("uplink", u0, up_b, pid, "uplink",
                                         {"step": t, "bucket": b}))
                up_end = u0 + up_b
                d0 = max(up_end, down_end)
                events.append(_event("downlink", d0, down_b, pid,
                                     "downlink", {"step": t, "bucket": b}))
                down_end = d0 + down_b
            t_end = down_end
        else:
            cur = agg0
            if timer.pack_s > 0:
                events.append(_event("pack", cur, timer.pack_s, pid, "pack",
                                     {"step": t}))
                cur += timer.pack_s
            if has_up:
                up_b = waves * (lat + xfer_max / B)
                for b in range(B):
                    events.append(_event("uplink", cur, up_b, pid, "uplink",
                                         {"step": t, "bucket": b}))
                    cur += up_b
            down_b = lat + down_xfer / B
            for b in range(B):
                events.append(_event("downlink", cur, down_b, pid,
                                     "downlink", {"step": t, "bucket": b}))
                cur += down_b
            t_end = cur

        events.append(_event("step", t0, t_end - t0, pid, "step",
                             {"step": t, "participants": participants}))
        step_times[t] = t_end - t0
        cursor = t_end
    return events, step_times
