"""Server-side optimizers operating on device-local flat vectors.

COCO-EF's aggregated update ghat already contains the learning rate
(eq. 4), so the paper-faithful server optimizer is plain SGD:
theta <- theta - ghat.  Momentum/Adam variants (beyond-paper) treat
ghat/gamma as the gradient estimate.

State lives as flat f32 vectors in the same device-local layout as the
error vectors (repro.core.cocoef), which keeps checkpointing uniform.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    kind: str = "sgd"            # sgd | momentum | adam
    momentum: float = 0.9
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0


def init_opt_state(cfg: OptimizerConfig, n: int):
    if cfg.kind == "sgd":
        return ()
    if cfg.kind == "momentum":
        return (jnp.zeros((n,), jnp.float32),)
    if cfg.kind == "adam":
        return (jnp.zeros((n,), jnp.float32), jnp.zeros((n,), jnp.float32))
    raise ValueError(cfg.kind)


def apply_update(cfg: OptimizerConfig, params_flat, ghat, state, step,
                 gamma):
    """params_flat: (n,) f32 local; ghat: aggregated update (incl. gamma).
    Returns (new_params, new_state)."""
    if cfg.weight_decay:
        ghat = ghat + cfg.weight_decay * gamma * params_flat
    if cfg.kind == "sgd":
        return params_flat - ghat, state
    if cfg.kind == "momentum":
        (m,) = state
        m = cfg.momentum * m + ghat
        return params_flat - m, (m,)
    if cfg.kind == "adam":
        m, v = state
        g = ghat / jnp.maximum(gamma, 1e-20)   # undo lr for the estimate
        m = cfg.beta1 * m + (1 - cfg.beta1) * g
        v = cfg.beta2 * v + (1 - cfg.beta2) * g * g
        t = step.astype(jnp.float32) + 1.0
        mh = m / (1 - cfg.beta1 ** t)
        vh = v / (1 - cfg.beta2 ** t)
        return params_flat - gamma * mh / (jnp.sqrt(vh) + cfg.eps), (m, v)
    raise ValueError(cfg.kind)


def lr_schedule(kind: str, base: float, warmup: int = 0,
                total: Optional[int] = None):
    """Returns gamma(step).  'constant' is the paper's setting (Sec. V);
    'rsqrt' matches the decaying scheme of Fig. 6; 'cosine' for production."""
    def f(step):
        s = jnp.asarray(step, jnp.float32)
        g = jnp.asarray(base, jnp.float32)
        if kind == "rsqrt":
            g = g / jnp.sqrt(s + 1.0)
        elif kind == "cosine":
            assert total is not None
            frac = jnp.clip(s / max(total, 1), 0.0, 1.0)
            g = g * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        if warmup > 0:
            g = g * jnp.clip((s + 1.0) / warmup, 0.0, 1.0)
        return g
    return f
