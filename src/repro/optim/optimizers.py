"""Server-side optimizers operating on device-local flat vectors.

COCO-EF's aggregated update ghat already contains the learning rate
(eq. 4), so the paper-faithful server optimizer is plain SGD:
theta <- theta - ghat.  Momentum/Adam variants (beyond-paper) treat
ghat/gamma as the gradient estimate.

State lives as flat f32 vectors in the same device-local layout as the
error vectors (repro.core.cocoef), which keeps checkpointing uniform.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    kind: str = "sgd"            # sgd | momentum | adam
    momentum: float = 0.9
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0


def init_opt_state(cfg: OptimizerConfig, n: int):
    if cfg.kind == "sgd":
        return ()
    if cfg.kind == "momentum":
        return (jnp.zeros((n,), jnp.float32),)
    if cfg.kind == "adam":
        return (jnp.zeros((n,), jnp.float32), jnp.zeros((n,), jnp.float32))
    raise ValueError(cfg.kind)


def _apply_update_impl(cfg: OptimizerConfig, params_flat, ghat, state, step,
                       gamma):
    decay = (cfg.weight_decay * gamma * params_flat if cfg.weight_decay
             else 0.0)
    if cfg.kind == "sgd":
        return params_flat - ghat - decay, state
    if cfg.kind == "momentum":
        (m,) = state
        m = cfg.momentum * m + ghat
        return params_flat - m - decay, (m,)
    if cfg.kind == "adam":
        m, v = state
        g = ghat / jnp.maximum(gamma, 1e-20)   # undo lr for the estimate
        m = cfg.beta1 * m + (1 - cfg.beta1) * g
        v = cfg.beta2 * v + (1 - cfg.beta2) * g * g
        t = step.astype(jnp.float32) + 1.0
        mh = m / (1 - cfg.beta1 ** t)
        vh = v / (1 - cfg.beta2 ** t)
        return (params_flat - gamma * mh / (jnp.sqrt(vh) + cfg.eps) - decay,
                (m, v))
    raise ValueError(cfg.kind)


def apply_update(cfg: OptimizerConfig, params_flat, ghat, state, step,
                 gamma, want_norms: bool = False):
    """params_flat: (n,) f32 local; ghat: aggregated update (incl. gamma).
    Returns (new_params, new_state) — with `want_norms=True`, additionally
    a third dict {"update_norm_sq", "param_norm_sq"} of device-local sums
    of squares (|theta_new - theta|^2 including the decoupled decay, and
    |theta_new|^2) filling the telemetry `MetricsFrame`'s optimizer
    fields; the default path traces the update exactly as before.

    Weight decay is DECOUPLED (AdamW): the decay term
    `weight_decay * gamma * params` is subtracted at the parameter update
    only and never enters the gradient estimate, so the momentum buffer and
    Adam's moments m/v are identical with and without decay."""
    with jax.named_scope("optim/apply_update"):
        new_params, new_state = _apply_update_impl(cfg, params_flat, ghat,
                                                   state, step, gamma)
        if not want_norms:
            return new_params, new_state
        delta = new_params - params_flat
        norms = {"update_norm_sq": jnp.sum(delta * delta),
                 "param_norm_sq": jnp.sum(new_params * new_params)}
        return new_params, new_state, norms


SCHEDULES = ("constant", "rsqrt", "cosine")


def lr_schedule(kind: str, base: float, warmup: int = 0,
                total: Optional[int] = None):
    """Returns gamma(step).  'constant' is the paper's setting (Sec. V);
    'rsqrt' matches the decaying scheme of Fig. 6; 'cosine' for production
    (needs `total`, the step count the cosine decays over).

    Knobs are validated HERE, at construction (same pattern as
    `TrainRun.__post_init__`): a bad combination raises ValueError before
    any tracing instead of dying on an assert inside jit."""
    if kind not in SCHEDULES:
        raise ValueError(f"unknown lr schedule {kind!r}; have {SCHEDULES}")
    if warmup < 0:
        raise ValueError(f"warmup={warmup} must be >= 0 steps")
    if kind == "cosine" and (total is None or total < 1):
        raise ValueError(
            f"cosine schedule needs total >= 1 decay steps, got {total!r} "
            f"(set TrainRun.schedule_total)")

    def f(step):
        s = jnp.asarray(step, jnp.float32)
        g = jnp.asarray(base, jnp.float32)
        if kind == "rsqrt":
            g = g / jnp.sqrt(s + 1.0)
        elif kind == "cosine":
            frac = jnp.clip(s / total, 0.0, 1.0)
            g = g * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        if warmup > 0:
            # (s+1)/warmup clipped to 1: full lr from step warmup-1 on, no
            # 0-division and no zero step at s=0
            g = g * jnp.clip((s + 1.0) / warmup, 0.0, 1.0)
        return g
    return f
