from .optimizers import (OptimizerConfig, SCHEDULES, init_opt_state,
                         apply_update, lr_schedule)  # noqa: F401
