from .optimizers import (OptimizerConfig, init_opt_state, apply_update,
                         lr_schedule)  # noqa: F401
