"""COCO-EF as a production optimizer transform over device-local flat state.

This is the piece that runs *inside* the fully-manual aggregation shard_map of
`repro.launch.train` (stage 2 in DESIGN.md Sec. 2): every (coding-rank,
tp-shard) device holds

  g_local   : its slice of this rank's coded gradient, flattened + padded
  e_local   : its slice of this rank's error vector  (Alg. 1 state)

and produces the aggregated update slice `ghat_local` (identical across
coding ranks, distinct across tp shards) plus the new error state.

The math is Algorithm 1 exactly:
  acc  = gamma * g + e
  c    = wire.roundtrip(acc)  (the wire IS the compressor: SignWire <->
                               grouped sign, SparseWire <-> block top-K,
                               DenseWire <-> identity; see collectives.py)
  ghat = sum_i mask_i c_i     (two-phase wire-compressed collective)
  e'   = mask ? acc - c : e

`mode` selects the paper's method or the baselines for A/B roofline runs:
  cocoef       biased sign + error feedback            (proposed)
  coco         biased sign, no error feedback          (Fig. 5 ablation)
  dense        no compression (SGC [31]; the dense-psum baseline)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.compat import axis_size
from repro.kernels import ref as kernel_ref
from repro.kernels.ops import backend_use_pallas
from repro.obs.metrics import MetricsFrame, norm_sq
from .collectives import (CodingCollectiveConfig, DenseWire, SignWire,
                          SparseWire, WireFormat, coded_allreduce_start,
                          dense_allreduce, two_phase_coded_allreduce)

__all__ = ["CocoEFConfig", "FlatMeta", "flatten_local", "unflatten_local",
           "padded_size", "cocoef_update", "coding_rank_index"]


@dataclasses.dataclass(frozen=True)
class CocoEFConfig:
    coding_axes: Tuple[str, ...] = ("data",)
    group_size: int = 512
    straggler_p: float = 0.0
    straggler_rates: Optional[Tuple[float, ...]] = None
    # ^ per-rank participation rates q_i (StragglerProcess.rates()) the
    #   encode weights were built with; None = scalar mean rate (eq. 3).
    #   Threaded so batch makers fold the SAME rate-aware W as the trainer.
    mode: str = "cocoef"              # cocoef | coco | dense
    compressor: str = "sign"          # sign | block_topk | topk | identity
    topk_k: int = 64                  # global-K budget (compressor="topk")
    k_per_block: Union[int, Tuple[int, ...]] = 8
    # ^ kept coords/block (compressor="block_topk"); a per-rank tuple (from
    #   sim.cost_model.solve_k_budgets) gives slow-uplink ranks smaller
    #   wire budgets (SparseWire per-rank budgets)
    block_size: int = 256             # sparsification block (compressor="block_topk")
    wire_dtype: str = "float32"       # sparse values / dense payload dtype
    ef_dtype: str = "float32"         # error-vector storage dtype
    phase2_dtype: str = "float32"     # f32 = paper-faithful broadcast
    phase2_sign: bool = False         # beyond-paper compressed broadcast
    num_buckets: int = 1              # split flat vector for comm overlap
    bucket_schedule: str = "pipelined"  # pipelined | serial (see below)
    # ^ "pipelined" double-buffers the per-bucket collectives: bucket i's
    #   all_to_all is issued, then bucket i+1's fused local step is traced
    #   BEFORE bucket i's decode/phase 2, so XLA's async collectives can
    #   overlap the wire transfer with compute.  Bit-for-bit identical to
    #   "serial" (same ops, reordered issue); "serial" kept as the
    #   schedule-parity reference.  With num_buckets=1 they coincide.
    backend: str = "auto"             # auto | pallas | jnp kernel dispatch

    def __post_init__(self):
        if self.bucket_schedule not in ("serial", "pipelined"):
            raise ValueError(f"unknown bucket_schedule "
                             f"{self.bucket_schedule!r}; have "
                             f"('serial', 'pipelined')")

    def collective(self) -> CodingCollectiveConfig:
        return CodingCollectiveConfig(
            coding_axes=self.coding_axes,
            group_size=self.group_size,
            phase2_dtype=jnp.dtype(self.phase2_dtype),
            phase2_sign=self.phase2_sign,
            backend=self.backend)

    def wire_format(self, n: int, nd: int) -> WireFormat:
        """Wire format for one bucket of `n` coords over `nd` chunks.

        Delegates to `plan.build_wire` — the one mapping from compressor
        name + knobs to a WireFormat, shared with `PlanSpec.wire`."""
        from .plan import build_wire
        return build_wire(self.compressor, group_size=self.group_size,
                          k_per_block=self.k_per_block,
                          block_size=self.block_size, topk_k=self.topk_k,
                          value_dtype=self.wire_dtype, n=n, nd=nd,
                          num_buckets=self.num_buckets)

    @property
    def pad_multiple(self) -> int:
        """Per-bucket flat-size alignment (feeds `padded_size`): the sign
        group always participates (phase-2 re-compression packs the chunk
        with `group_size`), joined with the sparse block when active."""
        if self.compressor == "block_topk":
            return math.lcm(self.group_size, self.block_size)
        return self.group_size


# --------------------------------------------------------------------------
# local flatten/unflatten with padding
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FlatMeta:
    shapes: Tuple[Tuple[int, ...], ...]
    sizes: Tuple[int, ...]
    dtypes: Tuple[str, ...]
    padded: int


def padded_size(total: int, chunk_ranks: int, group_size: int,
                num_buckets: int = 1) -> int:
    mult = chunk_ranks * group_size * num_buckets
    return math.ceil(total / mult) * mult


def flatten_local(leaves: Sequence[jnp.ndarray], chunk_ranks: int,
                  group_size: int, num_buckets: int = 1
                  ) -> Tuple[jnp.ndarray, FlatMeta]:
    """Concat device-local leaf blocks into one padded f32 vector."""
    sizes = tuple(int(l.size) for l in leaves)
    total = sum(sizes)
    padded = padded_size(total, chunk_ranks, group_size, num_buckets)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    flat = jnp.pad(flat, (0, padded - total))
    meta = FlatMeta(shapes=tuple(tuple(l.shape) for l in leaves), sizes=sizes,
                    dtypes=tuple(str(l.dtype) for l in leaves), padded=padded)
    return flat, meta


def unflatten_local(flat: jnp.ndarray, meta: FlatMeta) -> List[jnp.ndarray]:
    out, off = [], 0
    for shape, size, dt in zip(meta.shapes, meta.sizes, meta.dtypes):
        out.append(lax.dynamic_slice_in_dim(flat, off, size)
                   .reshape(shape).astype(jnp.dtype(dt)))
        off += size
    return out


# --------------------------------------------------------------------------
# the update (runs per device inside the fully-manual shard_map)
# --------------------------------------------------------------------------

def coding_rank_index(coding_axes: Sequence[str]) -> jnp.ndarray:
    """Row-major linear index of this device among the coding ranks."""
    idx = jnp.zeros((), jnp.int32)
    for ax in coding_axes:
        idx = idx * axis_size(ax) + lax.axis_index(ax)
    return idx


def _bucketed(flat: jnp.ndarray, num_buckets: int):
    return flat.reshape(num_buckets, -1)


def _check_rank_budgets(wire, mask: jnp.ndarray) -> None:
    """A per-rank-budget wire must carry exactly one budget per coding
    rank — jnp's clamped indexing would otherwise make a short tuple
    silently reuse the last budget for the out-of-range ranks."""
    if wire.has_rank_budgets() and len(wire.k_per_block) != mask.shape[0]:
        raise ValueError(
            f"wire has {len(wire.k_per_block)} per-rank budgets, the "
            f"coding collective has {mask.shape[0]} ranks")


def _joined(parts: List[jnp.ndarray]) -> jnp.ndarray:
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts)


class _BucketSchedule:
    """Per-bucket collective issue order (CocoEFConfig.bucket_schedule).

    serial:     submit(b) = start + finish immediately — bucket b's decode
                and phase 2 are traced before bucket b+1 does anything.
    pipelined:  submit(b) issues bucket b's all_to_all and holds the
                in-flight handle; the PREVIOUS bucket is finished only
                after the next one's local step + all_to_all have been
                traced (window-2 double buffer), so the compiler can hide
                bucket b's wire transfer behind bucket b+1's compute.

    Both produce identical values — the same ops run, only the issue
    order differs — which test_backend_parity pins down bitwise."""

    def __init__(self, schedule: str, coll: CodingCollectiveConfig,
                 mask: jnp.ndarray):
        self.pipelined = schedule == "pipelined"
        self.coll = coll
        self.mask = mask
        self._pending = None
        self._parts: List[jnp.ndarray] = []

    def submit(self, wire: WireFormat, payload) -> None:
        if not self.pipelined:
            self._parts.append(two_phase_coded_allreduce(
                None, wire, self.coll, self.mask, payload=payload))
            return
        nxt = coded_allreduce_start(wire, self.coll, self.mask, payload)
        if self._pending is not None:
            self._parts.append(self._pending.finish())
        self._pending = nxt

    def collect(self) -> List[jnp.ndarray]:
        if self._pending is not None:
            self._parts.append(self._pending.finish())
            self._pending = None
        return self._parts


def cocoef_update(g_local: jnp.ndarray, e_local: jnp.ndarray,
                  mask: Optional[jnp.ndarray], gamma, cfg: CocoEFConfig,
                  *, mask_provider: Optional[Callable] = None,
                  key: Optional[jnp.ndarray] = None,
                  step=None, want_metrics: bool = False):
    """One Algorithm-1 update on the device-local flat slice.

    g_local: (n,) local slice of this coding rank's coded gradient.
    e_local: (n,) local slice of this rank's error vector (cfg.ef_dtype).
    mask:    (n_coding,) straggler indicators I_i^t (same on all devices);
             may be None when `mask_provider` is given.
    gamma:   scalar learning rate (may be traced — lr schedules).
    mask_provider: optional hook `(key, step) -> (n_coding,) mask` — any
             `repro.sim.StragglerProcess.mask` qualifies.  Must be pure in
             (key, step) so every coding rank derives the identical mask
             without communication; called here (inside the shard_map /
             jit scope), with `key`/`step` threaded through.
    want_metrics: when True additionally return a `repro.obs.MetricsFrame`
             of in-graph telemetry (per-rank wire bytes, EF/compression
             norms, the acc-vs-C(acc) cosine inputs) — computed from
             values the step already has plus a local unpack where the
             hot path skips c; NO extra collectives.  When False (the
             default) the traced computation is `_cocoef_update_impl`,
             the pre-telemetry body verbatim, so the compiled step is
             byte-identical to a build without metrics (pinned by
             tests/test_obs.py).
    Returns (ghat_local, new_e_local) — plus the frame when requested;
    ghat is sum_i mask_i C_or_id(acc_i), already scaled by gamma per
    eq. (4): apply as  params -= ghat.

    Execution routes through the wire's fused backend (cfg.backend):
    `wire.fused_local_step` produces payload + new error in one pass over
    g/e (cocoef), and coco/dense never materialize the reconstruction c.
    """
    if mask is None:
        if mask_provider is None:
            raise ValueError("need a mask or a mask_provider hook")
        mask = mask_provider(key, step)
    if want_metrics:
        return _cocoef_update_metrics(g_local, e_local, mask, gamma, cfg)
    return _cocoef_update_impl(g_local, e_local, mask, gamma, cfg)


def _cocoef_update_impl(g_local: jnp.ndarray, e_local: jnp.ndarray,
                        mask: jnp.ndarray, gamma, cfg: CocoEFConfig
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The metrics-free update — the pre-telemetry `cocoef_update` body,
    kept verbatim so the default path provably traces the same HLO."""
    coll = cfg.collective()
    my_idx = coding_rank_index(cfg.coding_axes)
    my_mask = lax.dynamic_index_in_dim(mask, my_idx, keepdims=False)

    if cfg.mode == "dense":
        acc = gamma * g_local
        ghat = dense_allreduce(acc, coll, mask)
        return ghat, e_local

    nd = axis_size(coll.chunk_axis)
    use_pallas = backend_use_pallas(cfg.backend)

    if cfg.mode == "coco":
        # no error feedback: pack-and-send only — C(acc) is never needed
        # locally, so neither c nor the dead bucket concat is materialized
        sched = _BucketSchedule(cfg.bucket_schedule, coll, mask)
        for acc_b in _bucketed(gamma * g_local, cfg.num_buckets):
            wire = cfg.wire_format(acc_b.shape[0], nd)
            _check_rank_budgets(wire, mask)
            payload = wire.apply_rank_budget(
                wire.fused_pack(acc_b, use_pallas=use_pallas), my_idx)
            sched.submit(wire, payload)
        return _joined(sched.collect()), e_local

    # cocoef: fused accumulate + compress + error update per bucket.
    # Under the pipelined schedule bucket b's local step is traced before
    # bucket b-1's decode/phase 2 (the _BucketSchedule window), so the
    # wire transfer of one bucket hides behind the compression of the next.
    sched = _BucketSchedule(cfg.bucket_schedule, coll, mask)
    e_parts = []
    for g_b, e_b in zip(_bucketed(g_local, cfg.num_buckets),
                        _bucketed(e_local, cfg.num_buckets)):
        wire = cfg.wire_format(g_b.shape[0], nd)
        _check_rank_budgets(wire, mask)
        if wire.has_rank_budgets():
            # per-rank wire budgets: the truncation below this rank's budget
            # must feed the error vector, so reconstruct c from the
            # budget-masked payload instead of taking the fused kernel's
            # full-budget error update
            acc_b = kernel_ref.mul_add(gamma, g_b, e_b)
            payload = wire.apply_rank_budget(
                wire.fused_pack(acc_b, use_pallas=use_pallas), my_idx)
            c_b = wire.unpack(payload)
            e_new_b = jnp.where(my_mask > 0, acc_b - c_b,
                                e_b.astype(jnp.float32))
        else:
            payload, _, e_new_b = wire.fused_local_step(
                g_b, e_b, gamma, my_mask, use_pallas=use_pallas, want_c=False)
        sched.submit(wire, payload)
        e_parts.append(e_new_b)
    ghat = _joined(sched.collect())
    new_e = _joined(e_parts)
    return ghat, new_e.astype(jnp.dtype(cfg.ef_dtype))


def _cocoef_update_metrics(g_local: jnp.ndarray, e_local: jnp.ndarray,
                           mask: jnp.ndarray, gamma, cfg: CocoEFConfig
                           ) -> Tuple[jnp.ndarray, jnp.ndarray, MetricsFrame]:
    """`cocoef_update` with an in-graph `MetricsFrame` third output.

    Same math and the same collectives as `_cocoef_update_impl`; the extra
    work is device-LOCAL only: the wire-byte constants are static numpy
    (mask-multiplied in-graph), and the compression-quality scalars reuse
    the c the fused kernels can otherwise skip (want_c=True here, plus a
    local unpack on the coco path).  The frame's optimizer fields
    (update/param norms) are zero — `optim.apply_update(want_norms=True)`
    fills them in the caller."""
    coll = cfg.collective()
    my_idx = coding_rank_index(cfg.coding_axes)
    my_mask = lax.dynamic_index_in_dim(mask, my_idx, keepdims=False)
    N = mask.shape[0]
    B = cfg.num_buckets
    n = g_local.shape[0]
    f32 = jnp.float32
    maskf = mask.astype(f32)
    zero = jnp.zeros((), f32)
    grad_sq = norm_sq(g_local)
    down_bytes = float(n * jnp.dtype(cfg.phase2_dtype).itemsize)

    def finish(ghat, e_stored, bytes_rank, bucket_rank, acc_sq, c_sq, dot):
        # bytes_rank (N,), bucket_rank (B, N): STATIC per-rank phase-1
        # bytes; "actually sent" = x the participation mask, matching the
        # StepTimer ledger (trace @ rank_wire_bytes) per step exactly
        bucket_mine = jnp.take(jnp.asarray(bucket_rank, f32), my_idx, axis=1)
        return MetricsFrame(
            participation=maskf,
            wire_bytes_rank=jnp.asarray(bytes_rank, f32) * maskf,
            bucket_wire_bytes=bucket_mine * my_mask.astype(f32),
            bytes_down=jnp.asarray(down_bytes, f32),
            grad_norm_sq=grad_sq, ef_norm_sq=norm_sq(e_stored),
            acc_norm_sq=acc_sq, c_norm_sq=c_sq, acc_dot_c=dot,
            ghat_norm_sq=norm_sq(ghat),
            update_norm_sq=zero, param_norm_sq=zero)

    if cfg.mode == "dense":
        acc = gamma * g_local
        ghat = dense_allreduce(acc, coll, mask)
        # the dense psum ships the f32 accumulator (SGC baseline wire)
        bytes_rank = DenseWire(value_dtype="float32").rank_wire_bytes(n, N)
        bucket_rank = np.repeat(bytes_rank[None].astype(np.float64) / B,
                                B, axis=0)
        acc_sq = norm_sq(acc)         # identity compressor: c == acc
        frame = finish(ghat, e_local, bytes_rank, bucket_rank,
                       acc_sq, acc_sq, acc_sq)
        return ghat, e_local, frame

    nd = axis_size(coll.chunk_axis)
    use_pallas = backend_use_pallas(cfg.backend)
    acc_sq = c_sq = dot = zero
    bytes_rank = np.zeros((N,), np.int64)
    bucket_rows = []

    if cfg.mode == "coco":
        sched = _BucketSchedule(cfg.bucket_schedule, coll, mask)
        for acc_b in _bucketed(gamma * g_local, B):
            wire = cfg.wire_format(acc_b.shape[0], nd)
            _check_rank_budgets(wire, mask)
            payload = wire.apply_rank_budget(
                wire.fused_pack(acc_b, use_pallas=use_pallas), my_idx)
            c_b = wire.unpack(payload)    # metrics-only local decode
            acc_sq = acc_sq + norm_sq(acc_b)
            c_sq = c_sq + norm_sq(c_b)
            dot = dot + jnp.sum(acc_b.astype(f32) * c_b)
            rb = wire.rank_wire_bytes(acc_b.shape[0], N)
            bytes_rank = bytes_rank + rb
            bucket_rows.append(rb)
            sched.submit(wire, payload)
        ghat = _joined(sched.collect())
        frame = finish(ghat, e_local, bytes_rank, np.stack(bucket_rows),
                       acc_sq, c_sq, dot)
        return ghat, e_local, frame

    sched = _BucketSchedule(cfg.bucket_schedule, coll, mask)
    e_parts = []
    for g_b, e_b in zip(_bucketed(g_local, B), _bucketed(e_local, B)):
        wire = cfg.wire_format(g_b.shape[0], nd)
        _check_rank_budgets(wire, mask)
        acc_b = kernel_ref.mul_add(gamma, g_b, e_b)
        if wire.has_rank_budgets():
            payload = wire.apply_rank_budget(
                wire.fused_pack(acc_b, use_pallas=use_pallas), my_idx)
            c_b = wire.unpack(payload)
            e_new_b = jnp.where(my_mask > 0, acc_b - c_b,
                                e_b.astype(jnp.float32))
        else:
            payload, c_b, e_new_b = wire.fused_local_step(
                g_b, e_b, gamma, my_mask, use_pallas=use_pallas, want_c=True)
        acc_sq = acc_sq + norm_sq(acc_b)
        c_sq = c_sq + norm_sq(c_b)
        dot = dot + jnp.sum(acc_b.astype(f32) * c_b)
        rb = wire.rank_wire_bytes(g_b.shape[0], N)
        bytes_rank = bytes_rank + rb
        bucket_rows.append(rb)
        sched.submit(wire, payload)
        e_parts.append(e_new_b)
    ghat = _joined(sched.collect())
    new_e = _joined(e_parts).astype(jnp.dtype(cfg.ef_dtype))
    frame = finish(ghat, new_e, bytes_rank, np.stack(bucket_rows),
                   acc_sq, c_sq, dot)
    return ghat, new_e, frame
