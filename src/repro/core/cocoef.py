"""COCO-EF as a production optimizer transform over device-local flat state.

This is the piece that runs *inside* the fully-manual aggregation shard_map of
`repro.launch.train` (stage 2 in DESIGN.md Sec. 2): every (coding-rank,
tp-shard) device holds

  g_local   : its slice of this rank's coded gradient, flattened + padded
  e_local   : its slice of this rank's error vector  (Alg. 1 state)

and produces the aggregated update slice `ghat_local` (identical across
coding ranks, distinct across tp shards) plus the new error state.

The math is Algorithm 1 exactly:
  acc  = gamma * g + e
  c    = C(acc)            (sign wire format; pack once, unpack locally)
  ghat = sum_i mask_i c_i  (two-phase wire-compressed collective)
  e'   = mask ? acc - c : e

`mode` selects the paper's method or the baselines for A/B roofline runs:
  cocoef       biased sign + error feedback            (proposed)
  coco         biased sign, no error feedback          (Fig. 5 ablation)
  dense        no compression (SGC [31]; the dense-psum baseline)
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .collectives import (CodingCollectiveConfig, dense_allreduce, sign_pack,
                          sign_unpack, two_phase_sign_allreduce)

__all__ = ["CocoEFConfig", "FlatMeta", "flatten_local", "unflatten_local",
           "padded_size", "cocoef_update", "coding_rank_index"]


@dataclasses.dataclass(frozen=True)
class CocoEFConfig:
    coding_axes: Tuple[str, ...] = ("data",)
    group_size: int = 512
    straggler_p: float = 0.0
    mode: str = "cocoef"              # cocoef | coco | dense
    ef_dtype: str = "float32"         # error-vector storage dtype
    phase2_dtype: str = "float32"     # f32 = paper-faithful broadcast
    phase2_sign: bool = False         # beyond-paper compressed broadcast
    num_buckets: int = 1              # split flat vector for comm overlap

    def collective(self) -> CodingCollectiveConfig:
        return CodingCollectiveConfig(
            coding_axes=self.coding_axes,
            group_size=self.group_size,
            phase2_dtype=jnp.dtype(self.phase2_dtype),
            phase2_sign=self.phase2_sign)


# --------------------------------------------------------------------------
# local flatten/unflatten with padding
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FlatMeta:
    shapes: Tuple[Tuple[int, ...], ...]
    sizes: Tuple[int, ...]
    dtypes: Tuple[str, ...]
    padded: int


def padded_size(total: int, chunk_ranks: int, group_size: int,
                num_buckets: int = 1) -> int:
    mult = chunk_ranks * group_size * num_buckets
    return math.ceil(total / mult) * mult


def flatten_local(leaves: Sequence[jnp.ndarray], chunk_ranks: int,
                  group_size: int, num_buckets: int = 1
                  ) -> Tuple[jnp.ndarray, FlatMeta]:
    """Concat device-local leaf blocks into one padded f32 vector."""
    sizes = tuple(int(l.size) for l in leaves)
    total = sum(sizes)
    padded = padded_size(total, chunk_ranks, group_size, num_buckets)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    flat = jnp.pad(flat, (0, padded - total))
    meta = FlatMeta(shapes=tuple(tuple(l.shape) for l in leaves), sizes=sizes,
                    dtypes=tuple(str(l.dtype) for l in leaves), padded=padded)
    return flat, meta


def unflatten_local(flat: jnp.ndarray, meta: FlatMeta) -> List[jnp.ndarray]:
    out, off = [], 0
    for shape, size, dt in zip(meta.shapes, meta.sizes, meta.dtypes):
        out.append(lax.dynamic_slice_in_dim(flat, off, size)
                   .reshape(shape).astype(jnp.dtype(dt)))
        off += size
    return out


# --------------------------------------------------------------------------
# the update (runs per device inside the fully-manual shard_map)
# --------------------------------------------------------------------------

def coding_rank_index(coding_axes: Sequence[str]) -> jnp.ndarray:
    """Row-major linear index of this device among the coding ranks."""
    idx = jnp.zeros((), jnp.int32)
    for ax in coding_axes:
        idx = idx * lax.axis_size(ax) + lax.axis_index(ax)
    return idx


def _bucketed(flat: jnp.ndarray, num_buckets: int):
    return flat.reshape(num_buckets, -1)


def cocoef_update(g_local: jnp.ndarray, e_local: jnp.ndarray,
                  mask: jnp.ndarray, gamma, cfg: CocoEFConfig
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One Algorithm-1 update on the device-local flat slice.

    g_local: (n,) local slice of this coding rank's coded gradient.
    e_local: (n,) local slice of this rank's error vector (cfg.ef_dtype).
    mask:    (n_coding,) straggler indicators I_i^t (same on all devices).
    gamma:   scalar learning rate (may be traced — lr schedules).
    Returns (ghat_local, new_e_local); ghat is sum_i mask_i C_or_id(acc_i),
    already scaled by gamma per eq. (4): apply as  params -= ghat.
    """
    coll = cfg.collective()
    my_idx = coding_rank_index(cfg.coding_axes)
    my_mask = lax.dynamic_index_in_dim(mask, my_idx, keepdims=False)

    if cfg.mode == "dense":
        acc = gamma * g_local
        ghat = dense_allreduce(acc, coll, mask)
        return ghat, e_local

    if cfg.mode == "coco":
        acc = gamma * g_local
    else:  # cocoef
        acc = gamma * g_local + e_local.astype(jnp.float32)

    ghat_parts, c_parts = [], []
    for acc_b in _bucketed(acc, cfg.num_buckets):
        words, scales = sign_pack(acc_b, cfg.group_size)
        c_b = sign_unpack(words, scales, cfg.group_size)
        ghat_parts.append(two_phase_sign_allreduce(c_b, coll, mask))
        c_parts.append(c_b)
    ghat = jnp.concatenate(ghat_parts)
    c = jnp.concatenate(c_parts)

    if cfg.mode == "coco":
        new_e = e_local
    else:
        new_e = jnp.where(my_mask > 0, acc - c,
                          e_local.astype(jnp.float32))
    return ghat, new_e.astype(jnp.dtype(cfg.ef_dtype))
