"""Stochastic gradient coding: data allocation, encoding, straggler model.

Implements the pairwise-balanced allocation of [31] (Sec. III of the paper),
the encoding weights 1/(d_k (1-p)) of eq. (3), the Bernoulli straggler model
of eq. (8), and the redundancy statistic theta (eq. 18).

Allocation happens once before training (host-side, numpy-free: we use jax
PRNG for reproducibility but materialize small static matrices).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Allocation",
    "random_allocation",
    "cyclic_allocation",
    "rate_aware_allocation",
    "expected_coverage",
    "encode_weights",
    "straggler_mask",
    "redundancy_theta",
]


@dataclasses.dataclass(frozen=True)
class Allocation:
    """Static data-to-device allocation.

    S: (N, M) 0/1 matrix, S[i, k] = 1 iff subset k lives on device i.
    """

    S: np.ndarray  # (N, M) int8

    @property
    def num_devices(self) -> int:
        return self.S.shape[0]

    @property
    def num_subsets(self) -> int:
        return self.S.shape[1]

    @property
    def d(self) -> np.ndarray:
        """d_k = number of devices holding subset k, shape (M,)."""
        return self.S.sum(axis=0)

    def subsets_of(self, device: int) -> np.ndarray:
        return np.nonzero(self.S[device])[0]

    def validate(self) -> None:
        if (self.d == 0).any():
            raise ValueError("every subset must be allocated to >=1 device")


def random_allocation(seed: int, num_devices: int, num_subsets: int,
                      d: int) -> Allocation:
    """Uniform random allocation: subset k on d distinct random devices.

    This is the paper's practical approximation of the pairwise-balanced
    scheme (Sec. V.A): E[#devices holding both k1 and k2] = d^2/N.
    """
    rng = np.random.default_rng(seed)
    S = np.zeros((num_devices, num_subsets), dtype=np.int8)
    for k in range(num_subsets):
        devs = rng.choice(num_devices, size=min(d, num_devices), replace=False)
        S[devs, k] = 1
    alloc = Allocation(S=S)
    alloc.validate()
    return alloc


def cyclic_allocation(num_devices: int, num_subsets: int, d: int) -> Allocation:
    """Deterministic cyclic allocation: subset k on devices k, k+1, ..., k+d-1
    (mod N).  Exactly pairwise balanced when M = N (each pair of subsets at
    distance < d shares d - dist devices; used for regression tests where a
    deterministic S is wanted)."""
    S = np.zeros((num_devices, num_subsets), dtype=np.int8)
    for k in range(num_subsets):
        for j in range(min(d, num_devices)):
            S[(k + j) % num_devices, k] = 1
    alloc = Allocation(S=S)
    alloc.validate()
    return alloc


def expected_coverage(alloc: Allocation,
                      rates: Sequence[float]) -> np.ndarray:
    """Per-subset P(at least one holder participates) under per-rank
    participation rates q_i, shape (M,):  1 - prod_{i in S_k} (1 - q_i)."""
    q = np.asarray(rates, np.float64)
    if q.shape != (alloc.num_devices,):
        raise ValueError(f"need {alloc.num_devices} per-rank rates, got "
                         f"shape {q.shape}")
    miss = np.prod(np.where(alloc.S > 0, (1.0 - q)[:, None], 1.0), axis=0)
    return 1.0 - miss


def rate_aware_allocation(rates: Sequence[float], num_subsets: int, d: int,
                          *, load_slack: float = 1.25,
                          exact_load: bool = False) -> Allocation:
    """Heterogeneity-aware allocation: greedy expected-coverage maximization
    under per-rank participation rates q_i.

    Spends the same total replica budget as a uniform-d allocation (d * M
    replicas) but lets d_k vary: every subset starts on its cyclic home rank
    (data locality), then each remaining replica goes to the (subset, rank)
    pair with the largest marginal gain in expected coverage

        gain(k, i) = P(no current holder of k participates) * q_i ,

    subject to the balanced per-rank load cap ceil(load_slack * d * M / N).
    Subsets homed on unreliable ranks have the largest miss probability, so
    the extra redundancy concentrates exactly where the fleet is weak (the
    heterogeneous-system placement of Song & Choi).  Deterministic.

    The greedy maximum is tracked with a lazy max-heap keyed on the
    factored gain miss_k * q_best(k): a placement only ever *lowers* gains
    (miss_k shrinks, ranks fill up), so a popped entry whose miss/holder
    snapshot is stale can be recomputed and re-pushed without losing the
    true maximum.  O(budget * (log M + N)) instead of the dense
    O(budget * N * M) argmax scan — 1024 ranks allocate in milliseconds.

    exact_load=True replaces the slack cap with the exact per-rank load
    d * M / N (N must divide the budget) and spends any greedy remainder
    in a repair pass, so every rank holds exactly d * M / N subsets.  The
    mesh train path needs this: a uniform per-rank subset count keeps the
    stacked batch shape (and therefore the compiled step) stable across
    re-allocations.
    """
    q = np.asarray(rates, np.float64)
    N, M = q.shape[0], num_subsets
    if N < 1 or M < 1:
        raise ValueError("need at least one device and one subset")
    if np.any(q < 0.0) or np.any(q > 1.0):
        raise ValueError("every participation rate must be in [0, 1]")
    d_eff = min(max(int(d), 1), N)
    S = np.zeros((N, M), dtype=np.int8)
    homes = np.arange(M) % N
    S[homes, np.arange(M)] = 1
    load = np.bincount(homes, minlength=N).astype(np.int64)
    miss = 1.0 - q[homes]                            # per-subset miss prob
    if exact_load:
        if (d_eff * M) % N:
            raise ValueError(
                f"exact_load needs N={N} to divide the replica budget "
                f"d*M={d_eff * M}")
        cap = d_eff * M // N
    else:
        cap = int(np.ceil(load_slack * d_eff * M / N))

    def _best(k: int) -> int:
        """Most reliable rank that can still take subset k (tie: lowest
        rank index, matching the old dense-argmax order), or -1."""
        avail = (S[:, k] == 0) & (load < cap)
        if not avail.any():
            return -1
        return int(np.argmax(np.where(avail, q, -1.0)))

    heap: list = []
    for k in range(M):
        i = _best(k)
        if i >= 0:
            heapq.heappush(heap, (-(miss[k] * q[i]), i, k, miss[k]))
    budget = d_eff * M - M
    placed = 0
    while placed < budget and heap:
        _, i, k, m_snap = heapq.heappop(heap)
        if m_snap != miss[k] or S[i, k] or load[i] >= cap:
            i = _best(k)                             # stale -> recompute
            if i >= 0:
                heapq.heappush(heap, (-(miss[k] * q[i]), i, k, miss[k]))
            continue
        S[i, k] = 1
        load[i] += 1
        miss[k] *= 1.0 - q[i]
        placed += 1
        j = _best(k)
        if j >= 0:
            heapq.heappush(heap, (-(miss[k] * q[j]), j, k, miss[k]))
    if exact_load and placed < budget:
        # Greedy can strand budget (a subset already on every non-full
        # rank).  Spend the remainder on the emptiest rank x its
        # highest-miss unheld subset: always feasible, since load < cap
        # <= M implies an unheld subset exists, and the counting argument
        # (total = cap * N, each load <= cap) then forces load == cap
        # everywhere once the budget is gone.
        while placed < budget:
            open_load = np.where(load < cap, load, np.iinfo(np.int64).max)
            i = int(np.argmin(open_load))
            ks = np.nonzero(S[i] == 0)[0]
            k = int(ks[np.argmax(miss[ks])])
            S[i, k] = 1
            load[i] += 1
            miss[k] *= 1.0 - q[i]
            placed += 1
    alloc = Allocation(S=S)
    alloc.validate()
    return alloc


def encode_weights(alloc: Allocation, p: Optional[float] = None,
                   rates: Optional[Sequence[float]] = None) -> jnp.ndarray:
    """Encode weights making the masked aggregate unbiased.

    Exactly one of `p` / `rates` must be given:

      p      W[i, k] = S[i, k] / (d_k * (1 - p))        (eq. 3, iid mean rate)
      rates  W[i, k] = S[i, k] / sum_j S[j, k] * q_j    (rate-aware)

    The rate-aware form divides by the *expected number of participating
    holders* of subset k, so E[sum_i I_i g_i] = grad F for ANY per-rank
    marginal participation rates q_j (`StragglerProcess.rates()`); with
    uniform rates q_j = 1 - p it is bit-for-bit eq. 3.

    Multiplying the (M, D) per-subset gradient stack by W yields the (N, D)
    coded vectors g_i^t.
    """
    if (p is None) == (rates is None):
        raise ValueError("give exactly one of p (eq. 3) or rates (per-rank)")
    if p is not None:
        if not 0.0 <= p < 1.0:
            raise ValueError(f"straggler probability p={p} must be in [0, 1)")
        denom = alloc.d.astype(np.float64) * (1.0 - p)
    else:
        q = np.asarray(rates, np.float64)
        if q.shape != (alloc.num_devices,):
            raise ValueError(f"need {alloc.num_devices} per-rank rates, got "
                             f"shape {q.shape}")
        if np.any(q < 0.0) or np.any(q > 1.0):
            raise ValueError("every participation rate must be in [0, 1]")
        if np.all(q == q[0]):
            # uniform rates: reduce to the eq.-3 product so the iid case is
            # bit-for-bit identical to encode_weights(alloc, p=1-q)
            denom = alloc.d.astype(np.float64) * q[0]
        else:
            denom = alloc.S.astype(np.float64).T @ q
        if np.any(denom <= 0.0):
            bad = np.nonzero(denom <= 0.0)[0].tolist()
            raise ValueError(
                f"subsets {bad} have zero expected coverage (every holder "
                f"has participation rate 0) — add redundancy on live ranks")
    W = alloc.S.astype(np.float64) / denom[None, :]
    return jnp.asarray(W, dtype=jnp.float32)


def straggler_mask(key: jax.Array, step: jax.Array | int, num_devices: int,
                   p: float) -> jnp.ndarray:
    """I^t in {0,1}^N: device i participates iff mask[i] = 1  (eq. 8).

    Deterministic in (key, step) so every mesh rank / host derives the same
    mask without communication (DESIGN.md Sec. 2).
    """
    k = jax.random.fold_in(key, jnp.asarray(step, dtype=jnp.uint32))
    return (jax.random.uniform(k, (num_devices,)) >= p).astype(jnp.float32)


def redundancy_theta(alloc: Allocation) -> float:
    """theta = sum_k (1/d_k - 1/N)   (eq. 18).  0 when d_k = N (full replication)."""
    d = alloc.d.astype(np.float64)
    return float(np.sum(1.0 / d - 1.0 / alloc.num_devices))
