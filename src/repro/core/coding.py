"""Stochastic gradient coding: data allocation, encoding, straggler model.

Implements the pairwise-balanced allocation of [31] (Sec. III of the paper),
the encoding weights 1/(d_k (1-p)) of eq. (3), the Bernoulli straggler model
of eq. (8), and the redundancy statistic theta (eq. 18).

Allocation happens once before training (host-side, numpy-free: we use jax
PRNG for reproducibility but materialize small static matrices).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Allocation",
    "random_allocation",
    "cyclic_allocation",
    "encode_weights",
    "straggler_mask",
    "redundancy_theta",
]


@dataclasses.dataclass(frozen=True)
class Allocation:
    """Static data-to-device allocation.

    S: (N, M) 0/1 matrix, S[i, k] = 1 iff subset k lives on device i.
    """

    S: np.ndarray  # (N, M) int8

    @property
    def num_devices(self) -> int:
        return self.S.shape[0]

    @property
    def num_subsets(self) -> int:
        return self.S.shape[1]

    @property
    def d(self) -> np.ndarray:
        """d_k = number of devices holding subset k, shape (M,)."""
        return self.S.sum(axis=0)

    def subsets_of(self, device: int) -> np.ndarray:
        return np.nonzero(self.S[device])[0]

    def validate(self) -> None:
        if (self.d == 0).any():
            raise ValueError("every subset must be allocated to >=1 device")


def random_allocation(seed: int, num_devices: int, num_subsets: int,
                      d: int) -> Allocation:
    """Uniform random allocation: subset k on d distinct random devices.

    This is the paper's practical approximation of the pairwise-balanced
    scheme (Sec. V.A): E[#devices holding both k1 and k2] = d^2/N.
    """
    rng = np.random.default_rng(seed)
    S = np.zeros((num_devices, num_subsets), dtype=np.int8)
    for k in range(num_subsets):
        devs = rng.choice(num_devices, size=min(d, num_devices), replace=False)
        S[devs, k] = 1
    alloc = Allocation(S=S)
    alloc.validate()
    return alloc


def cyclic_allocation(num_devices: int, num_subsets: int, d: int) -> Allocation:
    """Deterministic cyclic allocation: subset k on devices k, k+1, ..., k+d-1
    (mod N).  Exactly pairwise balanced when M = N (each pair of subsets at
    distance < d shares d - dist devices; used for regression tests where a
    deterministic S is wanted)."""
    S = np.zeros((num_devices, num_subsets), dtype=np.int8)
    for k in range(num_subsets):
        for j in range(min(d, num_devices)):
            S[(k + j) % num_devices, k] = 1
    alloc = Allocation(S=S)
    alloc.validate()
    return alloc


def encode_weights(alloc: Allocation, p: float) -> jnp.ndarray:
    """W[i, k] = S[i, k] / (d_k * (1 - p))   (eq. 3).

    Multiplying the (M, D) per-subset gradient stack by W yields the (N, D)
    coded vectors g_i^t.
    """
    if not 0.0 <= p < 1.0:
        raise ValueError(f"straggler probability p={p} must be in [0, 1)")
    d = alloc.d.astype(np.float64)
    W = alloc.S.astype(np.float64) / (d[None, :] * (1.0 - p))
    return jnp.asarray(W, dtype=jnp.float32)


def straggler_mask(key: jax.Array, step: jax.Array | int, num_devices: int,
                   p: float) -> jnp.ndarray:
    """I^t in {0,1}^N: device i participates iff mask[i] = 1  (eq. 8).

    Deterministic in (key, step) so every mesh rank / host derives the same
    mask without communication (DESIGN.md Sec. 2).
    """
    k = jax.random.fold_in(key, jnp.asarray(step, dtype=jnp.uint32))
    return (jax.random.uniform(k, (num_devices,)) >= p).astype(jnp.float32)


def redundancy_theta(alloc: Allocation) -> float:
    """theta = sum_k (1/d_k - 1/N)   (eq. 18).  0 when d_k = N (full replication)."""
    d = alloc.d.astype(np.float64)
    return float(np.sum(1.0 / d - 1.0 / alloc.num_devices))
