"""Reference (simulation) implementations of COCO-EF and all baselines.

These operate on explicit (N, D) device-major arrays and follow Algorithm 1
of the paper line by line.  They are used for the paper-reproduction
experiments (Figs. 2-7) and as the oracle for the distributed runtime in
`repro.core.cocoef` / `repro.launch.train` (which must produce bitwise the
same model update for the same mask/keys).

Methods (Sec. V):
  cocoef_step        COCO-EF   (proposed; biased C + error feedback)
  coco_step          COCO      (proposed w/o error feedback; e_i ≡ 0)
  unbiased_step      Unbiased  (1-bit gradient coding [32] / rand-K variant)
  unbiased_diff_step Unbiased-diff (gradient-difference compression [23])
  uncompressed_step  SGC [31]  (no compression; delta = 0 bound of Sec. IV)
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.ref import mul_add
from .compression import Compressor

__all__ = [
    "EFState",
    "DiffState",
    "cocoef_step",
    "coco_step",
    "unbiased_step",
    "unbiased_diff_step",
    "uncompressed_step",
]

GradFn = Callable[[jnp.ndarray], jnp.ndarray]  # theta (D,) -> per-subset grads (M, D)


class EFState(NamedTuple):
    """COCO-EF device state: theta (D,), error vectors e (N, D)."""

    theta: jnp.ndarray
    e: jnp.ndarray

    @staticmethod
    def init(theta: jnp.ndarray, num_devices: int) -> "EFState":
        return EFState(theta=theta,
                       e=jnp.zeros((num_devices,) + theta.shape, theta.dtype))


class DiffState(NamedTuple):
    """Gradient-difference compression state [23]: per-device reference h_i
    (N, D) and the server-side aggregate H = sum_i h_i (D,)."""

    theta: jnp.ndarray
    h: jnp.ndarray
    H: jnp.ndarray

    @staticmethod
    def init(theta: jnp.ndarray, num_devices: int) -> "DiffState":
        return DiffState(theta=theta,
                         h=jnp.zeros((num_devices,) + theta.shape, theta.dtype),
                         H=jnp.zeros_like(theta))


def _coded_gradients(grad_fn: GradFn, theta: jnp.ndarray,
                     W: jnp.ndarray) -> jnp.ndarray:
    """g_i = sum_k W[i,k] grad f_k(theta)   (eq. 3).  Returns (N, D)."""
    per_subset = grad_fn(theta)  # (M, D)
    return W @ per_subset


def _masked_sum(mask: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Server aggregate  sum_i mask_i * c_i  over the device axis (eq. 9),
    accumulated IN DEVICE ORDER (i = 0..N-1, lax.scan) — the SAME
    accumulation order the production collective's streaming decode_reduce
    uses, so the reference loop and the mesh `cocoef_update` agree
    BIT-FOR-BIT (the parity gate, repro.launch.parity) instead of up to
    f32 reduction-order ulps of `(m * c).sum(0)`."""
    m = mask.reshape((-1,) + (1,) * (c.ndim - 1)).astype(c.dtype)

    def body(acc, inp):
        mi, ci = inp
        return acc + mi * ci, None
    return lax.scan(body, jnp.zeros(c.shape[1:], c.dtype), (m, c))[0]


def _per_device_keys(key: Optional[jax.Array], step, n: int):
    if key is None:
        return None
    k = jax.random.fold_in(key, jnp.asarray(step, dtype=jnp.uint32))
    return jax.random.split(k, n)


@partial(jax.jit, static_argnames=("grad_fn", "compressor"))
def cocoef_step(state: EFState, grad_fn: GradFn, W: jnp.ndarray,
                mask: jnp.ndarray, gamma: float, compressor: Compressor,
                step: jax.Array | int = 0,
                key: Optional[jax.Array] = None) -> EFState:
    """One iteration of Algorithm 1 (COCO-EF).

    mask: (N,) float 0/1 straggler indicators I_i^t.
    gamma may be a traced scalar (supports decaying-lr experiments, Fig. 6).
    """
    g = _coded_gradients(grad_fn, state.theta, W)          # (N, D)
    # eq. (4) argument; mul_add = the ONE accumulate definition shared with
    # the production kernels (two-rounding f32, no FMA contraction) so the
    # parity gate can demand bit-for-bit trajectories
    acc = mul_add(gamma, g, state.e)
    keys = _per_device_keys(key, step, g.shape[0])
    if keys is None:
        c = jax.vmap(lambda v: compressor.apply(v))(acc)
    else:
        c = jax.vmap(lambda v, k: compressor.apply(v, k))(acc, keys)
    m = mask.reshape((-1,) + (1,) * (acc.ndim - 1))
    ghat = _masked_sum(mask, c)                            # eq. (9)
    theta = state.theta - ghat                             # eq. (10)
    e = jnp.where(m > 0, acc - c, state.e)                 # eq. (7) / frozen
    return EFState(theta=theta, e=e)


@partial(jax.jit, static_argnames=("grad_fn", "compressor"))
def coco_step(state: EFState, grad_fn: GradFn, W: jnp.ndarray,
              mask: jnp.ndarray, gamma: float, compressor: Compressor,
              step: jax.Array | int = 0,
              key: Optional[jax.Array] = None) -> EFState:
    """COCO: the proposed method with the error feedback disabled (e ≡ 0)."""
    g = _coded_gradients(grad_fn, state.theta, W)
    acc = gamma * g
    keys = _per_device_keys(key, step, g.shape[0])
    if keys is None:
        c = jax.vmap(lambda v: compressor.apply(v))(acc)
    else:
        c = jax.vmap(lambda v, k: compressor.apply(v, k))(acc, keys)
    theta = state.theta - _masked_sum(mask, c)
    return EFState(theta=theta, e=state.e)


@partial(jax.jit, static_argnames=("grad_fn", "compressor"))
def unbiased_step(state: EFState, grad_fn: GradFn, W: jnp.ndarray,
                  mask: jnp.ndarray, gamma: float, compressor: Compressor,
                  step: jax.Array | int = 0,
                  key: Optional[jax.Array] = None) -> EFState:
    """Unbiased baseline [32]: devices send Q(g_i) with an *unbiased* Q;
    server updates theta <- theta - gamma * sum_i I_i Q(g_i)."""
    g = _coded_gradients(grad_fn, state.theta, W)
    keys = _per_device_keys(key, step, g.shape[0])
    if keys is None:
        q = jax.vmap(lambda v: compressor.apply(v))(g)
    else:
        q = jax.vmap(lambda v, k: compressor.apply(v, k))(g, keys)
    theta = state.theta - gamma * _masked_sum(mask, q)
    return EFState(theta=theta, e=state.e)


@partial(jax.jit, static_argnames=("grad_fn", "compressor", "alpha"))
def unbiased_diff_step(state: DiffState, grad_fn: GradFn, W: jnp.ndarray,
                       mask: jnp.ndarray, gamma: float, compressor: Compressor,
                       step: jax.Array | int = 0,
                       key: Optional[jax.Array] = None,
                       alpha: float = 0.1) -> DiffState:
    """Unbiased-diff baseline: gradient-difference compression [23] (DIANA-
    style) on top of the coded gradients, with partial participation.

    Non-straggler i sends q_i = Q(g_i - h_i) and sets h_i <- h_i + alpha*q_i
    (alpha <= 1/(omega+1) is the standard DIANA reference step size; with
    alpha = 1 the high-variance 1-bit quantizer makes the reference diverge).
    The server holds H = sum_i h_i and computes
        ghat = H + sum_{non-straggler} q_i ,  H <- H + alpha * sum q_i,
    which equals sum_i h_i^{new} exactly.
    """
    g = _coded_gradients(grad_fn, state.theta, W)
    diff = g - state.h
    keys = _per_device_keys(key, step, g.shape[0])
    if keys is None:
        q = jax.vmap(lambda v: compressor.apply(v))(diff)
    else:
        q = jax.vmap(lambda v, k: compressor.apply(v, k))(diff, keys)
    m = mask.reshape((-1,) + (1,) * (g.ndim - 1))
    q_sum = _masked_sum(mask, q)
    ghat = state.H + q_sum
    theta = state.theta - gamma * ghat
    h = jnp.where(m > 0, state.h + alpha * q, state.h)
    return DiffState(theta=theta, h=h, H=state.H + alpha * q_sum)


@partial(jax.jit, static_argnames=("grad_fn",))
def uncompressed_step(state: EFState, grad_fn: GradFn, W: jnp.ndarray,
                      mask: jnp.ndarray, gamma: float,
                      step: jax.Array | int = 0) -> EFState:
    """Stochastic gradient coding [31]: dense coded vectors, no compression."""
    g = _coded_gradients(grad_fn, state.theta, W)
    theta = state.theta - gamma * _masked_sum(mask, g)
    return EFState(theta=theta, e=state.e)
