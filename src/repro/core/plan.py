"""PlanSpec: the single source of truth for a (d, wire, k) deployment config.

The paper's tradeoff surface has three axes — redundancy d (straggler
tolerance), the wire format (compressor + its knobs, i.e. uplink bytes), and
the bucket/backend execution schedule.  Before this module those knobs were
smeared across `TrainRun` fields, `configs.common.CodingCfg`, inline
`CocoEFConfig` construction in `launch.train.build_train_setup`, and
per-benchmark plumbing.  A `PlanSpec` is ONE frozen, serializable record of a
deployment configuration; everything else derives from it:

  plan.wire(n, nd)                  -> the WireFormat actually shipped
  plan.coding_collective_config()   -> the collective config for the mesh step
  plan.rank_wire_bytes(n)           -> per-rank uplink bytes (StepTimer price)

so "the config priced is the config run" is a property of the type, not a
per-benchmark convention.  `sim.planner.plan_search` enumerates PlanSpecs and
`launch.train.TrainRun(plan=...)` executes one.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Any, Dict, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from .collectives import (CodingCollectiveConfig, DenseWire, SignWire,
                          SparseWire, WireFormat)

__all__ = ["PlanSpec", "build_wire", "PLAN_SCHEMA", "ALLOCATIONS",
           "PLAN_COMPRESSORS", "BUCKET_SCHEDULES", "PLAN_BACKENDS"]

PLAN_SCHEMA = "repro.plan/v1"
ALLOCATIONS = ("uniform", "rate_aware", "exact_load")
PLAN_COMPRESSORS = ("sign", "block_topk", "topk", "identity")
BUCKET_SCHEDULES = ("serial", "pipelined")
PLAN_BACKENDS = ("auto", "pallas", "jnp")


def build_wire(compressor: str, *, group_size: int = 512,
               k_per_block: Union[int, Tuple[int, ...]] = 8,
               block_size: int = 256, topk_k: int = 64,
               value_dtype: str = "float32", n: int = 0, nd: int = 1,
               num_buckets: int = 1) -> WireFormat:
    """Wire format for one bucket of `n` coords over `nd` all_to_all chunks.

    This is THE mapping from compressor name + knobs to a WireFormat; both
    `PlanSpec.wire` and `CocoEFConfig.wire_format` delegate here so the two
    config planes can never drift.
    """
    if compressor == "sign":
        return SignWire(group_size=group_size)
    if compressor == "block_topk":
        return SparseWire(k_per_block=k_per_block, block_size=block_size,
                          value_dtype=value_dtype)
    if compressor == "topk":
        # global top-K realized as one block per all_to_all chunk with an
        # equal per-chunk budget (fixed-shape payload; see
        # collectives.wire_for_compressor).  topk_k is the GLOBAL budget,
        # so it is split across nd chunks AND num_buckets.
        block = n // nd
        kb = -(-topk_k // (nd * num_buckets))
        return SparseWire(k_per_block=min(block, kb), block_size=block,
                          value_dtype=value_dtype)
    if compressor == "identity":
        return DenseWire(value_dtype=value_dtype)
    raise ValueError(f"unknown compressor {compressor!r}")


@dataclasses.dataclass(frozen=True)
class PlanSpec:
    """One deployment configuration of the coded-compressed trainer.

    `num_ranks` is the coding-rank count the plan targets; it is optional at
    authoring time (the launcher fills it from the mesh), but when set it
    gates the per-rank budget length at CONSTRUCTION — a wrong-length
    `k_per_block` tuple fails here with a real message instead of surfacing
    as an opaque shape error inside jit.
    """

    d: int = 2                          # redundancy (copies per data shard)
    allocation: str = "uniform"         # uniform | rate_aware | exact_load
    compressor: str = "sign"            # sign | block_topk | topk | identity
    group_size: int = 512               # sign group (also phase-2 packing)
    k_per_block: Union[int, Tuple[int, ...]] = 8
    # ^ kept coords per block (block_topk); a per-rank tuple is a per-rank
    #   k budget (sim.cost_model.solve_k_budgets output)
    block_size: int = 256               # sparsification block (block_topk)
    topk_k: int = 64                    # global-K budget (compressor="topk")
    value_dtype: str = "float32"        # sparse values / dense payload dtype
    num_buckets: int = 1                # flat-vector split for comm overlap
    bucket_schedule: str = "pipelined"  # pipelined | serial
    backend: str = "auto"               # auto | pallas | jnp
    num_ranks: Optional[int] = None     # coding-rank count (None = unbound)

    def __post_init__(self):
        if isinstance(self.k_per_block, (list, tuple)):
            ks = tuple(self.k_per_block)
            if any(int(k) != k for k in ks):
                raise ValueError(f"per-rank k budgets must be integers, "
                                 f"got {ks}")
            # normalize to plain ints (solve_k_budgets hands back np ints)
            object.__setattr__(self, "k_per_block",
                               tuple(int(k) for k in ks))
        if self.d < 1:
            raise ValueError(f"redundancy d must be >= 1, got {self.d}")
        if self.allocation not in ALLOCATIONS:
            raise ValueError(f"unknown allocation {self.allocation!r}; "
                             f"have {ALLOCATIONS}")
        if self.compressor not in PLAN_COMPRESSORS:
            raise ValueError(f"unknown compressor {self.compressor!r}; "
                             f"have {PLAN_COMPRESSORS}")
        if self.group_size < 1:
            raise ValueError(f"group_size must be >= 1, got {self.group_size}")
        if self.block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {self.block_size}")
        if self.topk_k < 1:
            raise ValueError(f"topk_k must be >= 1, got {self.topk_k}")
        if self.num_buckets < 1:
            raise ValueError(f"num_buckets must be >= 1, "
                             f"got {self.num_buckets}")
        if self.bucket_schedule not in BUCKET_SCHEDULES:
            raise ValueError(f"unknown bucket_schedule "
                             f"{self.bucket_schedule!r}; "
                             f"have {BUCKET_SCHEDULES}")
        if self.backend not in PLAN_BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"have {PLAN_BACKENDS}")
        if self.num_ranks is not None and self.num_ranks < 1:
            raise ValueError(f"num_ranks must be >= 1, got {self.num_ranks}")
        if self.num_ranks is not None and self.d > self.num_ranks:
            raise ValueError(f"redundancy d={self.d} exceeds the coding-rank "
                             f"count num_ranks={self.num_ranks}")
        if isinstance(self.k_per_block, tuple):
            if self.compressor != "block_topk":
                raise ValueError("per-rank k budgets (tuple k_per_block) "
                                 "require compressor='block_topk', got "
                                 f"{self.compressor!r}")
            if not self.k_per_block:
                raise ValueError("per-rank k budgets must be non-empty")
            if any(k < 1 for k in self.k_per_block):
                raise ValueError(f"per-rank k budgets must be ints >= 1, "
                                 f"got {self.k_per_block}")
            if (self.num_ranks is not None
                    and len(self.k_per_block) != self.num_ranks):
                raise ValueError(
                    f"per-rank k budgets have {len(self.k_per_block)} "
                    f"entries but the plan targets num_ranks="
                    f"{self.num_ranks} coding ranks; pass one k per rank")
        elif self.k_per_block < 1:
            raise ValueError(f"k_per_block must be >= 1, "
                             f"got {self.k_per_block}")

    # -- derivation ---------------------------------------------------------

    def wire(self, n: int = 0, nd: int = 1) -> WireFormat:
        """The WireFormat this plan ships for one bucket of `n` coords."""
        return build_wire(self.compressor, group_size=self.group_size,
                          k_per_block=self.k_per_block,
                          block_size=self.block_size, topk_k=self.topk_k,
                          value_dtype=self.value_dtype, n=n, nd=nd,
                          num_buckets=self.num_buckets)

    def coding_collective_config(self, coding_axes: Tuple[str, ...] = ("data",),
                                 phase2_dtype: str = "float32",
                                 phase2_sign: bool = False
                                 ) -> CodingCollectiveConfig:
        """The collective config the mesh step runs this plan with."""
        return CodingCollectiveConfig(coding_axes=tuple(coding_axes),
                                      group_size=self.group_size,
                                      phase2_dtype=jnp.dtype(phase2_dtype),
                                      phase2_sign=phase2_sign,
                                      backend=self.backend)

    def rank_wire_bytes(self, n: int,
                        num_ranks: Optional[int] = None) -> np.ndarray:
        """Per-rank phase-1 uplink bytes for an `n`-coord flat vector — the
        quantity StepTimer prices and benchmarks/comm_volume audits."""
        m = num_ranks if num_ranks is not None else self.num_ranks
        if m is None:
            raise ValueError("rank_wire_bytes needs num_ranks (pass it or "
                             "set PlanSpec.num_ranks)")
        return self.wire(n, 1).rank_wire_bytes(n, m)

    @property
    def pad_multiple(self) -> int:
        """Per-bucket flat-size alignment (mirrors CocoEFConfig)."""
        if self.compressor == "block_topk":
            return math.lcm(self.group_size, self.block_size)
        return self.group_size

    @property
    def overlap(self) -> bool:
        """Whether StepTimer should price the pipelined bucket overlap."""
        return self.bucket_schedule == "pipelined" and self.num_buckets > 1

    # -- serialization ------------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        if isinstance(d["k_per_block"], tuple):
            d["k_per_block"] = list(d["k_per_block"])
        return {"schema": PLAN_SCHEMA, **d}

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, obj: Dict[str, Any]) -> "PlanSpec":
        obj = dict(obj)
        schema = obj.pop("schema", PLAN_SCHEMA)
        if schema != PLAN_SCHEMA:
            raise ValueError(f"unknown plan schema {schema!r}; "
                             f"expected {PLAN_SCHEMA!r}")
        names = {f.name for f in dataclasses.fields(cls)}
        unknown = set(obj) - names
        if unknown:
            raise ValueError(f"unknown PlanSpec fields {sorted(unknown)}")
        if isinstance(obj.get("k_per_block"), list):
            obj["k_per_block"] = tuple(int(k) for k in obj["k_per_block"])
        return cls(**obj)

    @classmethod
    def from_json(cls, text: str) -> "PlanSpec":
        return cls.from_dict(json.loads(text))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(indent=2) + "\n")

    @classmethod
    def load(cls, path: str) -> "PlanSpec":
        with open(path) as f:
            return cls.from_json(f.read())
