"""Wire-compressed collective aggregation for COCO-EF on a TPU mesh.

The paper's device->server->device exchange maps onto a two-phase collective
over the coding axes (DESIGN.md Sec. 2):

  phase 1 (device -> "server"):  each coding rank packs C(acc_i) into its
     wire format and `all_to_all`s chunk j to rank j; rank j decodes every
     sender's chunk, applies the straggler mask of the *sender*, and sums.
     This leg carries the compressed payload -> ~26x fewer bytes than a
     dense f32 all-reduce leg for group_size=512 sign quantization, and
     ~21x for block top-K at k/B = 8/512.
  phase 2 ("server" -> device):  the aggregated dense chunk is `all_gather`ed
     back.  Paper-faithful mode sends f32 (the paper's server broadcast is
     uncompressed); `phase2_dtype=bf16` and `phase2_sign=True` are
     beyond-paper options evaluated in EXPERIMENTS.md §Perf.

When the coding runs over two mesh axes (e.g. ("pod", "data")) the phases are
hierarchical: all_to_all within the minor axis, psum across the major axis on
the decoded chunk, gather within the minor axis.

WireFormat contract
-------------------
A `WireFormat` is a frozen dataclass describing how a flat f32 vector is
serialized for the phase-1 leg.  Implementations provide:

  pack(x)          (n,) f32 -> tuple of arrays (the payload).  Every payload
                   leaf has leading dimension proportional to n, so chunking
                   for the all_to_all is the generic reshape
                   `leaf.reshape((nd, leaf.shape[0] // nd) + rest)`.
  unpack(payload)  payload -> (n,) f32, the decompressed vector.  Must be
                   vmap-safe (it is vmapped over senders on the decode side).
  wire_bytes(n)    bytes on the wire for one rank's phase-1 payload.
  check(n, nd)     raise ValueError unless n is compatible with this wire
                   format and `nd` all_to_all chunks (pad upstream with
                   `repro.core.cocoef.padded_size`).
  alignment()      n must be a multiple of `nd * alignment()`.

`roundtrip(x) = unpack(pack(x))` realizes the wire's compressor on the train
path: SignWire <-> GroupedSign (lossless re-pack), SparseWire <-> BlockTopK
(1-2 ulp from the per-block scale normalization), DenseWire <-> Identity.
Roundtrips are idempotent, so the collective may pack an already-compressed
vector without changing it (beyond ulp-level rescaling noise).

Fused execution backend
-----------------------
The train hot path does NOT run the pure-jnp pack/unpack above — those are
the semantic contract (and the `backend="jnp"` reference).  Two fused entry
points route the per-step work through `repro.kernels` (Pallas on TPU,
interpret mode elsewhere, jnp oracles as the fallback):

  fused_local_step(g, e, gamma, mask_self)
                   one HBM pass producing (payload, c, e_new) — the whole
                   Algorithm-1 local step (accumulate + compress + error
                   update) without materializing intermediates.
  decode_reduce(payloads, sender_mask)
                   fused decode + straggler-mask + sum over senders; never
                   materializes the per-sender dense (nd, n/nd) tensor.
  payload_n(payload)
                   flat length a payload represents (lets hot-path callers
                   skip carrying the dense c alongside the payload).

Base-class implementations compose pack/unpack in plain jnp, so every new
wire format arrives with a working fused path by construction; SignWire and
SparseWire override them to dispatch into `kernels.ops` (ef_sign_fused /
ef_topk_fused / sign_decode_reduce / topk_decode_reduce).  The
`CodingCollectiveConfig.backend` knob ("auto" | "pallas" | "jnp") selects
the implementation; "auto" uses Pallas exactly when running on TPU.

Everything here runs inside a *fully manual* shard_map: inputs are the
device-local flat gradient/error vectors.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.compat import axis_size
from repro.kernels import ops as kernel_ops, ref as kernel_ref
from repro.kernels.sign_pack import G_BLK as _SIGN_G_BLK
from repro.kernels.topk_pack import R_BLK as _TOPK_R_BLK

__all__ = [
    "sign_pack",
    "sign_unpack",
    "WireFormat",
    "SignWire",
    "SparseWire",
    "DenseWire",
    "get_wire",
    "wire_for_compressor",
    "CodingCollectiveConfig",
    "InFlightAggregate",
    "coded_allreduce_start",
    "two_phase_coded_allreduce",
    "two_phase_sign_allreduce",
    "dense_allreduce",
    "wire_bytes_sign",
]


# --------------------------------------------------------------------------
# sign wire primitives (shared with kernels/ref.py semantics)
# --------------------------------------------------------------------------

def sign_pack(x: jnp.ndarray, group_size: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pack a flat f32 vector into (bits: uint32 (n/32,), scales: f32 (n/g,)).

    scales[m] = ||x_m||_1 / |I_m|  (eq. 5); bit j of word w = 1  iff
    x[32*w + j] >= 0.  Requires n % lcm(32, group_size) == 0 (pad upstream).
    """
    n = x.shape[0]
    g = group_size
    if n % g or g % 32:
        raise ValueError(f"need group_size % 32 == 0 and n % group_size == 0 "
                         f"(n={n}, g={g})")
    xf = x.astype(jnp.float32)
    scales = jnp.mean(jnp.abs(xf.reshape(-1, g)), axis=-1)
    bits = (xf >= 0).reshape(-1, 32).astype(jnp.uint32)
    words = (bits << jnp.arange(32, dtype=jnp.uint32)).sum(-1, dtype=jnp.uint32)
    return words, scales


def sign_unpack(words: jnp.ndarray, scales: jnp.ndarray, group_size: int,
                dtype=jnp.float32) -> jnp.ndarray:
    """Inverse of sign_pack: returns sign(x) * scale_group, flat (n,)."""
    bits = (words[:, None] >> jnp.arange(32, dtype=jnp.uint32)) & jnp.uint32(1)
    signs = bits.astype(dtype).reshape(-1, group_size) * 2.0 - 1.0
    # per-group scale via broadcast (jnp.repeat lowers to a scatter loop)
    return (signs * scales.astype(dtype)[:, None]).reshape(-1)


def wire_bytes_sign(n: int, group_size: int) -> int:
    """Bytes on the wire for one rank's phase-1 payload."""
    return n // 8 + 4 * (n // group_size)


# --------------------------------------------------------------------------
# wire formats
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WireFormat:
    """Base class; subclasses are frozen dataclasses => valid static args."""

    def pack(self, x: jnp.ndarray) -> Tuple[jnp.ndarray, ...]:
        raise NotImplementedError

    def unpack(self, payload: Tuple[jnp.ndarray, ...]) -> jnp.ndarray:
        raise NotImplementedError

    def wire_bytes(self, n: int) -> int:
        raise NotImplementedError

    def alignment(self) -> int:
        raise NotImplementedError

    def check(self, n: int, nd: int = 1) -> None:
        a = self.alignment()
        if n <= 0 or n % (nd * a):
            raise ValueError(
                f"{type(self).__name__}: flat size {n} must be a positive "
                f"multiple of chunk_count*alignment = {nd}*{a}; pad upstream")

    def roundtrip(self, x: jnp.ndarray) -> jnp.ndarray:
        """The wire's compressor: what the receivers reconstruct."""
        return self.unpack(self.pack(x))

    # ---- fused execution backend (see module docstring) -------------------
    # Base implementations compose pack/unpack in plain jnp so that every
    # wire format has a working fused path by construction; performance-
    # critical wires override them to dispatch into repro.kernels.

    def payload_n(self, payload: Tuple[jnp.ndarray, ...]) -> int:
        """Flat length (n) the payload represents.  The base default
        decompresses to find out — correct for any wire, but traces an
        unpack; override with shape arithmetic (all built-ins do)."""
        return self.unpack(payload).shape[0]

    def fused_local_step(self, g: jnp.ndarray, e: jnp.ndarray, gamma,
                         mask_self, use_pallas: Optional[bool] = None,
                         want_c: bool = True
                         ) -> Tuple[Tuple[jnp.ndarray, ...],
                                    Optional[jnp.ndarray], jnp.ndarray]:
        """Whole Algorithm-1 local step in one pass over the flat vectors:

          acc     = gamma * g + e
          payload = pack(acc)
          c       = the transmitted reconstruction C(acc)
          e_new   = mask_self ? acc - c : e

        Returns (payload, c, e_new); c and e_new are f32.  `use_pallas`
        overrides the platform default (None = Pallas iff on TPU).
        want_c=False returns c=None and lets the kernels skip the
        full-vector c store (the train path only ships the payload)."""
        acc = kernel_ref.mul_add(gamma, g, e)
        payload = self.pack(acc)
        c = self.unpack(payload)
        e_new = jnp.where(mask_self > 0, acc - c, e.astype(jnp.float32))
        return payload, (c if want_c else None), e_new

    def fused_pack(self, x: jnp.ndarray, use_pallas: Optional[bool] = None
                   ) -> Tuple[jnp.ndarray, ...]:
        """pack(x) through the kernel backend (the non-EF hot path, where
        no error state is carried and no reconstruction c is needed)."""
        return self.pack(x)

    def decode_reduce(self, payloads: Tuple[jnp.ndarray, ...],
                      sender_mask: jnp.ndarray,
                      use_pallas: Optional[bool] = None) -> jnp.ndarray:
        """sum_i sender_mask_i * unpack(payloads_i) over the leading
        (sender) dimension of every payload leaf -> (n,) f32."""
        decoded = jax.vmap(lambda *p: self.unpack(p))(*payloads)
        return (sender_mask[:, None] * decoded).sum(axis=0)

    # ---- per-rank adaptive wire budgets -----------------------------------
    # A wire may carry a *per-rank* budget vector (SparseWire with a tuple
    # k_per_block, sized by repro.sim.cost_model.solve_k_budgets): every rank
    # ships the same fixed payload SHAPE (the all_to_all needs static
    # shapes), but entries beyond rank i's budget are zeroed at pack time
    # and charged per-rank by the cost model / comm audit.

    def has_rank_budgets(self) -> bool:
        """True when this wire carries per-rank budgets (see SparseWire)."""
        return False

    def apply_rank_budget(self, payload: Tuple[jnp.ndarray, ...],
                          rank) -> Tuple[jnp.ndarray, ...]:
        """Zero payload entries beyond `rank`'s budget (identity for
        uniform-budget wires).  `rank` may be a traced scalar."""
        return payload

    def rank_wire_bytes(self, n: int, num_ranks: int) -> np.ndarray:
        """(num_ranks,) int64 phase-1 bytes per rank — the per-rank
        refinement of `wire_bytes` (uniform unless the wire carries
        per-rank budgets)."""
        return np.full((num_ranks,), int(self.wire_bytes(n)), np.int64)


@dataclasses.dataclass(frozen=True)
class SignWire(WireFormat):
    """Grouped sign quantization on the wire: 1 bit/coord + f32 scale/group.

    Exactly representable inputs (sign(x)*scale_group, incl. StochasticSign
    outputs) roundtrip bit-for-bit; sign(±0) := +1.
    """

    group_size: int = 512

    def pack(self, x):
        return sign_pack(x, self.group_size)

    def unpack(self, payload):
        words, scales = payload
        return sign_unpack(words, scales, self.group_size)

    def wire_bytes(self, n):
        return wire_bytes_sign(n, self.group_size)

    def alignment(self):
        return self.group_size

    def payload_n(self, payload):
        return payload[0].shape[0] * 32

    def _tile(self) -> int:
        return _SIGN_G_BLK * self.group_size

    def fused_pack(self, x, use_pallas=None):
        use = kernel_ops.resolve_use_pallas(use_pallas, x.shape[0],
                                            self._tile(), op="sign_pack",
                                            dtype=x.dtype)
        with jax.named_scope("wire/sign_pack"):
            return kernel_ops.sign_pack(x, self.group_size, use_pallas=use)

    def fused_local_step(self, g, e, gamma, mask_self, use_pallas=None,
                         want_c=True):
        use = kernel_ops.resolve_use_pallas(use_pallas, g.shape[0],
                                            self._tile(),
                                            op="ef_sign_fused", dtype=g.dtype)
        with jax.named_scope("wire/ef_sign_local_step"):
            words, scales, c, e_new = kernel_ops.ef_sign_fused(
                g, e, gamma, mask_self, self.group_size, want_c=want_c,
                use_pallas=use)
        return (words, scales), c, e_new

    def decode_reduce(self, payloads, sender_mask, use_pallas=None):
        words, scales = payloads
        use = kernel_ops.resolve_use_pallas(use_pallas, words.shape[1] * 32,
                                            self._tile(),
                                            op="sign_decode_reduce",
                                            dtype=scales.dtype)
        with jax.named_scope("wire/sign_decode_reduce"):
            return kernel_ops.sign_decode_reduce(words, scales, sender_mask,
                                                 self.group_size,
                                                 use_pallas=use)


@dataclasses.dataclass(frozen=True)
class SparseWire(WireFormat):
    """Block-local top-K on the wire (Ye & Abbe 2018 comm-efficient coding).

    Payload per block of `block_size` coords:
      indices : (nblocks, k) uint16 (uint32 when block_size > 65536) —
                in-block positions of the k largest-|.| entries, in
                decreasing-magnitude order, first occurrence wins ties
                (matches `kernels.topk_block` / `lax.top_k`).
      values  : (nblocks, k) value_dtype — kept entries normalized by the
                block scale (|v| <= 1), enabling narrow value dtypes.
      scales  : (nblocks,) f32 — per-block max-|.| (1.0 for all-zero blocks).

    roundtrip == BlockTopK.apply up to 1-2 ulp of the scale normalization;
    delta = 1 - k/block_size (Assumption 5).

    `k_per_block` may be a per-rank tuple (one budget per coding rank,
    typically from `repro.sim.cost_model.solve_k_budgets`): the payload is
    shaped by max(k) on every rank (static shapes for the all_to_all), and
    `apply_rank_budget` zeroes the values beyond rank i's budget so
    slow-uplink ranks effectively send fewer coordinates.  `wire_bytes`
    then reports the max-budget (shipped-shape) bytes; the honest per-rank
    on-the-wire accounting is `rank_wire_bytes` (zeros beyond the budget
    cost nothing under length framing), which is what the cost model and
    the comm-volume audit charge.
    """

    k_per_block: Union[int, Tuple[int, ...]] = 8
    block_size: int = 256
    value_dtype: str = "float32"

    def __post_init__(self):
        ks = self.k_per_block
        if isinstance(ks, (list, tuple, np.ndarray)):
            ks = tuple(int(k) for k in np.asarray(ks).reshape(-1))
            if not ks:
                raise ValueError("per-rank k_per_block must be non-empty")
            object.__setattr__(self, "k_per_block", ks)
        else:
            ks = (int(ks),)
        for k in ks:
            if not (0 < k <= self.block_size):
                raise ValueError(f"need 0 < k_per_block <= block_size, got "
                                 f"{k} / {self.block_size}")

    @property
    def k_max(self) -> int:
        """Largest per-rank budget = the shipped payload's k dimension."""
        ks = self.k_per_block
        return max(ks) if isinstance(ks, tuple) else ks

    def has_rank_budgets(self) -> bool:
        return isinstance(self.k_per_block, tuple)

    def for_rank(self, rank: int) -> "SparseWire":
        """The scalar-budget wire rank `rank` semantically transmits."""
        if not self.has_rank_budgets():
            return self
        return dataclasses.replace(
            self, k_per_block=int(self.k_per_block[rank]))

    def apply_rank_budget(self, payload, rank):
        if not self.has_rank_budgets():
            return payload
        idx, values, scales = payload
        k_i = jnp.asarray(self.k_per_block, jnp.int32)[
            jnp.asarray(rank, jnp.int32)]
        keep = jnp.arange(self.k_max, dtype=jnp.int32) < k_i     # (k_max,)
        # top-k indices within a block are distinct, so zeroing the values
        # beyond the budget is exactly the k_i-budget payload
        values = jnp.where(keep[None, :], values, jnp.zeros_like(values))
        return idx, values, scales

    def rank_wire_bytes(self, n, num_ranks):
        if not self.has_rank_budgets():
            return np.full((num_ranks,), int(self.wire_bytes(n)), np.int64)
        if len(self.k_per_block) != num_ranks:
            raise ValueError(f"wire has {len(self.k_per_block)} per-rank "
                             f"budgets, asked for {num_ranks} ranks")
        return np.asarray([self.for_rank(i).wire_bytes(n)
                           for i in range(num_ranks)], np.int64)

    @property
    def index_dtype(self):
        return jnp.uint16 if self.block_size <= (1 << 16) else jnp.uint32

    def pack(self, x):
        xf = x.astype(jnp.float32)
        blocks = xf.reshape(-1, self.block_size)
        mag = jnp.abs(blocks)
        topv, idx = lax.top_k(mag, self.k_max)              # (nb, k)
        sv = jnp.take_along_axis(blocks, idx, axis=-1)      # signed values
        scale = topv[:, 0]            # block max |.| = first top-k value
        safe = jnp.where(scale == 0, 1.0, scale)
        values = (sv / safe[:, None]).astype(jnp.dtype(self.value_dtype))
        return idx.astype(self.index_dtype), values, safe

    def unpack(self, payload):
        idx, values, scales = payload
        nb, k = idx.shape
        n = nb * self.block_size
        sv = values.astype(jnp.float32) * scales[:, None]
        base = jnp.arange(nb, dtype=jnp.int32)[:, None] * self.block_size
        flat_idx = (base + idx.astype(jnp.int32)).reshape(-1)
        return jnp.zeros((n,), jnp.float32).at[flat_idx].set(sv.reshape(-1))

    def wire_bytes(self, n):
        nb = n // self.block_size
        idx_b = 2 if self.block_size <= (1 << 16) else 4
        val_b = jnp.dtype(self.value_dtype).itemsize
        return nb * (self.k_max * (idx_b + val_b) + 4)  # + f32 scale

    def alignment(self):
        return self.block_size

    def payload_n(self, payload):
        return payload[2].shape[0] * self.block_size

    def _tile(self) -> int:
        return _TOPK_R_BLK * self.block_size

    def fused_pack(self, x, use_pallas=None):
        use = kernel_ops.resolve_use_pallas(use_pallas, x.shape[0],
                                            self._tile(), op="topk_pack",
                                            dtype=self.value_dtype)
        with jax.named_scope("wire/topk_pack"):
            idx, val, scale = kernel_ops.topk_pack(x, self.k_max,
                                                   self.block_size,
                                                   use_pallas=use)
        return (idx.astype(self.index_dtype),
                val.astype(jnp.dtype(self.value_dtype)), scale)

    def fused_local_step(self, g, e, gamma, mask_self, use_pallas=None,
                         want_c=True):
        use = kernel_ops.resolve_use_pallas(use_pallas, g.shape[0],
                                            self._tile(),
                                            op="ef_topk_fused",
                                            dtype=self.value_dtype)
        # The kernels quantize in-register (normalize -> value_dtype ->
        # denormalize), so their c IS the transmitted reconstruction the
        # receivers decode (`values * scale` after value-dtype rounding)
        # and e_new already tracks acc - C(acc) with C == unpack∘pack —
        # which the reference-vs-mesh parity gate demands of the error
        # vector.  No unpack-of-pack scatter here, and want_c=False lets
        # the kernel skip the full-vector c store again.
        with jax.named_scope("wire/ef_topk_local_step"):
            idx, val, scale, c_q, e_new = kernel_ops.ef_topk_fused(
                g, e, gamma, mask_self, self.k_max, self.block_size,
                want_c=want_c, value_dtype=self.value_dtype, use_pallas=use)
        # val carries value_dtype-rounded numbers in f32: the cast is exact
        payload = (idx.astype(self.index_dtype),
                   val.astype(jnp.dtype(self.value_dtype)), scale)
        return payload, c_q, e_new

    def decode_reduce(self, payloads, sender_mask, use_pallas=None):
        idx, val, scales = payloads
        use = kernel_ops.resolve_use_pallas(
            use_pallas, idx.shape[1] * self.block_size, self._tile(),
            op="topk_decode_reduce", dtype=self.value_dtype)
        with jax.named_scope("wire/topk_decode_reduce"):
            return kernel_ops.topk_decode_reduce(idx, val, scales,
                                                 sender_mask,
                                                 self.block_size,
                                                 use_pallas=use)


@dataclasses.dataclass(frozen=True)
class DenseWire(WireFormat):
    """Uncompressed fallback: the flat vector, optionally narrowed to bf16.

    f32 roundtrips bit-exact (the SGC [31] baseline wire); bf16 is the
    beyond-paper half-width dense wire.
    """

    value_dtype: str = "float32"

    def pack(self, x):
        return (x.astype(jnp.dtype(self.value_dtype)),)

    def unpack(self, payload):
        return payload[0].astype(jnp.float32)

    def wire_bytes(self, n):
        return n * jnp.dtype(self.value_dtype).itemsize

    def alignment(self):
        return 1

    def payload_n(self, payload):
        return payload[0].shape[0]

    def decode_reduce(self, payloads, sender_mask, use_pallas=None):
        return kernel_ops.dense_decode_reduce(payloads[0], sender_mask,
                                              use_pallas=use_pallas)


_WIRE_REGISTRY = {
    # NOTE: no "topk" alias — the global-top-K spelling of
    # CocoEFConfig.compressor needs (n, nd) to size its per-chunk blocks;
    # use wire_for_compressor / CocoEFConfig.wire_format for that.
    "sign": SignWire,
    "sparse": SparseWire,
    "dense": DenseWire,
}


def get_wire(name: str, **kwargs) -> WireFormat:
    if name not in _WIRE_REGISTRY:
        raise KeyError(f"unknown wire format {name!r}; "
                       f"have {sorted(_WIRE_REGISTRY)}")
    return _WIRE_REGISTRY[name](**kwargs)


def wire_for_compressor(comp, n: int, nd: int = 1) -> WireFormat:
    """Map a `repro.core.compression.Compressor` onto the wire format that
    carries it on the coded collective (`n` = flat size, `nd` = chunk count).

    Global TopK / RandK have no fixed-shape per-chunk payload, so they ride
    the sparse wire with one block per all_to_all chunk and an equal
    per-chunk budget ceil(k/nd) (RandK additionally gets 2x capacity slack;
    coords beyond the budget in one chunk are dropped — documented
    approximation, still a contraction).
    """
    from .compression import (BlockTopK, GroupedSign, Identity, RandK,
                              StochasticSign, TopK)
    if isinstance(comp, (GroupedSign, StochasticSign)):
        g = comp.group_size if comp.group_size > 0 else n
        return SignWire(group_size=g)
    if isinstance(comp, BlockTopK):
        return SparseWire(k_per_block=comp.k_per_block,
                          block_size=comp.block_size)
    if isinstance(comp, TopK):
        block = n // nd
        return SparseWire(k_per_block=min(block, math.ceil(comp.k / nd)),
                          block_size=block)
    if isinstance(comp, RandK):
        block = n // nd
        return SparseWire(k_per_block=min(block, 2 * math.ceil(comp.k / nd)),
                          block_size=block)
    if isinstance(comp, Identity):
        return DenseWire()
    raise TypeError(f"no wire format for compressor {type(comp).__name__}")


# --------------------------------------------------------------------------
# collective aggregation
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CodingCollectiveConfig:
    """Static config for the coded aggregation.

    coding_axes: mesh axis names the COCO-EF 'devices' live on.  The last
      axis is the all_to_all/gather (chunking) axis; any earlier axes are
      reduced hierarchically with a dense psum of the (small) decoded chunk.
    group_size: sign-quantization group (multiple of 32); also the phase-2
      re-compression group when phase2_sign is on.
    phase2_dtype: dtype of the aggregated update broadcast (f32 = paper).
    """

    coding_axes: Tuple[str, ...] = ("data",)
    group_size: int = 512
    phase2_dtype: jnp.dtype = jnp.float32
    phase2_sign: bool = False  # beyond-paper: sign-compress the broadcast
    backend: str = "auto"      # auto | pallas | jnp (kernel dispatch)

    @property
    def chunk_axis(self) -> str:
        return self.coding_axes[-1]

    @property
    def outer_axes(self) -> Tuple[str, ...]:
        return self.coding_axes[:-1]


def _chunk_count(axis: str) -> int:
    return axis_size(axis)


@dataclasses.dataclass
class InFlightAggregate:
    """Phase-1 state of a coded allreduce whose all_to_all has been issued
    but whose decode / phase 2 has not.

    The double-buffered bucket schedule (`repro.core.cocoef` with
    `bucket_schedule="pipelined"`) traces bucket i+1's fused local step
    between `coded_allreduce_start(bucket_i)` and this handle's `finish()`,
    giving XLA's async collectives / latency-hiding scheduler a window to
    overlap bucket i's wire transfer with bucket i+1's compute.  The values
    are untouched — finishing later is bit-for-bit the serial schedule."""

    recv: Tuple[jnp.ndarray, ...]
    sender_mask: jnp.ndarray
    wire: WireFormat
    cfg: CodingCollectiveConfig

    def finish(self) -> jnp.ndarray:
        """Decode + mask + reduce the received chunks, run phase 2; returns
        the (n,) aggregate, identical on every coding rank."""
        with jax.named_scope("coded/decode_reduce"):
            chunk_sum = self.wire.decode_reduce(
                self.recv, self.sender_mask,
                use_pallas=kernel_ops.backend_use_pallas(self.cfg.backend))
            for ax in self.cfg.outer_axes:
                chunk_sum = lax.psum(chunk_sum, ax)
        with jax.named_scope("coded/phase2_gather"):
            return _phase2_gather(chunk_sum, self.cfg)


def coded_allreduce_start(
    wire: WireFormat,
    cfg: CodingCollectiveConfig,
    mask: jnp.ndarray,
    payload: Tuple[jnp.ndarray, ...],
) -> InFlightAggregate:
    """Issue phase 1 of the coded allreduce — chunk the payload and
    all_to_all it over the chunk axis — and return the in-flight handle
    whose `finish()` completes decode + phase 2."""
    n = wire.payload_n(payload)
    nd = _chunk_count(cfg.chunk_axis)
    wire.check(n, nd)

    # ---- phase 1: all_to_all compressed chunks over the chunk axis -------
    # generic chunking: every payload leaf's leading dim is proportional to n
    with jax.named_scope("coded/phase1_all_to_all"):
        chunked = tuple(p.reshape((nd, p.shape[0] // nd) + p.shape[1:])
                        for p in payload)
        # row i of the result = sender i's chunk destined for this rank
        recv = tuple(lax.all_to_all(p, cfg.chunk_axis, split_axis=0,
                                    concat_axis=0, tiled=False)
                     for p in chunked)

    # sender identity: (outer..., chunk-rank i); this rank's outer coords
    outer_idx = 0
    for ax in cfg.outer_axes:
        outer_idx = outer_idx * axis_size(ax) + lax.axis_index(ax)
    sender_base = outer_idx * nd
    sender_mask = lax.dynamic_slice_in_dim(mask, sender_base, nd)  # (nd,)
    return InFlightAggregate(recv, sender_mask, wire, cfg)


def _phase2_gather(chunk_sum: jnp.ndarray,
                   cfg: CodingCollectiveConfig) -> jnp.ndarray:
    """Phase 2: broadcast the aggregated chunk back over the chunk axis."""
    if cfg.phase2_sign:
        # beyond-paper: re-sign-compress the aggregate (server-side EF is
        # maintained by the caller via the returned residual if desired)
        w2, s2 = sign_pack(chunk_sum.astype(jnp.float32), cfg.group_size)
        w2g = lax.all_gather(w2, cfg.chunk_axis, axis=0, tiled=True)
        s2g = lax.all_gather(s2, cfg.chunk_axis, axis=0, tiled=True)
        return sign_unpack(w2g, s2g, cfg.group_size)
    payload2 = chunk_sum.astype(cfg.phase2_dtype)
    return lax.all_gather(payload2, cfg.chunk_axis, axis=0,
                          tiled=True).astype(jnp.float32)


def two_phase_coded_allreduce(
    c_local: Optional[jnp.ndarray],
    wire: WireFormat,
    cfg: CodingCollectiveConfig,
    mask: jnp.ndarray,
    payload: Optional[Tuple[jnp.ndarray, ...]] = None,
) -> jnp.ndarray:
    """Compute  sum_i mask_i * c_i  across the coding ranks, transmitting
    phase 1 in `wire`'s packed format.

    c_local: (n,) this rank's *decompressed* compressed vector C(acc_i).
      When c_local is exactly representable by the wire (it is the output of
      `wire.roundtrip`), pack->unpack is lossless up to ulp-level rescaling
      and the result equals the dense masked psum (bit-for-bit for
      SignWire/DenseWire(f32); within 1-2 ulp for SparseWire — tested).
      May be None when `payload` is given — the hot path never materializes
      the dense c (it transmits the payload from `wire.fused_local_step`).
    mask: (n_coding_total,) straggler indicators, flattened over coding axes
      in row-major (outer..., chunk) order — identical on every rank.
    payload: optional pre-packed wire payload of c_local (hot-path callers
      that already packed to obtain c_local avoid a second pack here).
    Returns: (n,) aggregated ghat, identical on every coding rank.

    This is `coded_allreduce_start(...).finish()` — callers that want to
    overlap compute with the wire transfer use the split form directly.
    """
    if payload is None:
        if c_local is None:
            raise ValueError("need c_local or a pre-packed payload")
        payload = wire.pack(c_local)
    return coded_allreduce_start(wire, cfg, mask, payload).finish()


def two_phase_sign_allreduce(
    c_local: jnp.ndarray,
    cfg: CodingCollectiveConfig,
    mask: jnp.ndarray,
) -> jnp.ndarray:
    """Sign-wire specialization of `two_phase_coded_allreduce` (seed API)."""
    return two_phase_coded_allreduce(
        c_local, SignWire(group_size=cfg.group_size), cfg, mask)


def dense_allreduce(c_local: jnp.ndarray, cfg: CodingCollectiveConfig,
                    mask: jnp.ndarray) -> jnp.ndarray:
    """Baseline aggregation: dense f32 masked psum over the coding axes
    (stochastic gradient coding [31] / reference semantics for tests)."""
    idx = 0
    for ax in cfg.coding_axes:
        idx = idx * axis_size(ax) + lax.axis_index(ax)
    my_mask = lax.dynamic_index_in_dim(mask, idx, keepdims=False)
    out = my_mask * c_local
    for ax in cfg.coding_axes:
        out = lax.psum(out, ax)
    return out
