"""Wire-compressed collective aggregation for COCO-EF on a TPU mesh.

The paper's device->server->device exchange maps onto a two-phase collective
over the coding axes (DESIGN.md Sec. 2):

  phase 1 (device -> "server"):  each coding rank packs C(acc_i) into its
     wire format (sign bits -> uint32 words + per-group f32 scales) and
     `all_to_all`s chunk j to rank j; rank j decodes every sender's chunk,
     applies the straggler mask of the *sender*, and sums.  This leg carries
     the compressed payload -> ~26x fewer bytes than a dense f32 all-reduce
     leg for group_size=512 sign quantization.
  phase 2 ("server" -> device):  the aggregated dense chunk is `all_gather`ed
     back.  Paper-faithful mode sends f32 (the paper's server broadcast is
     uncompressed); `phase2_dtype=bf16` and `phase2_sign=True` are
     beyond-paper options evaluated in EXPERIMENTS.md §Perf.

When the coding runs over two mesh axes (e.g. ("pod", "data")) the phases are
hierarchical: all_to_all within the minor axis, psum across the major axis on
the decoded chunk, gather within the minor axis.

Everything here runs inside a *fully manual* shard_map: inputs are the
device-local flat gradient/error vectors.  The pure-jnp pack/unpack here are
the reference implementations; `repro.kernels.sign_pack` provides the Pallas
TPU kernels for the same wire format.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "sign_pack",
    "sign_unpack",
    "CodingCollectiveConfig",
    "two_phase_sign_allreduce",
    "dense_allreduce",
    "wire_bytes_sign",
]


# --------------------------------------------------------------------------
# wire format: sign bits + per-group scales
# --------------------------------------------------------------------------

def sign_pack(x: jnp.ndarray, group_size: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pack a flat f32 vector into (bits: uint32 (n/32,), scales: f32 (n/g,)).

    scales[m] = ||x_m||_1 / |I_m|  (eq. 5); bit j of word w = 1  iff
    x[32*w + j] >= 0.  Requires n % lcm(32, group_size) == 0 (pad upstream).
    """
    n = x.shape[0]
    g = group_size
    if n % g or g % 32:
        raise ValueError(f"need group_size % 32 == 0 and n % group_size == 0 "
                         f"(n={n}, g={g})")
    xf = x.astype(jnp.float32)
    scales = jnp.mean(jnp.abs(xf.reshape(-1, g)), axis=-1)
    bits = (xf >= 0).reshape(-1, 32).astype(jnp.uint32)
    words = (bits << jnp.arange(32, dtype=jnp.uint32)).sum(-1, dtype=jnp.uint32)
    return words, scales


def sign_unpack(words: jnp.ndarray, scales: jnp.ndarray, group_size: int,
                dtype=jnp.float32) -> jnp.ndarray:
    """Inverse of sign_pack: returns sign(x) * scale_group, flat (n,)."""
    bits = (words[:, None] >> jnp.arange(32, dtype=jnp.uint32)) & jnp.uint32(1)
    signs = bits.astype(dtype).reshape(-1) * 2.0 - 1.0
    n = signs.shape[0]
    per_group = jnp.repeat(scales.astype(dtype), group_size, total_repeat_length=n)
    return signs * per_group


def wire_bytes_sign(n: int, group_size: int) -> int:
    """Bytes on the wire for one rank's phase-1 payload."""
    return n // 8 + 4 * (n // group_size)


# --------------------------------------------------------------------------
# collective aggregation
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CodingCollectiveConfig:
    """Static config for the coded aggregation.

    coding_axes: mesh axis names the COCO-EF 'devices' live on.  The last
      axis is the all_to_all/gather (chunking) axis; any earlier axes are
      reduced hierarchically with a dense psum of the (small) decoded chunk.
    group_size: sign-quantization group (multiple of 32).
    phase2_dtype: dtype of the aggregated update broadcast (f32 = paper).
    """

    coding_axes: Tuple[str, ...] = ("data",)
    group_size: int = 512
    phase2_dtype: jnp.dtype = jnp.float32
    phase2_sign: bool = False  # beyond-paper: sign-compress the broadcast

    @property
    def chunk_axis(self) -> str:
        return self.coding_axes[-1]

    @property
    def outer_axes(self) -> Tuple[str, ...]:
        return self.coding_axes[:-1]


def _chunk_count(axis: str) -> int:
    return lax.axis_size(axis)


def two_phase_sign_allreduce(
    c_local: jnp.ndarray,
    cfg: CodingCollectiveConfig,
    mask: jnp.ndarray,
) -> jnp.ndarray:
    """Compute  sum_i mask_i * c_i  across the coding ranks, transmitting
    phase 1 in the packed sign wire format.

    c_local: (n,) this rank's *decompressed* compressed vector C(acc_i).
      Because sign quantization is exactly representable by (bits, scales),
      pack->unpack is lossless for such inputs and the result equals the
      dense masked psum bit-for-bit (tested).
    mask: (n_coding_total,) straggler indicators, flattened over coding axes
      in row-major (outer..., chunk) order — identical on every rank.
    Returns: (n,) aggregated ghat, identical on every coding rank.
    """
    n = c_local.shape[0]
    nd = _chunk_count(cfg.chunk_axis)
    if n % (nd * cfg.group_size):
        raise ValueError(f"flat size {n} must be divisible by "
                         f"chunk_count*group_size = {nd * cfg.group_size}")

    words, scales = sign_pack(c_local, cfg.group_size)

    # ---- phase 1: all_to_all compressed chunks over the chunk axis -------
    words_c = words.reshape(nd, -1)
    scales_c = scales.reshape(nd, -1)
    # row i of the result = sender i's chunk destined for this rank
    words_r = lax.all_to_all(words_c, cfg.chunk_axis, split_axis=0,
                             concat_axis=0, tiled=False)
    scales_r = lax.all_to_all(scales_c, cfg.chunk_axis, split_axis=0,
                              concat_axis=0, tiled=False)

    # sender identity: (outer..., chunk-rank i); this rank's outer coords
    outer_idx = 0
    for ax in cfg.outer_axes:
        outer_idx = outer_idx * lax.axis_size(ax) + lax.axis_index(ax)
    sender_base = outer_idx * nd
    sender_mask = lax.dynamic_slice_in_dim(mask, sender_base, nd)  # (nd,)

    def _decode(w_row, s_row):
        return sign_unpack(w_row, s_row, cfg.group_size)

    decoded = jax.vmap(_decode)(words_r, scales_r)          # (nd, n/nd)
    chunk_sum = (sender_mask[:, None] * decoded).sum(axis=0)  # (n/nd,)

    # ---- hierarchical reduction over outer coding axes (dense, small) ----
    for ax in cfg.outer_axes:
        chunk_sum = lax.psum(chunk_sum, ax)

    # ---- phase 2: broadcast the aggregated chunk back ---------------------
    if cfg.phase2_sign:
        # beyond-paper: re-sign-compress the aggregate (server-side EF is
        # maintained by the caller via the returned residual if desired)
        w2, s2 = sign_pack(chunk_sum.astype(jnp.float32), cfg.group_size)
        w2g = lax.all_gather(w2, cfg.chunk_axis, axis=0, tiled=True)
        s2g = lax.all_gather(s2, cfg.chunk_axis, axis=0, tiled=True)
        ghat = sign_unpack(w2g, s2g, cfg.group_size)
    else:
        payload = chunk_sum.astype(cfg.phase2_dtype)
        ghat = lax.all_gather(payload, cfg.chunk_axis, axis=0,
                              tiled=True).astype(jnp.float32)
    return ghat


def dense_allreduce(c_local: jnp.ndarray, cfg: CodingCollectiveConfig,
                    mask: jnp.ndarray) -> jnp.ndarray:
    """Baseline aggregation: dense f32 masked psum over the coding axes
    (stochastic gradient coding [31] / reference semantics for tests)."""
    idx = 0
    for ax in cfg.coding_axes:
        idx = idx * lax.axis_size(ax) + lax.axis_index(ax)
    my_mask = lax.dynamic_index_in_dim(mask, idx, keepdims=False)
    out = my_mask * c_local
    for ax in cfg.coding_axes:
        out = lax.psum(out, ax)
    return out
