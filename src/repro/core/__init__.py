"""COCO-EF core: the paper's contribution (compression + coding + EF)."""
from . import coding, coding_state, collectives, compression, \
    error_feedback, cocoef, plan  # noqa: F401
from .plan import PlanSpec  # noqa: F401
