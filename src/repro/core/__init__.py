"""COCO-EF core: the paper's contribution (compression + coding + EF)."""
from . import coding, coding_state, collectives, compression, \
    error_feedback, cocoef  # noqa: F401
