"""Compression functions for COCO-EF and baselines.

Implements the paper's two biased compressors (Sec. III):
  * grouped sign-bit quantization  C_m(g_m) = sign(g_m) * ||g_m||_1 / |I_m|
  * top-K sparsification (exact global and TPU-friendly block-local)
and the unbiased compressors used by the baselines of Sec. V:
  * stochastic sign (1-bit) quantization   (Unbiased (Sign),  [32])
  * amplified rand-K sparsification        (Unbiased (Rand-K), [14])

Every compressor exposes:
  apply(x, key=None) -> C(x)      same shape/dtype as x (the decompressed value)
  wire_bits(n)       -> int       bits on the wire for an n-element input
  delta(n)           -> float     contraction constant (biased compressors only):
                                  E||C(x) - x||^2 <= delta * ||x||^2

All `apply` implementations are pure jnp (jit / vmap / grad-safe, static
shapes).  The Pallas kernels in `repro.kernels` implement the same math for
the packed wire format; `tests/test_kernels.py` checks them against these
references.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "Compressor",
    "GroupedSign",
    "TopK",
    "BlockTopK",
    "StochasticSign",
    "RandK",
    "Identity",
    "WireCompressor",
    "get_compressor",
]


def _strict_sign(x: jnp.ndarray) -> jnp.ndarray:
    """sign with sign(0) := +1 so the output is exactly 1-bit representable."""
    return jnp.where(x >= 0, jnp.ones_like(x), -jnp.ones_like(x))


@dataclasses.dataclass(frozen=True)
class Compressor:
    """Base class; subclasses are frozen dataclasses => valid static args."""

    #: True if E[C(x)] = x over the compressor's internal randomness.
    unbiased: bool = dataclasses.field(default=False, init=False)

    def apply(self, x: jnp.ndarray, key: Optional[jax.Array] = None) -> jnp.ndarray:
        raise NotImplementedError

    def wire_bits(self, n: int) -> int:
        raise NotImplementedError

    def delta(self, n: int) -> float:  # contraction constant of Assumption 5
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Identity(Compressor):
    """No compression (the delta=0 'optimal performance bound' of Sec. IV)."""

    def apply(self, x, key=None):
        return x

    def wire_bits(self, n):
        return 32 * n

    def delta(self, n):
        return 0.0


@dataclasses.dataclass(frozen=True)
class GroupedSign(Compressor):
    """Grouped sign-bit quantization, eq. (5)-(6).

    group_size <= 0 means a single group over the whole vector (M0 = 1,
    plain sign-bit quantization).  delta = 1 - 1/|I_m|  (Prop. 2).
    """

    group_size: int = -1

    def _groups(self, n: int) -> int:
        g = n if self.group_size <= 0 else self.group_size
        if n % g != 0:
            raise ValueError(f"group_size {g} must divide n={n}; pad upstream")
        return g

    def apply(self, x, key=None):
        shape, dtype = x.shape, x.dtype
        flat = x.reshape(-1)
        g = self._groups(flat.shape[0])
        grouped = flat.reshape(-1, g)
        scale = jnp.mean(jnp.abs(grouped), axis=-1, keepdims=True)  # ||.||_1/|I_m|
        out = _strict_sign(grouped) * scale
        return out.reshape(shape).astype(dtype)

    def wire_bits(self, n):
        g = self._groups(n)
        return n + 32 * (n // g)  # 1 bit/coord + one f32 scale per group

    def delta(self, n):
        g = self._groups(n)
        return 1.0 - 1.0 / g


@dataclasses.dataclass(frozen=True)
class TopK(Compressor):
    """Exact global top-K magnitude sparsification.  delta = 1 - K/D."""

    k: int = 1

    def apply(self, x, key=None):
        shape, dtype = x.shape, x.dtype
        flat = x.reshape(-1)
        n = flat.shape[0]
        k = min(self.k, n)
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        mask = jnp.zeros((n,), dtype=bool).at[idx].set(True)
        return jnp.where(mask, flat, 0).reshape(shape).astype(dtype)

    def wire_bits(self, n):
        k = min(self.k, n)
        return k * (32 + 32)  # value + index per kept coordinate

    def delta(self, n):
        return 1.0 - min(self.k, n) / n


@dataclasses.dataclass(frozen=True)
class BlockTopK(Compressor):
    """Block-local top-k: top-`k_per_block` within each contiguous block.

    TPU-native adaptation of top-K (DESIGN.md Sec. 2): fixed-shape payloads,
    no global sort.  Still a contraction with delta = 1 - k/B per block, hence
    delta = 1 - k_per_block/block_size globally.
    """

    k_per_block: int = 8
    block_size: int = 256

    def apply(self, x, key=None):
        shape, dtype = x.shape, x.dtype
        flat = x.reshape(-1)
        n = flat.shape[0]
        b = self.block_size
        if n % b != 0:
            raise ValueError(f"block_size {b} must divide n={n}; pad upstream")
        blocks = flat.reshape(-1, b)
        k = min(self.k_per_block, b)
        # threshold = k-th largest magnitude per block
        topv = jax.lax.top_k(jnp.abs(blocks), k)[0]
        thr = topv[:, -1:]
        keep = jnp.abs(blocks) >= thr
        # break magnitude ties so exactly k survive per block: rank by (|x|, -pos)
        # cumulative count of keeps, capped at k
        cum = jnp.cumsum(keep.astype(jnp.int32), axis=-1)
        keep = keep & (cum <= k)
        out = jnp.where(keep, blocks, 0)
        return out.reshape(shape).astype(dtype)

    def wire_bits(self, n):
        b = self.block_size
        k = min(self.k_per_block, b)
        nblocks = n // b
        return nblocks * k * (32 + 16)  # value + in-block index (<=65536)

    def delta(self, n):
        return 1.0 - min(self.k_per_block, self.block_size) / self.block_size


@dataclasses.dataclass(frozen=True)
class StochasticSign(Compressor):
    """Unbiased per-group stochastic 1-bit quantization (baseline of [32]).

    Per group with m = max|x|: Q_j = m * (2*B_j - 1), B_j ~ Bern((1+x_j/m)/2).
    E[Q_j] = x_j.  Wire format identical to GroupedSign (1 bit + scale).
    """

    group_size: int = -1
    unbiased: bool = dataclasses.field(default=True, init=False)

    def apply(self, x, key=None):
        if key is None:
            raise ValueError("StochasticSign requires a PRNG key")
        shape, dtype = x.shape, x.dtype
        flat = x.reshape(-1).astype(jnp.float32)
        g = flat.shape[0] if self.group_size <= 0 else self.group_size
        grouped = flat.reshape(-1, g)
        m = jnp.max(jnp.abs(grouped), axis=-1, keepdims=True)
        m = jnp.where(m == 0, 1.0, m)
        p_up = 0.5 * (1.0 + grouped / m)
        u = jax.random.uniform(key, grouped.shape)
        out = jnp.where(u < p_up, m, -m)
        # exactly-zero groups stay zero (m replaced by 1 only to avoid 0/0)
        out = jnp.where(jnp.max(jnp.abs(grouped), -1, keepdims=True) == 0, 0.0, out)
        return out.reshape(shape).astype(dtype)

    def wire_bits(self, n):
        g = n if self.group_size <= 0 else self.group_size
        return n + 32 * (n // g)


@dataclasses.dataclass(frozen=True)
class RandK(Compressor):
    """Amplified rand-K sparsification [14]: keep K uniform coords * (D/K)."""

    k: int = 1
    unbiased: bool = dataclasses.field(default=True, init=False)

    def apply(self, x, key=None):
        if key is None:
            raise ValueError("RandK requires a PRNG key")
        shape, dtype = x.shape, x.dtype
        flat = x.reshape(-1)
        n = flat.shape[0]
        k = min(self.k, n)
        idx = jax.random.choice(key, n, shape=(k,), replace=False)
        mask = jnp.zeros((n,), dtype=bool).at[idx].set(True)
        out = jnp.where(mask, flat * (n / k), 0)
        return out.reshape(shape).astype(dtype)

    def wire_bits(self, n):
        k = min(self.k, n)
        return k * (32 + 32)


@dataclasses.dataclass(frozen=True)
class WireCompressor(Compressor):
    """A `repro.core.collectives.WireFormat` as a reference-loop compressor.

    `apply` is the wire's `roundtrip` — EXACTLY what the receivers of the
    coded collective reconstruct, bit for bit, including the payload's
    value-dtype and scale-normalization rounding.  This is the bridge that
    keeps the repo at ONE Algorithm 1: the (N, D) reference EF loop run
    with `WireCompressor(wire)` and the mesh `cocoef_update` on the same
    wire produce identical trajectories (asserted by the parity gate,
    `repro.launch.parity` / tests/test_algorithm_parity.py).

    Wire formats are frozen dataclasses, so this is hashable and remains a
    valid jit static argument wherever a `Compressor` is accepted.
    """

    wire: object                      # a collectives.WireFormat (required)

    def apply(self, x, key=None):
        shape, dtype = x.shape, x.dtype
        return (self.wire.roundtrip(x.reshape(-1))
                .reshape(shape).astype(dtype))

    def wire_bits(self, n):
        return 8 * int(self.wire.wire_bytes(n))


_REGISTRY = {
    "identity": Identity,
    "sign": GroupedSign,
    "grouped_sign": GroupedSign,
    "topk": TopK,
    "block_topk": BlockTopK,
    "stochastic_sign": StochasticSign,
    "randk": RandK,
}


def get_compressor(name: str, **kwargs) -> Compressor:
    if name not in _REGISTRY:
        raise KeyError(f"unknown compressor {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](**kwargs)
