"""Live coding plane: online rate estimation -> encode weights -> allocation.

Closes ROADMAP item 4's loop.  The static pipeline bakes oracle
`StragglerProcess.rates()` into jit constants at `build_train_setup` time;
this module makes the same quantities *state*:

  `RateEstimator`   turns observed participation masks into bias-corrected
                    per-rank rate estimates (the standalone twin of the
                    `repro.obs.MetricsLogger` EWMA — one test asserts they
                    agree bit-for-bit; the logger cannot import this module
                    because `repro.core` imports `repro.obs`).
  `CodingState`     a pytree (rates_estimate, W, epoch) passed to the train
                    step as an explicit (donatable) argument, so W can
                    change every step without retracing.
  `CodingPlan`      the host-side controller: `maybe_replan(rates)` refits
                    `encode_weights` from the latest estimates on EVERY
                    call (cheap: O(N*M) float64 numpy) and re-runs the
                    greedy `rate_aware_allocation` only when estimates
                    drift past `drift_threshold` (epoch bump — batch maker
                    must refresh subset ids; EF state is untouched).

Parity invariant (tested): with the estimate pinned to the oracle rates,
`CodingPlan` reproduces the static `encode_weights(alloc, rates=...)` W
bit-for-bit, so the dynamic path equals the static path exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from . import coding

__all__ = ["CodingState", "RateEstimator", "CodingPlan", "maybe_replan"]


class CodingState(NamedTuple):
    """Per-step coding inputs as a pytree (all leaves are arrays, so a
    value change never retraces the jitted step).

    rates_estimate: (N,) f32 — current per-rank participation estimate.
    W:              (N, M) f32 — encode weights fitted to those rates.
    epoch:          () i32 — allocation epoch; bumps when the host replans
                    the subset placement (the batch maker must then emit
                    subset ids from the new allocation).
    """

    rates_estimate: jnp.ndarray
    W: jnp.ndarray
    epoch: jnp.ndarray

    @classmethod
    def create(cls, rates: Sequence[float], W: jnp.ndarray,
               epoch: int = 0) -> "CodingState":
        return cls(rates_estimate=jnp.asarray(rates, jnp.float32),
                   W=jnp.asarray(W, jnp.float32),
                   epoch=jnp.asarray(epoch, jnp.int32))


class RateEstimator:
    """Bias-corrected online EWMA of participation masks.

    Accumulates from zero and divides by the Adam-style warmup factor
    1 - (1-alpha)^t, so the step-t estimate is an exact weighted average
    of the masks seen so far instead of being dominated by the first mask.
    At t = 1 the corrected value IS the first mask; the correction only
    matters while (1-alpha)^t is non-negligible.

    Per-rank step counts make the estimator elastic: `resize` keeps the
    survivors' statistics and starts joiners from the prior.
    """

    def __init__(self, num_ranks: int, *, alpha: float = 0.1,
                 prior: float = 1.0):
        if not (0.0 < alpha <= 1.0):
            raise ValueError(f"alpha={alpha} must be in (0, 1]")
        if not (0.0 <= prior <= 1.0):
            raise ValueError(f"prior={prior} must be in [0, 1]")
        self.alpha = float(alpha)
        self.prior = float(prior)
        self._s = np.zeros(num_ranks, np.float64)
        self._t = np.zeros(num_ranks, np.int64)

    @property
    def num_ranks(self) -> int:
        return self._s.shape[0]

    @property
    def steps_seen(self) -> np.ndarray:
        return self._t.copy()

    def update(self, mask: Sequence[float]) -> np.ndarray:
        """Fold one observed participation mask in; returns `rates`."""
        m = np.asarray(mask, np.float64)
        if m.shape != self._s.shape:
            raise ValueError(f"mask shape {m.shape} != ({self.num_ranks},)")
        a = self.alpha
        self._s = (1.0 - a) * self._s + a * m
        self._t += 1
        return self.rates

    @property
    def rates(self) -> np.ndarray:
        """(N,) bias-corrected estimate; ranks with no observations yet
        report the prior."""
        # float64 exponent: numpy's int-exponent pow (repeated squaring)
        # differs in the last ulp from libm pow, and the logger's inline
        # twin must match bit-for-bit
        corr = 1.0 - (1.0 - self.alpha) ** self._t.astype(np.float64)
        return np.where(self._t > 0, self._s / np.where(corr > 0, corr, 1.0),
                        self.prior)

    def resize(self, num_new: int,
               survivors: Optional[Sequence[int]] = None) -> None:
        """Membership change: keep the survivors' statistics (default: the
        first min(N_old, N_new) ranks, the `checkpoint.elastic_rescale_ef`
        convention), zero-init joiners (they report the prior until their
        first mask)."""
        if survivors is None:
            survivors = range(min(self.num_ranks, num_new))
        idx = np.asarray(list(survivors), np.int64)
        if idx.size > num_new or (idx.size and
                                  (idx.min() < 0 or
                                   idx.max() >= self.num_ranks)):
            raise ValueError(f"bad survivor indices {idx} for "
                             f"{self.num_ranks} -> {num_new} ranks")
        s = np.zeros(num_new, np.float64)
        t = np.zeros(num_new, np.int64)
        s[:idx.size] = self._s[idx]
        t[:idx.size] = self._t[idx]
        self._s, self._t = s, t


@dataclasses.dataclass
class CodingPlan:
    """Host-side replan controller over (allocation, encode weights).

    Every `maybe_replan(rates)` call refits W to the clipped estimates
    against the CURRENT allocation; the allocation itself is recomputed
    (epoch bump) only when some rank's estimate has drifted more than
    `drift_threshold` from the rates the allocation was planned for.
    `min_rate` floors the estimates before weight fitting so a rank that
    has not participated yet cannot produce an infinite weight (the
    zero-expected-coverage guard in `encode_weights` stays as the
    backstop for genuinely dead subsets).
    """

    allocation: coding.Allocation
    rates_planned: np.ndarray            # (N,) f64 rates the allocation saw
    d: int
    epoch: int = 0
    drift_threshold: float = 0.1
    min_rate: float = 0.05
    load_slack: float = 1.25
    exact_load: bool = False
    replan_hook: Optional[Callable[[np.ndarray], object]] = \
        dataclasses.field(default=None, repr=False, compare=False)
    # ^ optional planner callback (e.g. `sim.planner.elastic_replan_hook`):
    #   invoked with the clipped rate estimates whenever a drift-triggered
    #   re-allocation fires, and its return value is surfaced as
    #   info["plan_ranking"] — so an elastic run re-invokes the analytic
    #   pruning stage on drift and logs what the planner would now pick.
    #   The hook must not mutate the plan (it advises; wire/shape changes
    #   need a restart through checkpoint.elastic_rescale_ef).

    @classmethod
    def create(cls, rates: Sequence[float], num_subsets: int, d: int, *,
               drift_threshold: float = 0.1, min_rate: float = 0.05,
               load_slack: float = 1.25, exact_load: bool = False,
               allocation: Optional[coding.Allocation] = None,
               replan_hook: Optional[Callable[[np.ndarray], object]] = None,
               ) -> "CodingPlan":
        """Plan from initial rates.  Pass `allocation` to keep an existing
        placement (e.g. the static setup's cyclic allocation) so epoch 0
        of the dynamic path is bit-for-bit the static path."""
        q = np.asarray(rates, np.float64)
        if allocation is None:
            allocation = coding.rate_aware_allocation(
                q, num_subsets, d, load_slack=load_slack,
                exact_load=exact_load)
        return cls(allocation=allocation, rates_planned=q.copy(), d=int(d),
                   drift_threshold=drift_threshold, min_rate=min_rate,
                   load_slack=load_slack, exact_load=exact_load,
                   replan_hook=replan_hook)

    def clip(self, rates: Sequence[float]) -> np.ndarray:
        return np.clip(np.asarray(rates, np.float64), self.min_rate, 1.0)

    def state(self, rates: Optional[Sequence[float]] = None,
              *, clip: bool = True) -> CodingState:
        """CodingState for the current allocation at the given (default:
        planned) rates.  clip=False reproduces the static pipeline's W
        bit-for-bit (the static path never clips its oracle rates)."""
        q = np.asarray(self.rates_planned if rates is None else rates,
                       np.float64)
        if clip:
            q = self.clip(q)
        W = coding.encode_weights(self.allocation, rates=q)
        return CodingState.create(q, W, self.epoch)

    def maybe_replan(self, rates: Sequence[float],
                     *, clip: bool = True) -> Tuple[CodingState, dict]:
        """One control-loop tick: always refit W; re-allocate on drift.

        Returns (state, info) where info carries the host-side event
        fields of the obs `replan` record: {"epoch", "drift",
        "reallocated", "rates_estimate"}.
        """
        q = np.asarray(rates, np.float64)
        if clip:
            q = self.clip(q)
        drift = float(np.max(np.abs(q - self.rates_planned))) \
            if q.shape == self.rates_planned.shape else float("inf")
        reallocated = drift > self.drift_threshold
        if reallocated:
            self.allocation = coding.rate_aware_allocation(
                q, self.allocation.num_subsets, self.d,
                load_slack=self.load_slack, exact_load=self.exact_load)
            self.rates_planned = q.copy()
            self.epoch += 1
        st = CodingState.create(
            q, coding.encode_weights(self.allocation, rates=q), self.epoch)
        info = {"epoch": self.epoch, "drift": drift,
                "reallocated": bool(reallocated),
                "rates_estimate": q.tolist()}
        if reallocated and self.replan_hook is not None:
            info["plan_ranking"] = self.replan_hook(q)
        return st, info

    def resize(self, rates: Sequence[float], num_subsets: int) -> None:
        """Membership change: re-plan the placement for the new fleet size
        (always an epoch bump — the old S has the wrong shape)."""
        q = self.clip(rates)
        self.allocation = coding.rate_aware_allocation(
            q, num_subsets, self.d, load_slack=self.load_slack,
            exact_load=self.exact_load)
        self.rates_planned = np.asarray(q, np.float64).copy()
        self.epoch += 1


def maybe_replan(plan: CodingPlan,
                 rates: Optional[Sequence[float]]) -> Tuple[CodingState, dict]:
    """Convenience tick: `rates=None` (estimator has seen nothing, e.g.
    `MetricsLogger.rates` before the first step) keeps the planned rates."""
    if rates is None:
        return plan.state(), {"epoch": plan.epoch, "drift": 0.0,
                              "reallocated": False,
                              "rates_estimate":
                                  plan.rates_planned.tolist()}
    return plan.maybe_replan(rates)
