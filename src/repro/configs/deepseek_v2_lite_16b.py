"""deepseek-v2-lite-16b [moe]: MLA (kv_lora=512) + 2 shared / 64 routed
top-6 experts, first layer dense.  [arXiv:2405.04434]

Note: the assignment brief lists both "MoE 64e top-6" and "160 routed";
DeepSeek-V2-Lite has 64 routed experts (2 shared, top-6) — we follow the
64e figure (DESIGN.md).
"""
from repro.nn.config import ModelConfig
from .common import ArchSpec, CodingPlan, lm_shapes

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="deepseek", num_layers=27,
    d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128, d_ff=1408,
    vocab_size=102400, mlp="swiglu", mla=True, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128, moe_experts=64,
    moe_top_k=6, moe_shared=2, moe_ff=1408, moe_first_dense=1,
    dense_ff=10944, rope_theta=10000.0)

SMOKE = CONFIG.scaled(num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
                      head_dim=16, d_ff=64, vocab_size=256, kv_lora_rank=32,
                      qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
                      moe_experts=8, moe_top_k=2, moe_shared=1, moe_ff=64,
                      dense_ff=128, capacity_factor=4.0)

shapes, skips = lm_shapes(include_long=False)
skips["long_500k"] = ("MLA is still full (latent-compressed) attention: "
                      "524k decode is O(T) per token per layer — skipped "
                      "per the pure-full-attention rule")

ARCH = ArchSpec(
    arch_id="deepseek-v2-lite-16b", config=CONFIG, smoke=SMOKE,
    coding=CodingPlan(coding_axes=("pod", "data"), redundancy=2,
                      straggler_p=0.1, group_size=512),
    shapes=shapes, skip_shapes=skips)
