"""qwen1.5-110b [dense]: GQA with QKV bias.  [hf:Qwen/Qwen1.5-110B]

Memory plan: 110B params cannot replicate over the data axis (27.5 GB/chip
f32 at TP=16 alone), so parameters/optimizer are FSDP-sharded over 'data'
and gradient coding engages across PODS only.  On the single-pod mesh the
coding axis degenerates to 1 rank -> dense baseline (DESIGN.md Sec. 4/5).
"""
from repro.nn.config import ModelConfig
from .common import ArchSpec, CodingPlan, lm_shapes

CONFIG = ModelConfig(
    name="qwen1.5-110b", family="dense", num_layers=80, d_model=8192,
    num_heads=64, num_kv_heads=8, head_dim=128, d_ff=49152,
    vocab_size=152064, mlp="swiglu", qkv_bias=True, rope_theta=1000000.0)

SMOKE = CONFIG.scaled(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      head_dim=16, d_ff=128, vocab_size=256)

shapes, skips = lm_shapes(include_long=False)

ARCH = ArchSpec(
    arch_id="qwen1.5-110b", config=CONFIG, smoke=SMOKE,
    coding=CodingPlan(coding_axes=("pod",), redundancy=2, straggler_p=0.1,
                      group_size=512, fsdp=True),
    shapes=shapes, skip_shapes=skips,
    notes="FSDP over data axis; coding over pod axis (multi-pod only).")
