"""zamba2-2.7b [hybrid]: Mamba2 backbone + shared attention block.
[arXiv:2411.15242]"""
from repro.nn.config import ModelConfig
from .common import ArchSpec, CodingPlan, lm_shapes

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid", num_layers=54, d_model=2560,
    num_heads=32, num_kv_heads=32, head_dim=80, d_ff=10240,
    vocab_size=32000, mlp="swiglu", ssm_state=64, d_inner=5120,
    hybrid_attn_period=6, rope_theta=10000.0)

SMOKE = CONFIG.scaled(num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
                      head_dim=16, d_ff=128, vocab_size=256, ssm_state=8,
                      d_inner=128, ssm_heads=2, hybrid_attn_period=2)

shapes, skips = lm_shapes(include_long=True)

ARCH = ArchSpec(
    arch_id="zamba2-2.7b", config=CONFIG, smoke=SMOKE,
    coding=CodingPlan(coding_axes=("pod", "data"), redundancy=2,
                      straggler_p=0.1, group_size=512),
    shapes=shapes, skip_shapes=skips,
    notes="long_500k: O(1) SSM state decode; shared-attn blocks use full "
          "524k KV cache (9 blocks only).")
