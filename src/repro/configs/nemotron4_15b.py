"""nemotron-4-15b [dense]: GQA + squared-ReLU MLP + LayerNorm.
[arXiv:2402.16819]"""
from repro.nn.config import ModelConfig
from .common import ArchSpec, CodingPlan, lm_shapes

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense", num_layers=32, d_model=6144,
    num_heads=48, num_kv_heads=8, head_dim=128, d_ff=24576,
    vocab_size=256000, mlp="relu2", norm="layer", rope_theta=10000.0)

SMOKE = CONFIG.scaled(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      head_dim=16, d_ff=128, vocab_size=256)

shapes, skips = lm_shapes(include_long=False)

ARCH = ArchSpec(
    arch_id="nemotron-4-15b", config=CONFIG, smoke=SMOKE,
    coding=CodingPlan(coding_axes=("pod", "data"), redundancy=2,
                      straggler_p=0.1, group_size=512),
    shapes=shapes, skip_shapes=skips)
