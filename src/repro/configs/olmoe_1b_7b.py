"""olmoe-1b-7b [moe]: 64 experts, top-8.  [arXiv:2409.02060]"""
from repro.nn.config import ModelConfig
from .common import ArchSpec, CodingPlan, lm_shapes

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe", num_layers=16, d_model=2048,
    num_heads=16, num_kv_heads=16, head_dim=128, d_ff=1024,
    vocab_size=50304, mlp="swiglu", moe_experts=64, moe_top_k=8,
    moe_ff=1024, rope_theta=10000.0)

SMOKE = CONFIG.scaled(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                      head_dim=16, d_ff=64, vocab_size=256, moe_experts=8,
                      moe_top_k=2, moe_ff=64, capacity_factor=4.0)

shapes, skips = lm_shapes(include_long=False)

ARCH = ArchSpec(
    arch_id="olmoe-1b-7b", config=CONFIG, smoke=SMOKE,
    coding=CodingPlan(coding_axes=("pod", "data"), redundancy=2,
                      straggler_p=0.1, group_size=512),
    shapes=shapes, skip_shapes=skips,
    notes="experts sharded over model axis (EP); COCO-EF compresses the "
          "dense DP gradient of expert weights identically.")
