"""gemma2-2b [dense]: local+global alternating attention, logit softcaps.
[arXiv:2408.00118; hf]"""
from repro.nn.config import ModelConfig
from .common import ArchSpec, CodingPlan, lm_shapes

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense", num_layers=26, d_model=2304,
    num_heads=8, num_kv_heads=4, head_dim=288, d_ff=9216, vocab_size=256000,
    mlp="geglu", attn_softcap=50.0, final_softcap=30.0,
    sliding_window=4096, local_global_period=2, tie_embeddings=True,
    rope_theta=10000.0)

SMOKE = CONFIG.scaled(num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
                      head_dim=16, d_ff=128, vocab_size=256, sliding_window=8)

# long_500k runs with ALL layers window-capped (ring caches): the local half
# is faithful; capping the global half is a documented deviation (DESIGN.md).
shapes, skips = lm_shapes(include_long=True)

ARCH = ArchSpec(
    arch_id="gemma2-2b", config=CONFIG, smoke=SMOKE,
    coding=CodingPlan(coding_axes=("pod", "data"), redundancy=2,
                      straggler_p=0.1, group_size=512),
    shapes=shapes, skip_shapes=skips,
    notes="long_500k: global layers window-capped to 4096 (ring cache); "
          "sliding-window half is faithful sub-quadratic.")
