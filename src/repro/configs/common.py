"""ArchSpec: one assigned architecture + its shape set + distribution plan."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.nn.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


# the assigned LM shape set (identical across archs; applicability varies)
TRAIN_4K = ShapeCfg("train", 4096, 256)
PREFILL_32K = ShapeCfg("prefill", 32768, 32)
DECODE_32K = ShapeCfg("decode", 32768, 128)
LONG_500K = ShapeCfg("decode", 524288, 1)

STANDARD_SHAPES = {
    "train_4k": TRAIN_4K,
    "prefill_32k": PREFILL_32K,
    "decode_32k": DECODE_32K,
    "long_500k": LONG_500K,
}

# smoke-shape overrides: tiny seq/batch that drive the REAL mesh train /
# serve steps on each arch's `ArchSpec.smoke` ModelConfig — the shapes the
# CPU-CI sweeps (fig10 model zoo, serve smoke tests) run every cell at.
# Deliberately NOT in STANDARD_SHAPES: the dry-run matrix stays the
# production shape set.
SMOKE_TRAIN = ShapeCfg("train", seq_len=32, global_batch=8)
SMOKE_PREFILL = ShapeCfg("prefill", seq_len=32, global_batch=4)
SMOKE_DECODE = ShapeCfg("decode", seq_len=32, global_batch=4)

SMOKE_SHAPES = {
    "train": SMOKE_TRAIN,
    "prefill": SMOKE_PREFILL,
    "decode": SMOKE_DECODE,
}


@dataclasses.dataclass(frozen=True)
class CodingPlan:
    """How COCO-EF engages for this arch on the production mesh.

    coding_axes: mesh axes forming the paper's 'devices' for gradient coding
      (single-pod mesh drops 'pod' automatically).
    redundancy: d_k — how many coding ranks hold each data subset.
    straggler_p: Bernoulli straggler probability baked into encode weights.
    group_size: sign-quantization group.
    compressor: phase-1 wire compressor (sign | block_topk | topk |
      identity); selects the WireFormat of repro.core.collectives.
    k_per_block / block_size: block top-K sparsification parameters
      (compressor="block_topk").
    topk_k: global top-K budget (compressor="topk"); split evenly across
      all_to_all chunks and comm-overlap buckets.
    wire_dtype: sparse-value / dense-payload dtype on the wire.
    fsdp: shard parameters over the 'data' axis too (memory-bound archs);
      when fsdp is on, coding runs over 'pod' only (DESIGN.md Sec. 5).
    """

    coding_axes: Tuple[str, ...] = ("pod", "data")
    redundancy: int = 2
    straggler_p: float = 0.1
    group_size: int = 512
    compressor: str = "sign"
    k_per_block: int = 8
    block_size: int = 256
    topk_k: int = 64
    wire_dtype: str = "float32"
    fsdp: bool = False


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    config: ModelConfig
    smoke: ModelConfig
    coding: CodingPlan
    shapes: Dict[str, ShapeCfg]
    skip_shapes: Dict[str, str] = dataclasses.field(default_factory=dict)
    notes: str = ""

    def shape(self, name: str) -> ShapeCfg:
        if name in self.skip_shapes:
            raise KeyError(f"{self.arch_id}: shape {name} skipped: "
                           f"{self.skip_shapes[name]}")
        return self.shapes[name]


def lm_shapes(include_long: bool, long_reason: str = "",
              include_decode: bool = True) -> Tuple[Dict, Dict]:
    shapes = {"train_4k": TRAIN_4K, "prefill_32k": PREFILL_32K}
    skips = {}
    if include_decode:
        shapes["decode_32k"] = DECODE_32K
    if include_long:
        shapes["long_500k"] = LONG_500K
    else:
        skips["long_500k"] = long_reason or (
            "pure full-attention arch: 524k dense-KV decode is "
            "quadratic-cost by design (assignment rule)")
    return shapes, skips
