"""xlstm-1.3b [ssm]: mLSTM + sLSTM blocks (7:1).  [arXiv:2405.04517]"""
from repro.nn.config import ModelConfig
from .common import ArchSpec, CodingPlan, lm_shapes

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="xlstm", num_layers=48, d_model=2048,
    num_heads=4, num_kv_heads=4, d_ff=0, head_dim=512, vocab_size=50304,
    slstm_every=8, proj_factor=2.0)

SMOKE = CONFIG.scaled(num_layers=4, d_model=64, num_heads=2, num_kv_heads=2,
                      head_dim=32, vocab_size=256, slstm_every=2)

shapes, skips = lm_shapes(include_long=True)

ARCH = ArchSpec(
    arch_id="xlstm-1.3b", config=CONFIG, smoke=SMOKE,
    coding=CodingPlan(coding_axes=("pod", "data"), redundancy=2,
                      straggler_p=0.1, group_size=512),
    shapes=shapes, skip_shapes=skips,
    notes="long_500k: fully recurrent O(1)-state decode.")
