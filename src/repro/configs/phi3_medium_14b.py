"""phi3-medium-14b [dense]: RoPE + SwiGLU + GQA.  [arXiv:2404.14219]"""
from repro.nn.config import ModelConfig
from .common import ArchSpec, CodingPlan, lm_shapes

CONFIG = ModelConfig(
    name="phi3-medium-14b", family="dense", num_layers=40, d_model=5120,
    num_heads=40, num_kv_heads=10, head_dim=128, d_ff=17920,
    vocab_size=100352, mlp="swiglu", rope_theta=10000.0)

SMOKE = CONFIG.scaled(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      head_dim=16, d_ff=128, vocab_size=256)

shapes, skips = lm_shapes(include_long=False)

ARCH = ArchSpec(
    arch_id="phi3-medium-14b", config=CONFIG, smoke=SMOKE,
    coding=CodingPlan(coding_axes=("pod", "data"), redundancy=2,
                      straggler_p=0.1, group_size=512),
    shapes=shapes, skip_shapes=skips)
