"""musicgen-large [audio]: decoder-only over EnCodec tokens; the EnCodec
frontend is a STUB — input_specs() provides precomputed frame embeddings.
[arXiv:2306.05284]"""
from repro.nn.config import ModelConfig
from .common import ArchSpec, CodingPlan, lm_shapes

CONFIG = ModelConfig(
    name="musicgen-large", family="dense", num_layers=48, d_model=2048,
    num_heads=32, num_kv_heads=32, head_dim=64, d_ff=8192, vocab_size=2048,
    mlp="gelu", norm="layer", input_mode="embeddings", rope_theta=10000.0)

SMOKE = CONFIG.scaled(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                      head_dim=16, d_ff=128, vocab_size=64)

shapes, skips = lm_shapes(include_long=False)

ARCH = ArchSpec(
    arch_id="musicgen-large", config=CONFIG, smoke=SMOKE,
    coding=CodingPlan(coding_axes=("pod", "data"), redundancy=2,
                      straggler_p=0.1, group_size=512),
    shapes=shapes, skip_shapes=skips,
    notes="backbone only; EnCodec frame embeddings stubbed via input_specs.")
