"""llava-next-34b [vlm]: anyres-tiling VLM; the vision tower is a STUB —
input_specs() provides precomputed patch embeddings interleaved with text.
[hf:llava-hf/llava-v1.6]"""
from repro.nn.config import ModelConfig
from .common import ArchSpec, CodingPlan, lm_shapes

CONFIG = ModelConfig(
    name="llava-next-34b", family="dense", num_layers=60, d_model=7168,
    num_heads=56, num_kv_heads=8, head_dim=128, d_ff=20480,
    vocab_size=64000, mlp="swiglu", input_mode="embeddings",
    rope_theta=5000000.0)

SMOKE = CONFIG.scaled(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      head_dim=16, d_ff=128, vocab_size=256)

shapes, skips = lm_shapes(include_long=False)

ARCH = ArchSpec(
    arch_id="llava-next-34b", config=CONFIG, smoke=SMOKE,
    coding=CodingPlan(coding_axes=("pod", "data"), redundancy=2,
                      straggler_p=0.1, group_size=512),
    shapes=shapes, skip_shapes=skips,
    notes="backbone only; anyres patch embeddings stubbed via input_specs.")
