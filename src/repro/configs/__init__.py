"""Registry of assigned architectures (plus the paper's own tasks)."""
from . import (deepseek_v2_lite_16b, gemma2_2b, llava_next_34b,
               musicgen_large, nemotron4_15b, olmoe_1b_7b, phi3_medium_14b,
               qwen15_110b, xlstm_1_3b, zamba2_2_7b)
from .common import (ArchSpec, CodingPlan, ShapeCfg, SMOKE_DECODE,
                     SMOKE_PREFILL, SMOKE_SHAPES, SMOKE_TRAIN,
                     STANDARD_SHAPES)

REGISTRY = {m.ARCH.arch_id: m.ARCH for m in (
    gemma2_2b, phi3_medium_14b, qwen15_110b, nemotron4_15b, zamba2_2_7b,
    xlstm_1_3b, olmoe_1b_7b, deepseek_v2_lite_16b, musicgen_large,
    llava_next_34b)}


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(REGISTRY)}")
    return REGISTRY[arch_id]


def all_cells():
    """Every (arch, shape) cell incl. skipped ones (with reasons)."""
    for aid, spec in REGISTRY.items():
        for sname in STANDARD_SHAPES:
            if sname in spec.skip_shapes:
                yield aid, sname, None, spec.skip_shapes[sname]
            elif sname in spec.shapes:
                yield aid, sname, spec.shapes[sname], None
