"""Version-tolerance shims for the jax APIs this repo leans on.

The production target is current jax (jax.shard_map, lax.axis_size,
jax.make_mesh(..., axis_types=...)); CI and the CPU container may run an
older release (>= 0.4.35) where those spell differently.  Everything in the
repo that touches one of these APIs goes through this module so the
difference lives in exactly one place.
"""
from __future__ import annotations

import jax
from jax import lax

__all__ = ["axis_size", "shard_map", "make_mesh"]


def axis_size(name: str) -> int:
    """Static size of a mapped mesh axis (usable inside shard_map)."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(name)
    # psum of a Python int is folded statically to the axis size
    return lax.psum(1, name)


def shard_map(f, mesh, in_specs, out_specs, axis_names=None, check=True):
    """`jax.shard_map` with every mesh axis manual; `check` maps onto
    check_vma (new) / check_rep (old) and defaults to True like
    jax.shard_map itself (launch/train.py opts out explicitly).
    `axis_names` defaults to all axes — callers here never use
    partial-manual mode."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs,
                             axis_names=axis_names or set(mesh.axis_names),
                             check_vma=check)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)


def make_mesh(axis_shapes, axis_names):
    """`jax.make_mesh` with explicit Auto axis types where supported."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            axis_shapes, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)
