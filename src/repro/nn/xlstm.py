"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM per head, with exponential gating and running stabilizer m:
  C_t = f_t C_{t-1} + i_t v_t k_t^T ,  n_t = f_t n_{t-1} + i_t k_t
  h_t = (C_t q_t) / max(|n_t^T q_t|, exp(-m_t))

Training/prefill uses the CHUNKWISE form (sequential lax.scan over chunks,
quadratic only within a chunk) so 4k-500k sequences never materialize an
S x S weight matrix; decode is the O(1) recurrence.  Chunk carries
(C: (B,H,hd,hd)) are the big tensors — they are sharding-constrained over
the model axis via repro.sharding.ctx.

sLSTM: scalar-memory recurrent cell with exponential gating — sequential
by construction (lax.scan over time).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import ctx

from .config import ModelConfig
from .layers import dense_init


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig):
    pd = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    di = int(d * cfg.proj_factor)
    H = cfg.num_heads
    ks = jax.random.split(key, 8)
    return {
        "w_xin": dense_init(ks[0], (d, di), d, pd),
        "w_zgate": dense_init(ks[1], (d, di), d, pd),
        # per-head (block-diagonal) projections, as in xLSTM — a dense
        # (di, di) qkv would triple the parameter count at proj_factor 2
        "w_q": dense_init(ks[2], (H, di // H, di // H), di // H, pd),
        "w_k": dense_init(ks[3], (H, di // H, di // H), di // H, pd),
        "w_v": dense_init(ks[4], (H, di // H, di // H), di // H, pd),
        "w_if": dense_init(ks[5], (di, 2 * H), di, pd),  # input/forget gates
        "b_if": jnp.zeros((2 * H,), pd),
        "norm_scale": jnp.ones((di,), pd),
        "w_down": dense_init(ks[6], (di, d), di, pd),
    }


def _mlstm_chunk_scan(q, k, v, ig, log_f, state, chunk: int):
    """Chunkwise mLSTM.
    q,k,v: (B,S,H,hd) f32; ig, log_f: (B,S,H) f32 (log-space gates)
    state: (C (B,H,hd,hd), n (B,H,hd), m (B,H)) — C,n stored scaled by
      exp(-m).  Returns (y (B,S,H,hd), new_state).
    """
    B, S, H, hd = q.shape
    nc = max(1, S // chunk)
    c = S // nc
    rs = lambda t: t.reshape((B, nc, c) + t.shape[2:])
    qc, kc, vc = rs(q), rs(k), rs(v)
    igc, lfc = rs(ig), rs(log_f)

    b_cum = jnp.cumsum(lfc, axis=2)                       # (B,nc,c,H) inclusive
    g = igc - b_cum                                       # ig_j - b_j
    total = b_cum[:, :, -1]                               # (B,nc,H)

    causal = jnp.tril(jnp.ones((c, c), bool))

    def step(carry, xs):
        C, n, m = carry                                   # scaled by exp(-m)
        qn, kn, vn, bn, gn, tot = xs                      # per-chunk slices
        # qn: (B,c,H,hd) ...
        # stabilizers
        m_intra = jnp.max(jnp.where(causal[None, :, :, None],
                                    bn[:, :, None, :] + gn[:, None, :, :],
                                    -jnp.inf), axis=2)    # (B,c,H): max_j<=i
        m_i = jnp.maximum(m_intra, bn + m[:, None, :])    # (B,c,H)
        m_i = jnp.maximum(m_i, -30.0)                     # numeric floor
        # intra-chunk
        Dm = bn[:, :, None, :] + gn[:, None, :, :] - m_i[:, :, None, :]
        Dm = jnp.where(causal[None, :, :, None], Dm, -jnp.inf)
        W = jnp.exp(Dm)                                   # (B,i,j,H)
        s_qk = jnp.einsum("bihd,bjhd->bijh", qn, kn)
        num = jnp.einsum("bijh,bijh,bjhv->bihv", s_qk, W, vn)
        den_i = jnp.einsum("bijh,bijh->bih", W, s_qk)     # sum_j W_ij (q_i.k_j)
        # inter-chunk (carry)
        scale_c = jnp.exp(bn + m[:, None, :] - m_i)       # (B,c,H)
        num = num + scale_c[..., None] * jnp.einsum("bihd,bhdv->bihv", qn, C)
        den_i = den_i + scale_c * jnp.einsum("bihd,bhd->bih", qn, n)
        y = num / jnp.maximum(jnp.abs(den_i), jnp.exp(-m_i))[..., None]

        # carry update
        m_next = jnp.maximum(tot + m, jnp.max(gn + tot[:, None, :], axis=1))
        m_next = jnp.maximum(m_next, -30.0)
        wj = jnp.exp(gn + tot[:, None, :] - m_next[:, None, :])  # (B,c,H)
        C_new = (jnp.exp(tot + m - m_next)[..., None, None] * C
                 + jnp.einsum("bjh,bjhd,bjhv->bhdv", wj, kn, vn))
        C_new = ctx.constrain(C_new, (None, None, None, "model"))
        n_new = (jnp.exp(tot + m - m_next)[..., None] * n
                 + jnp.einsum("bjh,bjhd->bhd", wj, kn))
        return (C_new, n_new, m_next), y

    xs = (jnp.moveaxis(qc, 1, 0), jnp.moveaxis(kc, 1, 0),
          jnp.moveaxis(vc, 1, 0), jnp.moveaxis(b_cum, 1, 0),
          jnp.moveaxis(g, 1, 0), jnp.moveaxis(total, 1, 0))
    new_state, ys = jax.lax.scan(step, state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, hd)
    return y, new_state


def apply_mlstm(p, x, cfg: ModelConfig, *, state=None, chunk: int = 256):
    """x: (B, S, d).  state (decode / carry-in): (C, n, m)."""
    ct = x.dtype
    B, S, d = x.shape
    di = int(d * cfg.proj_factor)
    H = cfg.num_heads
    hd = di // H

    xin = x @ p["w_xin"].astype(ct)
    z = x @ p["w_zgate"].astype(ct)
    xh = xin.reshape(B, S, H, hd)
    q = jnp.einsum("bshd,hde->bshe", xh,
                   p["w_q"].astype(ct)).astype(jnp.float32)
    k = jnp.einsum("bshd,hde->bshe", xh,
                   p["w_k"].astype(ct)).astype(jnp.float32) * (hd ** -0.5)
    v = jnp.einsum("bshd,hde->bshe", xh,
                   p["w_v"].astype(ct)).astype(jnp.float32)
    gates = (xin @ p["w_if"].astype(ct) + p["b_if"].astype(ct)
             ).astype(jnp.float32)
    ig, fg = gates[..., :H], gates[..., H:]               # (B,S,H)
    log_f = jax.nn.log_sigmoid(fg)

    if state is None:
        state = init_mlstm_cache_raw(B, H, hd)

    if S == 1:
        C, n, m = state
        qf, kf, vf = q[:, 0], k[:, 0], v[:, 0]
        m_new = jnp.maximum(log_f[:, 0] + m, ig[:, 0])
        m_new = jnp.maximum(m_new, -30.0)
        i_s = jnp.exp(ig[:, 0] - m_new)[..., None]
        f_s = jnp.exp(log_f[:, 0] + m - m_new)[..., None]
        C = f_s[..., None] * C + i_s[..., None] * kf[..., None] * vf[..., None, :]
        n = f_s * n + i_s * kf
        num = jnp.einsum("bhd,bhdv->bhv", qf, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)),
                          jnp.exp(-m_new))
        y = (num / den[..., None])[:, None]               # (B,1,H,hd)
        new_state = (C, n, m_new)
    else:
        y, new_state = _mlstm_chunk_scan(q, k, v, ig, log_f, state,
                                         chunk=min(chunk, S))

    y = y.astype(ct).reshape(B, S, di)
    yf = y.astype(jnp.float32)
    yf = yf * jax.lax.rsqrt((yf ** 2).mean(-1, keepdims=True) + 1e-6)
    y = (yf * p["norm_scale"].astype(jnp.float32)).astype(ct)
    y = y * jax.nn.silu(z)
    return y @ p["w_down"].astype(ct), new_state


def init_mlstm_cache_raw(batch: int, H: int, hd: int):
    return (jnp.zeros((batch, H, hd, hd), jnp.float32),
            jnp.zeros((batch, H, hd), jnp.float32),
            jnp.full((batch, H), -30.0, jnp.float32))


def init_mlstm_cache(cfg: ModelConfig, batch: int):
    di = int(cfg.d_model * cfg.proj_factor)
    H = cfg.num_heads
    return init_mlstm_cache_raw(batch, H, di // H)


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig):
    pd = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "w_x": dense_init(ks[0], (d, 4 * d), d, pd),   # i, f, z, o pre-acts
        "w_h": dense_init(ks[1], (d, 4 * d), d, pd),
        "b": jnp.zeros((4 * d,), pd),
        "w_down": dense_init(ks[2], (d, d), d, pd),
    }


def _slstm_cell(pre, c, n, m):
    """One sLSTM cell update (pure elementwise, cheap VJP)."""
    ig, fg, zg, og = jnp.split(pre, 4, axis=-1)
    log_f = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(log_f + m, ig)
    i_s = jnp.exp(ig - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c2 = f_s * c + i_s * jnp.tanh(zg)
    n2 = f_s * n + i_s
    h2 = jax.nn.sigmoid(og) * c2 / jnp.maximum(n2, 1.0)
    return c2, n2, h2, m_new


@jax.custom_vjp
def _slstm_scan(px, wh, b, state):
    """Sequential sLSTM over time.  px: (S, B, 4d) f32.

    custom_vjp rationale (EXPERIMENTS.md §Perf xlstm iteration): XLA's scan
    transpose accumulates dW_h = sum_t h_{t-1}^T dpre_t INSIDE the loop,
    reading/writing the (d,4d) accumulator every timestep (~200 TB of HBM
    traffic at S=4096).  We instead stack dpre_t in the backward scan and
    compute the weight gradient as ONE einsum over the stacked sequence.
    """
    (c, n, h, m), (hs, _) = _slstm_fwd_scan(px, wh, b, state)
    return hs, (c, n, h, m)


def _slstm_fwd_scan(px, wh, b, state):
    def step(carry, px_t):
        c, n, h, m = carry
        pre = px_t + h @ wh + b
        c2, n2, h2, m2 = _slstm_cell(pre, c, n, m)
        return (c2, n2, h2, m2), (h2, (c, n, h, m))

    final, (hs, saved) = jax.lax.scan(step, state, px)
    return final, (hs, saved)


def _slstm_vjp_fwd(px, wh, b, state):
    final, (hs, saved) = _slstm_fwd_scan(px, wh, b, state)
    # saved: per-step PRE-state (c,n,h,m) stacked over time (S, B, d) x4
    return (hs, final), (px, wh, b, saved)


def _slstm_vjp_bwd(res, cts):
    px, wh, b, saved = res
    dhs, dfinal = cts

    def bwd_step(carry, xs):
        dc, dn, dh, dm = carry
        px_t, dh_out, (c_p, n_p, h_p, m_p) = xs
        pre = px_t + h_p @ wh + b                    # recompute (no save)
        _, cell_vjp = jax.vjp(_slstm_cell, pre, c_p, n_p, m_p)
        dpre, dc_p, dn_p, dm_p = cell_vjp((dc, dn, dh + dh_out, dm))
        dh_p = dpre @ wh.T
        return (dc_p, dn_p, dh_p, dm_p), dpre

    dstate, dpre_stack = jax.lax.scan(
        bwd_step, dfinal, (px, dhs, saved), reverse=True)
    # weight/bias grads as single contractions over the stacked sequence
    _, _, h_stack, _ = saved
    dwh = jnp.einsum("sbd,sbe->de", h_stack, dpre_stack)
    db = dpre_stack.sum((0, 1))
    return dpre_stack, dwh, db, dstate


_slstm_scan.defvjp(_slstm_vjp_fwd, _slstm_vjp_bwd)


def apply_slstm(p, x, cfg: ModelConfig, *, state=None):
    """x: (B, S, d); sequential scan over S.  state: (c, n, h, m)."""
    ct = x.dtype
    B, S, d = x.shape
    pre_x = (x @ p["w_x"].astype(ct)).astype(jnp.float32)      # (B,S,4d)
    wh = p["w_h"].astype(jnp.float32)
    b = p["b"].astype(jnp.float32)

    if state is None:
        state = init_slstm_cache_raw(B, d)

    hs, new_state = _slstm_scan(jnp.moveaxis(pre_x, 1, 0), wh, b, state)
    y = jnp.moveaxis(hs, 0, 1).astype(ct)
    return y @ p["w_down"].astype(ct), new_state


def init_slstm_cache_raw(batch: int, d: int):
    z = jnp.zeros((batch, d), jnp.float32)
    return (z, jnp.ones((batch, d), jnp.float32), z, z)


def init_slstm_cache(cfg: ModelConfig, batch: int):
    return init_slstm_cache_raw(batch, cfg.d_model)
