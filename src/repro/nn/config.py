"""Unified model configuration covering all assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | xlstm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 => d_model // num_heads

    # attention
    qkv_bias: bool = False
    attn_softcap: float = 0.0       # gemma2: 50.0
    final_softcap: float = 0.0      # gemma2: 30.0
    sliding_window: int = 0         # >0: window for local layers
    local_global_period: int = 0    # gemma2: 2 => alternate local/global
    rope_theta: float = 10000.0
    norm: str = "rms"               # rms | layer
    mlp: str = "swiglu"             # swiglu | geglu | relu2 | gelu

    # MLA (deepseek)
    mla: bool = False
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_shared: int = 0
    moe_ff: int = 0
    moe_first_dense: int = 0        # leading dense layers (deepseek: 1)
    dense_ff: int = 0               # ff of the leading dense layers
    capacity_factor: float = 1.25

    # SSM / hybrid (mamba2 / zamba2)
    ssm_state: int = 0
    d_inner: int = 0                # 0 => 2*d_model
    ssm_heads: int = 0              # mamba2 heads; 0 => d_inner // 64
    conv_width: int = 4
    hybrid_attn_period: int = 0     # zamba2: shared attn block every k layers

    # xlstm
    slstm_every: int = 0            # one sLSTM block every k layers (0=never)
    proj_factor: float = 2.0        # xlstm block up-projection

    # input/output
    input_mode: str = "tokens"      # tokens | embeddings (musicgen/llava stubs)
    tie_embeddings: bool = False

    # numerics
    dtype: str = "bfloat16"         # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = True

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.family in ("ssm", "hybrid") and self.d_inner == 0:
            object.__setattr__(self, "d_inner", 2 * self.d_model)
        if self.family in ("ssm", "hybrid") and self.ssm_heads == 0:
            object.__setattr__(self, "ssm_heads", max(1, self.d_inner // 64))

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def scaled(self, **overrides) -> "ModelConfig":
        """Return a reduced copy (smoke tests)."""
        return dataclasses.replace(self, **overrides)
