from . import config, layers, models, moe, ssm, transformer, xlstm  # noqa: F401
from .config import ModelConfig  # noqa: F401
from .models import Model  # noqa: F401
