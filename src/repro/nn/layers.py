"""Base layers: norms, RoPE, attention (GQA / MLA / sliding-window), MLPs.

Functional style: `init_*` build param dicts, `apply`-style functions are
pure.  Compute runs in cfg.dtype (bf16 on TPU), params stored in
cfg.param_dtype.  All shapes keep the head dimension explicit so the
partition rules in `repro.sharding.rules` can target them by name.

Attention has two entry points:
  attn_train(p, x, ...)                 full self-attention (train / prefill)
  attn_decode(p, x, cache, pos, ...)    one-step decode against a KV cache

KV caches are ring buffers: writes go to  pos % cache_len  and every entry
carries its absolute position (cache["pos"]), so a window-sized cache for
sliding-window layers and a full-length cache use the same code path.
"""
from __future__ import annotations

import math
import os
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig

BIG_WINDOW = 1 << 30  # "no window" sentinel usable as a traced value
NEG_INF = -1e30

# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def dense_init(key, shape, in_axis_size, dtype):
    scale = 1.0 / math.sqrt(max(1, in_axis_size))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, dim: Optional[int] = None):
    d = dim or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.dtype(cfg.param_dtype))}
    if cfg.norm == "layer":
        p["bias"] = jnp.zeros((d,), jnp.dtype(cfg.param_dtype))
    return p


def apply_norm(p, x, cfg: ModelConfig, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layer":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = (xf ** 2).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions broadcastable to (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs        # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                              # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# GQA attention
# --------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig):
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    d, qd = cfg.d_model, cfg.q_dim
    p = {
        "wq": dense_init(ks[0], (d, cfg.num_heads, cfg.head_dim), d, pd),
        "wk": dense_init(ks[1], (d, cfg.num_kv_heads, cfg.head_dim), d, pd),
        "wv": dense_init(ks[2], (d, cfg.num_kv_heads, cfg.head_dim), d, pd),
        "wo": dense_init(ks[3], (cfg.num_heads, cfg.head_dim, d), qd, pd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads, cfg.head_dim), pd)
        p["bk"] = jnp.zeros((cfg.num_kv_heads, cfg.head_dim), pd)
        p["bv"] = jnp.zeros((cfg.num_kv_heads, cfg.head_dim), pd)
    return p


def _qkv(p, x, cfg: ModelConfig, positions):
    ct = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(ct))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(ct))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(ct))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(ct)
        k = k + p["bk"].astype(ct)
        v = v + p["bv"].astype(ct)
    q = rope(q, positions, cfg.rope_theta) * (cfg.head_dim ** -0.5)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _softcap(scores, cap: float):
    return cap * jnp.tanh(scores / cap) if cap > 0 else scores


ATTN_CHUNK_MIN_S = 2048   # q-chunk long sequences (peak-memory: §Perf)
ATTN_CHUNK = 512


def _attn_core(q, k, v, cfg: ModelConfig, q_pos, k_pos, w_eff):
    """scores+softmax+values for one q block against full k/v."""
    B, Sq = q.shape[:2]
    ct = q.dtype
    groups = cfg.num_heads // cfg.num_kv_heads
    keep = (k_pos[None, :] <= q_pos[:, None]) & \
           (k_pos[None, :] > q_pos[:, None] - w_eff)              # (Sq, St)
    qh = q.reshape(B, Sq, cfg.num_kv_heads, groups, cfg.head_dim)
    scores = jnp.einsum("bsngk,btnk->bsngt", qh, k)
    scores = _softcap(scores, cfg.attn_softcap)
    scores = jnp.where(keep[None, :, None, None, :], scores, NEG_INF)
    wts = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(ct)
    out = jnp.einsum("bsngt,btnk->bsngk", wts, v)
    return out.reshape(B, Sq, cfg.num_heads, cfg.head_dim)


def attn_train(p, x, cfg: ModelConfig, window=0, return_kv: bool = False):
    """Full causal self-attention.  window: 0/BIG = global; may be traced
    (gemma2 alternation selects it per scanned layer).  return_kv=True also
    returns (k, v) in cache layout (B, Hkv, S, hd) for prefill.

    Long sequences are processed in q blocks (scan + per-block remat) so
    only one block's score matrix is ever live — an 8x peak-memory
    reduction at S=4096 (EXPERIMENTS.md §Perf).  The Pallas flash kernel
    (repro.kernels.flash_attention) replaces the block core on real TPU.
    """
    B, S, _ = x.shape
    pos = jnp.arange(S)[None]                                     # (1, S)
    q, k, v = _qkv(p, x, cfg, pos)
    w_eff = jnp.asarray(window if not isinstance(window, int) or window > 0
                        else BIG_WINDOW)
    k_pos = pos[0]
    # default "full": the q-chunked path was measured WORSE on the
    # trip-scaled cost model (k/v re-read + re-gathered per q block) —
    # EXPERIMENTS.md §Perf gemma2 iteration 2 (refuted); opt-in for
    # peak-constrained runs.
    mode = os.environ.get("REPRO_ATTN", "full")
    if mode == "chunked" and S >= ATTN_CHUNK_MIN_S and S % ATTN_CHUNK == 0:
        nblk = S // ATTN_CHUNK

        def block(_, qb_and_pos):
            qb, qp = qb_and_pos
            ob = _attn_core(qb, k, v, cfg, qp, k_pos, w_eff)
            return (), ob

        qb = q.reshape(B, nblk, ATTN_CHUNK, *q.shape[2:]).swapaxes(0, 1)
        qp = pos[0].reshape(nblk, ATTN_CHUNK)
        _, outs = jax.lax.scan(
            jax.checkpoint(block,
                           policy=jax.checkpoint_policies.nothing_saveable),
            (), (qb, qp))
        out = outs.swapaxes(0, 1).reshape(B, S, cfg.num_heads, cfg.head_dim)
    else:
        out = _attn_core(q, k, v, cfg, pos[0], k_pos, w_eff)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    if return_kv:
        return y, (jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1))
    return y


def init_kv_cache(cfg: ModelConfig, batch: int, cache_len: int,
                  dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    return {
        "k": jnp.zeros((batch, cfg.num_kv_heads, cache_len, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, cfg.num_kv_heads, cache_len, cfg.head_dim), dtype),
        "pos": jnp.full((cache_len,), -BIG_WINDOW, jnp.int32),
    }


def attn_decode(p, x, cfg: ModelConfig, cache: Dict[str, jnp.ndarray],
                pos, window=0):
    """One-step decode.  x: (B, 1, d); pos: scalar absolute position.
    Ring-buffer write at pos % cache_len."""
    B = x.shape[0]
    ct = x.dtype
    cache_len = cache["k"].shape[2]
    q, k, v = _qkv(p, x, cfg, jnp.full((1, 1), pos))
    slot = pos % cache_len
    ck = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], jnp.moveaxis(k, 2, 1).astype(cache["k"].dtype), slot, axis=2)
    cv = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], jnp.moveaxis(v, 2, 1).astype(cache["v"].dtype), slot, axis=2)
    cpos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.full((1,), pos, jnp.int32), slot, axis=0)
    w_eff = jnp.asarray(window if not isinstance(window, int) or window > 0
                        else BIG_WINDOW)
    keep = (cpos <= pos) & (cpos > pos - w_eff)                   # (T,)
    groups = cfg.num_heads // cfg.num_kv_heads
    qh = q.reshape(B, 1, cfg.num_kv_heads, groups, cfg.head_dim)
    scores = jnp.einsum("bsngk,bntk->bsngt", qh, ck.astype(ct))
    scores = _softcap(scores, cfg.attn_softcap)
    scores = jnp.where(keep[None, None, None, None, :], scores, NEG_INF)
    wts = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(ct)
    out = jnp.einsum("bsngt,bntk->bsngk", wts, cv.astype(ct))
    out = out.reshape(B, 1, cfg.num_heads, cfg.head_dim)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(ct))
    return y, {"k": ck, "v": cv, "pos": cpos}


def prefill_kv(p, x, cfg: ModelConfig, cache_len: int, dtype=jnp.bfloat16):
    """Build a cache from a full prefill pass (keeps the trailing cache_len
    positions when the prompt exceeds the ring)."""
    B, S, _ = x.shape
    pos = jnp.arange(S)[None]
    _, k, v = _qkv(p, x, cfg, pos)
    k = jnp.moveaxis(k, 2, 1)                                     # (B,H,S,hd)
    v = jnp.moveaxis(v, 2, 1)
    if S >= cache_len:
        sel = jnp.arange(S - cache_len, S)
    else:
        sel = jnp.arange(cache_len) % max(S, 1)
    ring_slot = sel % cache_len
    order = jnp.argsort(ring_slot)
    ck = k[:, :, sel[order]].astype(dtype)
    cv = v[:, :, sel[order]].astype(dtype)
    cpos = jnp.where(jnp.arange(cache_len) < min(S, cache_len),
                     sel[order], -BIG_WINDOW).astype(jnp.int32)
    return {"k": ck, "v": cv, "pos": cpos}


# --------------------------------------------------------------------------
# MLA (deepseek-v2): low-rank compressed KV with decoupled RoPE
# --------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig):
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    d, r = cfg.d_model, cfg.kv_lora_rank
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wq": dense_init(ks[0], (d, cfg.num_heads, qk), d, pd),
        "w_dkv": dense_init(ks[1], (d, r + cfg.qk_rope_dim), d, pd),
        "w_uk": dense_init(ks[2], (r, cfg.num_heads, cfg.qk_nope_dim), r, pd),
        "w_uv": dense_init(ks[3], (r, cfg.num_heads, cfg.v_head_dim), r, pd),
        "wo": dense_init(ks[4], (cfg.num_heads, cfg.v_head_dim, d),
                         cfg.num_heads * cfg.v_head_dim, pd),
        "kv_norm": jnp.ones((r,), pd),
    }


def _mla_latent(p, x, cfg: ModelConfig, positions):
    """Compressed latent [c_kv ; k_rope]: (B, S, r + qk_rope)."""
    ct = x.dtype
    r = cfg.kv_lora_rank
    ckv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(ct))
    c, k_rope = ckv[..., :r], ckv[..., r:]
    cf = c.astype(jnp.float32)
    c = (cf * jax.lax.rsqrt((cf ** 2).mean(-1, keepdims=True) + 1e-6)
         * p["kv_norm"].astype(jnp.float32)).astype(ct)
    k_rope = rope(k_rope[..., None, :], positions, cfg.rope_theta)[..., 0, :]
    return jnp.concatenate([c, k_rope], axis=-1)


def _mla_attend(p, x, lat, cfg: ModelConfig, positions, keep):
    ct = x.dtype
    r = cfg.kv_lora_rank
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(ct))
    q_nope, q_rope = q[..., :cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    c_all, krope_all = lat[..., :r], lat[..., r:]
    k_nope = jnp.einsum("btr,rhk->bthk", c_all, p["w_uk"].astype(ct))
    v = jnp.einsum("btr,rhk->bthk", c_all, p["w_uv"].astype(ct))
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    scores = (jnp.einsum("bshk,bthk->bsht", q_nope, k_nope)
              + jnp.einsum("bshk,btk->bsht", q_rope, krope_all)) * scale
    scores = jnp.where(keep[:, :, None, :], scores, NEG_INF)
    wts = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(ct)
    out = jnp.einsum("bsht,bthk->bshk", wts, v)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(ct))


def mla_train(p, x, cfg: ModelConfig, return_lat: bool = False):
    B, S, _ = x.shape
    pos = jnp.arange(S)[None]
    lat = _mla_latent(p, x, cfg, pos)
    keep = (pos[0][None, :] <= pos[0][:, None])[None]             # (1,S,S)
    y = _mla_attend(p, x, lat, cfg, pos, keep)
    return (y, lat) if return_lat else y


def init_mla_cache(cfg: ModelConfig, batch: int, cache_len: int,
                   dtype=jnp.bfloat16):
    return {"lat": jnp.zeros((batch, cache_len,
                              cfg.kv_lora_rank + cfg.qk_rope_dim), dtype),
            "pos": jnp.full((cache_len,), -BIG_WINDOW, jnp.int32)}


def mla_decode(p, x, cfg: ModelConfig, cache, pos):
    cache_len = cache["lat"].shape[1]
    new_lat = _mla_latent(p, x, cfg, jnp.full((1, 1), pos))
    slot = pos % cache_len
    lat = jax.lax.dynamic_update_slice_in_dim(
        cache["lat"], new_lat.astype(cache["lat"].dtype), slot, axis=1)
    cpos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.full((1,), pos, jnp.int32), slot, axis=0)
    # exclude empty slots (pos == -BIG_WINDOW sentinel)
    keep = ((cpos <= pos) & (cpos > pos - BIG_WINDOW))[None, None]  # (1,1,T)
    y = _mla_attend(p, x, lat.astype(x.dtype), cfg, jnp.full((1, 1), pos), keep)
    return y, {"lat": lat, "pos": cpos}


def mla_prefill(p, x, cfg: ModelConfig, cache_len: int, dtype=jnp.bfloat16):
    B, S, _ = x.shape
    pos = jnp.arange(S)[None]
    lat = _mla_latent(p, x, cfg, pos)
    take = min(S, cache_len)
    out = jnp.zeros((B, cache_len, lat.shape[-1]), dtype)
    out = out.at[:, :take].set(lat[:, S - take:].astype(dtype))
    cpos = jnp.where(jnp.arange(cache_len) < take,
                     jnp.arange(cache_len) + (S - take), -BIG_WINDOW
                     ).astype(jnp.int32)
    return {"lat": out, "pos": cpos}


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    pd = jnp.dtype(cfg.param_dtype)
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    if cfg.mlp in ("swiglu", "geglu"):
        return {"w_gate": dense_init(ks[0], (d, ff), d, pd),
                "w_up": dense_init(ks[1], (d, ff), d, pd),
                "w_down": dense_init(ks[2], (ff, d), ff, pd)}
    return {"w_up": dense_init(ks[0], (d, ff), d, pd),
            "w_down": dense_init(ks[1], (ff, d), ff, pd)}


def apply_mlp(p, x, cfg: ModelConfig):
    ct = x.dtype
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"].astype(ct)) * (x @ p["w_up"].astype(ct))
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"].astype(ct)) * (x @ p["w_up"].astype(ct))
    elif cfg.mlp == "relu2":
        h = jax.nn.relu(x @ p["w_up"].astype(ct)) ** 2
    else:  # gelu
        h = jax.nn.gelu(x @ p["w_up"].astype(ct))
    return h @ p["w_down"].astype(ct)


# --------------------------------------------------------------------------
# embeddings / head
# --------------------------------------------------------------------------

def init_embedding(key, cfg: ModelConfig):
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 2)
    p = {}
    if cfg.input_mode == "tokens":
        p["tok"] = (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model))
                    .astype(pd))
    else:  # embeddings input: projection stub for the modality frontend
        p["proj"] = dense_init(ks[0], (cfg.d_model, cfg.d_model), cfg.d_model, pd)
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size),
                               cfg.d_model, pd)
    return p


def embed(p, inputs, cfg: ModelConfig):
    ct = jnp.dtype(cfg.dtype)
    if cfg.input_mode == "tokens":
        x = p["tok"].astype(ct)[inputs]
        return x * (cfg.d_model ** 0.5) if cfg.name.startswith("gemma") else x
    return inputs.astype(ct) @ p["proj"].astype(ct)


def logits_from(p, x, cfg: ModelConfig):
    ct = x.dtype
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    logits = x @ w.astype(ct)
    if cfg.final_softcap > 0:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits
