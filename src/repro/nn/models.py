"""Public model facade: init / loss / prefill / decode per ModelConfig."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from . import transformer as T
from .config import ModelConfig

__all__ = ["Model"]


class Model:
    """Thin functional wrapper (no state) around the family dispatch."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ---- params ----------------------------------------------------------
    def init(self, key) -> Dict[str, Any]:
        return T.init_params(key, self.cfg)

    def param_shapes(self, key=None):
        k = key if key is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(lambda: T.init_params(k, self.cfg))

    def num_params(self) -> int:
        import math
        shapes = self.param_shapes()
        return sum(math.prod(l.shape) for l in jax.tree.leaves(shapes))

    # ---- training --------------------------------------------------------
    def loss(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return T.weighted_loss(params, batch, self.cfg)

    def grad_fn(self):
        def f(params, batch):
            (loss, per_ex), g = jax.value_and_grad(
                lambda p: self.loss(p, batch), has_aux=True)(params)
            return g, loss, per_ex
        return f

    # ---- serving ---------------------------------------------------------
    def init_caches(self, batch: int, cache_len: int, dtype=jnp.bfloat16):
        return T.init_caches(self.cfg, batch, cache_len, dtype)

    def decode_step(self, params, caches, inputs, pos):
        return T.decode_step(params, caches, inputs, pos, self.cfg)

    def prefill(self, params, inputs, cache_dtype=jnp.bfloat16):
        return T.prefill(params, inputs, self.cfg, cache_dtype)

    def forward(self, params, inputs):
        return T.forward(params, inputs, self.cfg)
