"""Decoder stacks for every assigned architecture family.

Stacks are built from scanned homogeneous layer groups (compile-time compact
HLO, remat-friendly):
  dense / moe      one scan over L stacked blocks
  deepseek         1 dense block + scan over (L-1) MLA+MoE blocks
  zamba2 (hybrid)  G groups of [scan over mamba2 layers] + shared attn block
  xlstm            G groups of [scan over mLSTM layers] + one sLSTM block

Each family provides train (full-sequence), prefill (train pass that also
emits caches) and decode (single-token) paths over the same parameters.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import ctx

from . import layers as L
from . import moe as MOE
from . import ssm as SSM
from . import xlstm as XL
from .config import ModelConfig




import os


def _res_strategy(cfg: ModelConfig) -> str:
    """Residual-stream sharding strategy (perf-iterated in EXPERIMENTS.md
    §Perf; override with REPRO_RES_SPEC=seq|channel|batch|none):
      seq      (B, S/model, d)  Megatron-SP — good for attention stacks
      channel  (B, S, d/model)  — naive; forces per-projection all-reduce
      batch    (B/model, S, d)  batch-parallel + FSDP-style weight gathers —
               the right shape for recurrent (conv/scan) families
    """
    env = os.environ.get("REPRO_RES_SPEC")
    if env:
        return env
    if cfg.family in ("hybrid", "xlstm"):
        return "batch"
    return "seq"


def _res(x, cfg: ModelConfig):
    """Residual-stream sharding constraint.  No-op outside a mesh context."""
    s = _res_strategy(cfg)
    if s == "none":
        return x
    if s == "batch":
        return ctx.constrain(x, ("model", "*", "*"))
    if s == "channel":
        return ctx.constrain(x, ("*", "*", "model"))
    return ctx.constrain(x, ("*", "model", "*"))

def _gb(blk, cfg: ModelConfig):
    """JIT weight gather (FSDP archs): see ctx.gather_block."""
    return ctx.gather_block(blk, jnp.dtype(cfg.dtype))


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat:
        return jax.checkpoint(fn,
                              policy=jax.checkpoint_policies.nothing_saveable)
    return fn


# ==========================================================================
# init
# ==========================================================================

def init_params(key, cfg: ModelConfig):
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {"embed": L.init_embedding(ks[0], cfg),
                              "final_norm": L.init_norm(cfg)}
    f = cfg.family

    if f in ("dense", "moe"):
        def one(k):
            k1, k2, k3, k4 = jax.random.split(k, 4)
            blk = {"norm1": L.init_norm(cfg),
                   "attn": L.init_attention(k1, cfg),
                   "norm2": L.init_norm(cfg)}
            if f == "moe":
                blk["moe"] = MOE.init_moe(k2, cfg)
            else:
                blk["mlp"] = L.init_mlp(k3, cfg)
            return blk
        params["blocks"] = jax.vmap(one)(jax.random.split(ks[1], cfg.num_layers))

    elif f == "deepseek":
        k1, k2 = jax.random.split(ks[1])
        params["block0"] = {"norm1": L.init_norm(cfg),
                            "attn": L.init_mla(k1, cfg),
                            "norm2": L.init_norm(cfg),
                            "mlp": L.init_mlp(k2, cfg, cfg.dense_ff)}

        def one(k):
            k1, k2 = jax.random.split(k)
            return {"norm1": L.init_norm(cfg),
                    "attn": L.init_mla(k1, cfg),
                    "norm2": L.init_norm(cfg),
                    "moe": MOE.init_moe(k2, cfg)}
        params["blocks"] = jax.vmap(one)(
            jax.random.split(ks[2], cfg.num_layers - 1))

    elif f == "hybrid":
        per = cfg.hybrid_attn_period
        groups = cfg.num_layers // per

        def one(k):
            return {"norm1": L.init_norm(cfg), "mamba": SSM.init_mamba2(k, cfg)}
        params["blocks"] = jax.vmap(one)(
            jax.random.split(ks[1], cfg.num_layers))
        params["blocks"] = jax.tree.map(
            lambda x: x.reshape((groups, per) + x.shape[1:]), params["blocks"])
        k1, k2 = jax.random.split(ks[2])
        params["shared_attn"] = {"norm1": L.init_norm(cfg),
                                 "attn": L.init_attention(k1, cfg),
                                 "norm2": L.init_norm(cfg),
                                 "mlp": L.init_mlp(k2, cfg)}

    elif f == "xlstm":
        per = cfg.slstm_every
        groups = cfg.num_layers // per
        n_m = groups * (per - 1)

        def one_m(k):
            return {"norm1": L.init_norm(cfg), "mlstm": XL.init_mlstm(k, cfg)}

        def one_s(k):
            return {"norm1": L.init_norm(cfg), "slstm": XL.init_slstm(k, cfg)}
        m = jax.vmap(one_m)(jax.random.split(ks[1], n_m))
        params["mlstm_blocks"] = jax.tree.map(
            lambda x: x.reshape((groups, per - 1) + x.shape[1:]), m)
        params["slstm_blocks"] = jax.vmap(one_s)(
            jax.random.split(ks[2], groups))
    else:
        raise ValueError(f"unknown family {f}")
    return params


# ==========================================================================
# train / prefill forward
# ==========================================================================

def _layer_windows(cfg: ModelConfig, n: int) -> jnp.ndarray:
    """Per-layer attention windows (gemma2 local/global alternation)."""
    if cfg.local_global_period and cfg.sliding_window:
        idx = jnp.arange(n)
        return jnp.where(idx % cfg.local_global_period == 0,
                         cfg.sliding_window, L.BIG_WINDOW)
    if cfg.sliding_window:
        return jnp.full((n,), cfg.sliding_window)
    return jnp.full((n,), L.BIG_WINDOW)


def forward(params, inputs, cfg: ModelConfig):
    """inputs: tokens (B,S) int32 or embeddings (B,S,d).  Returns (B,S,d)
    final hidden states (normed) and the scalar MoE aux loss."""
    x = L.embed(_gb(params["embed"], cfg), inputs, cfg)
    f = cfg.family
    aux0 = jnp.zeros((), jnp.float32)

    if f in ("dense", "moe"):
        windows = _layer_windows(cfg, cfg.num_layers)

        def block(carry, scanned):
            x, aux = carry
            blk, win = scanned
            blk = _gb(blk, cfg)
            h = L.attn_train(blk["attn"], L.apply_norm(blk["norm1"], x, cfg),
                             cfg, window=win)
            x = x + h
            h2 = L.apply_norm(blk["norm2"], x, cfg)
            if f == "moe":
                h2, a = MOE.apply_moe(blk["moe"], h2, cfg)
                aux = aux + a
            else:
                h2 = L.apply_mlp(blk["mlp"], h2, cfg)
            return (_res(x + h2, cfg), aux), None

        x = _res(x, cfg)
        (x, aux0), _ = jax.lax.scan(_maybe_remat(block, cfg), (x, aux0),
                                    (params["blocks"], windows))

    elif f == "deepseek":
        b0 = _gb(params["block0"], cfg)
        x = x + L.mla_train(b0["attn"], L.apply_norm(b0["norm1"], x, cfg), cfg)
        x = x + L.apply_mlp(b0["mlp"], L.apply_norm(b0["norm2"], x, cfg), cfg)

        def block(carry, blk):
            x, aux = carry
            blk = _gb(blk, cfg)
            x = x + L.mla_train(blk["attn"],
                                L.apply_norm(blk["norm1"], x, cfg), cfg)
            h, a = MOE.apply_moe(blk["moe"],
                                 L.apply_norm(blk["norm2"], x, cfg), cfg)
            return (_res(x + h, cfg), aux + a), None

        x = _res(x, cfg)
        (x, aux0), _ = jax.lax.scan(_maybe_remat(block, cfg), (x, aux0),
                                    params["blocks"])

    elif f == "hybrid":
        def mamba_block(x, blk):
            blk = _gb(blk, cfg)
            h, _ = SSM.apply_mamba2(blk["mamba"],
                                    L.apply_norm(blk["norm1"], x, cfg), cfg)
            return _res(x + h, cfg), None
        sa = _gb(params["shared_attn"], cfg)
        groups = cfg.num_layers // cfg.hybrid_attn_period
        for g in range(groups):
            grp = jax.tree.map(lambda p: p[g], params["blocks"])
            x, _ = jax.lax.scan(_maybe_remat(mamba_block, cfg), x, grp)
            h = L.attn_train(sa["attn"], L.apply_norm(sa["norm1"], x, cfg), cfg)
            x = x + h
            x = x + L.apply_mlp(sa["mlp"], L.apply_norm(sa["norm2"], x, cfg), cfg)

    elif f == "xlstm":
        def m_block(x, blk):
            blk = _gb(blk, cfg)
            h, _ = XL.apply_mlstm(blk["mlstm"],
                                  L.apply_norm(blk["norm1"], x, cfg), cfg)
            return _res(x + h, cfg), None
        groups = cfg.num_layers // cfg.slstm_every
        for g in range(groups):
            grp = jax.tree.map(lambda p: p[g], params["mlstm_blocks"])
            x, _ = jax.lax.scan(_maybe_remat(m_block, cfg), x, grp)
            sb = jax.tree.map(lambda p: p[g], params["slstm_blocks"])
            h, _ = XL.apply_slstm(sb["slstm"],
                                  L.apply_norm(sb["norm1"], x, cfg), cfg)
            x = x + h
    else:
        raise ValueError(f)

    return L.apply_norm(params["final_norm"], x, cfg), aux0


def weighted_loss(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
                  aux_weight: float = 0.01):
    """Coded training loss: sum_j w_j * mean-token-NLL(example j).

    batch: {"inputs": tokens (B,S+1) or embeddings (B,S,d),
            "targets": (B,S) int32 (embeddings mode only),
            "weights": (B,) f32 coded weights 1/(d_k(1-p)) / subset_size}.
    """
    if cfg.input_mode == "tokens":
        inputs = batch["inputs"][:, :-1]
        targets = batch["inputs"][:, 1:]
    else:
        inputs = batch["inputs"]
        targets = batch["targets"]
    x, aux = forward(params, inputs, cfg)
    logits = L.logits_from(_gb(params["embed"], cfg), x, cfg)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    per_example = nll.mean(axis=-1)                       # (B,)
    loss = (per_example * batch["weights"]).sum()
    return loss + aux_weight * aux, per_example


# ==========================================================================
# prefill (full pass that also emits serving caches)
# ==========================================================================

def prefill(params, inputs, cfg: ModelConfig, cache_dtype=jnp.bfloat16):
    """Full forward over the prompt, returning (last-token logits, caches).
    Cache length == prompt length (the decode step then appends)."""
    x = L.embed(params["embed"], inputs, cfg)
    f = cfg.family
    if cfg.input_mode == "tokens":
        B, S = inputs.shape
    else:
        B, S = inputs.shape[:2]
    arange_pos = jnp.arange(S, dtype=jnp.int32)

    if f in ("dense", "moe"):
        windows = _layer_windows(cfg, cfg.num_layers)

        def block(x, scanned):
            blk, win = scanned
            h, (k, v) = L.attn_train(blk["attn"],
                                     L.apply_norm(blk["norm1"], x, cfg),
                                     cfg, window=win, return_kv=True)
            x = x + h
            h2 = L.apply_norm(blk["norm2"], x, cfg)
            if f == "moe":
                h2, _ = MOE.apply_moe(blk["moe"], h2, cfg)
            else:
                h2 = L.apply_mlp(blk["mlp"], h2, cfg)
            return _res(x + h2, cfg), (k.astype(cache_dtype),
                                       v.astype(cache_dtype))

        x = _res(x, cfg)
        x, (ks, vs) = jax.lax.scan(block, x, (params["blocks"], windows))
        caches = {"kv": {"k": ks, "v": vs,
                         "pos": jnp.broadcast_to(arange_pos,
                                                 (cfg.num_layers, S))}}

    elif f == "deepseek":
        b0 = params["block0"]
        h, lat0 = L.mla_train(b0["attn"], L.apply_norm(b0["norm1"], x, cfg),
                              cfg, return_lat=True)
        x = x + h
        x = x + L.apply_mlp(b0["mlp"], L.apply_norm(b0["norm2"], x, cfg), cfg)

        def block(x, blk):
            h, lat = L.mla_train(blk["attn"],
                                 L.apply_norm(blk["norm1"], x, cfg), cfg,
                                 return_lat=True)
            x = x + h
            h2, _ = MOE.apply_moe(blk["moe"],
                                  L.apply_norm(blk["norm2"], x, cfg), cfg)
            return _res(x + h2, cfg), lat.astype(cache_dtype)

        x, lats = jax.lax.scan(block, x, params["blocks"])
        caches = {"mla0": {"lat": lat0.astype(cache_dtype), "pos": arange_pos},
                  "mla": {"lat": lats,
                          "pos": jnp.broadcast_to(arange_pos,
                                                  (cfg.num_layers - 1, S))}}

    elif f == "hybrid":
        def mamba_block(x, blk):
            h, st = SSM.apply_mamba2(blk["mamba"],
                                     L.apply_norm(blk["norm1"], x, cfg), cfg)
            return _res(x + h, cfg), st

        sa = params["shared_attn"]
        groups = cfg.num_layers // cfg.hybrid_attn_period
        ssm_states, kvs = [], []
        for g in range(groups):
            grp = jax.tree.map(lambda p: p[g], params["blocks"])
            x, st = jax.lax.scan(mamba_block, x, grp)
            ssm_states.append(st)
            h, (k, v) = L.attn_train(sa["attn"],
                                     L.apply_norm(sa["norm1"], x, cfg), cfg,
                                     return_kv=True)
            x = x + h
            x = x + L.apply_mlp(sa["mlp"], L.apply_norm(sa["norm2"], x, cfg),
                                cfg)
            kvs.append({"k": k.astype(cache_dtype), "v": v.astype(cache_dtype),
                        "pos": arange_pos})
        caches = {"ssm": jax.tree.map(lambda *xs: jnp.stack(xs), *ssm_states),
                  "kv": jax.tree.map(lambda *xs: jnp.stack(xs), *kvs)}

    elif f == "xlstm":
        def m_block(x, blk):
            h, st = XL.apply_mlstm(blk["mlstm"],
                                   L.apply_norm(blk["norm1"], x, cfg), cfg)
            return _res(x + h, cfg), st

        groups = cfg.num_layers // cfg.slstm_every
        all_m, sstates = [], []
        for g in range(groups):
            grp = jax.tree.map(lambda p: p[g], params["mlstm_blocks"])
            x, st = jax.lax.scan(m_block, x, grp)
            all_m.append(st)
            sb = jax.tree.map(lambda p: p[g], params["slstm_blocks"])
            h, ss = XL.apply_slstm(sb["slstm"],
                                   L.apply_norm(sb["norm1"], x, cfg), cfg)
            x = x + h
            sstates.append(ss)
        caches = {"mlstm": jax.tree.map(lambda *xs: jnp.stack(xs), *all_m),
                  "slstm": jax.tree.map(lambda *xs: jnp.stack(xs), *sstates)}
    else:
        raise ValueError(f)

    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.logits_from(params["embed"], x[:, -1:], cfg)
    return logits[:, -1], caches


# ==========================================================================
# caches / decode
# ==========================================================================

def init_caches(cfg: ModelConfig, batch: int, cache_len: int,
                dtype=jnp.bfloat16):
    f = cfg.family
    if f in ("dense", "moe"):
        if cfg.local_global_period and cfg.sliding_window:
            lens = [min(cache_len, cfg.sliding_window)
                    if i % cfg.local_global_period == 0 else cache_len
                    for i in range(cfg.num_layers)]
            # ring caches sized per layer; cap globals at window for the
            # 500k cell (documented deviation) happens in the config shape
            ml = max(lens)
            caches = jax.vmap(lambda _: L.init_kv_cache(cfg, batch, ml, dtype)
                              )(jnp.arange(cfg.num_layers))
            return {"kv": caches}
        caches = jax.vmap(lambda _: L.init_kv_cache(cfg, batch, cache_len,
                                                    dtype))(
            jnp.arange(cfg.num_layers))
        return {"kv": caches}
    if f == "deepseek":
        c0 = L.init_mla_cache(cfg, batch, cache_len, dtype)
        cs = jax.vmap(lambda _: L.init_mla_cache(cfg, batch, cache_len, dtype)
                      )(jnp.arange(cfg.num_layers - 1))
        return {"mla0": c0, "mla": cs}
    if f == "hybrid":
        per = cfg.hybrid_attn_period
        groups = cfg.num_layers // per
        ssm = jax.vmap(lambda _: SSM.init_mamba2_cache(cfg, batch)
                       )(jnp.arange(cfg.num_layers))
        ssm = jax.tree.map(lambda x: x.reshape((groups, per) + x.shape[1:]), ssm)
        kv = jax.vmap(lambda _: L.init_kv_cache(cfg, batch, cache_len, dtype)
                      )(jnp.arange(groups))
        return {"ssm": ssm, "kv": kv}
    if f == "xlstm":
        per = cfg.slstm_every
        groups = cfg.num_layers // per
        m = jax.vmap(lambda _: XL.init_mlstm_cache(cfg, batch)
                     )(jnp.arange(groups * (per - 1)))
        m = jax.tree.map(lambda x: x.reshape((groups, per - 1) + x.shape[1:]), m)
        s = jax.vmap(lambda _: XL.init_slstm_cache(cfg, batch)
                     )(jnp.arange(groups))
        return {"mlstm": m, "slstm": s}
    raise ValueError(f)


def decode_step(params, caches, inputs, pos, cfg: ModelConfig):
    """One-token decode.  inputs: (B, 1) tokens or (B, 1, d) embeddings;
    pos: scalar absolute position.  Returns (logits (B, vocab), caches)."""
    x = L.embed(params["embed"], inputs, cfg)
    f = cfg.family

    if f in ("dense", "moe"):
        windows = _layer_windows(cfg, cfg.num_layers)

        def block(x, scanned):
            blk, cache, win = scanned
            h, new_cache = L.attn_decode(
                blk["attn"], L.apply_norm(blk["norm1"], x, cfg), cfg, cache,
                pos, window=win)
            x = x + h
            h2 = L.apply_norm(blk["norm2"], x, cfg)
            if f == "moe":
                h2, _ = MOE.apply_moe(blk["moe"], h2, cfg)
            else:
                h2 = L.apply_mlp(blk["mlp"], h2, cfg)
            return x + h2, new_cache

        x, kv = jax.lax.scan(block, x,
                             (params["blocks"], caches["kv"], windows))
        caches = {"kv": kv}

    elif f == "deepseek":
        b0 = params["block0"]
        h, c0 = L.mla_decode(b0["attn"], L.apply_norm(b0["norm1"], x, cfg),
                             cfg, caches["mla0"], pos)
        x = x + h
        x = x + L.apply_mlp(b0["mlp"], L.apply_norm(b0["norm2"], x, cfg), cfg)

        def block(x, scanned):
            blk, cache = scanned
            h, nc = L.mla_decode(blk["attn"],
                                 L.apply_norm(blk["norm1"], x, cfg), cfg,
                                 cache, pos)
            x = x + h
            h2, _ = MOE.apply_moe(blk["moe"],
                                  L.apply_norm(blk["norm2"], x, cfg), cfg)
            return x + h2, nc

        x, cs = jax.lax.scan(block, x, (params["blocks"], caches["mla"]))
        caches = {"mla0": c0, "mla": cs}

    elif f == "hybrid":
        def mamba_block(x, scanned):
            blk, (ssm_s, conv_s) = scanned
            h, (ns, ncv) = SSM.apply_mamba2(
                blk["mamba"], L.apply_norm(blk["norm1"], x, cfg), cfg,
                ssm_state=ssm_s, conv_state=conv_s)
            return x + h, (ns, ncv)

        sa = params["shared_attn"]
        groups = cfg.num_layers // cfg.hybrid_attn_period
        new_ssm, new_kv = [], []
        for g in range(groups):
            grp = jax.tree.map(lambda p: p[g], params["blocks"])
            grp_cache = jax.tree.map(lambda c: c[g], caches["ssm"])
            x, ns = jax.lax.scan(mamba_block, x, (grp, grp_cache))
            new_ssm.append(ns)
            kv_g = jax.tree.map(lambda c: c[g], caches["kv"])
            h, nkv = L.attn_decode(sa["attn"],
                                   L.apply_norm(sa["norm1"], x, cfg), cfg,
                                   kv_g, pos)
            x = x + h
            x = x + L.apply_mlp(sa["mlp"], L.apply_norm(sa["norm2"], x, cfg),
                                cfg)
            new_kv.append(nkv)
        caches = {"ssm": jax.tree.map(lambda *xs: jnp.stack(xs), *new_ssm),
                  "kv": jax.tree.map(lambda *xs: jnp.stack(xs), *new_kv)}

    elif f == "xlstm":
        def m_block(x, scanned):
            blk, st = scanned
            h, ns = XL.apply_mlstm(blk["mlstm"],
                                   L.apply_norm(blk["norm1"], x, cfg), cfg,
                                   state=st)
            return x + h, ns

        groups = cfg.num_layers // cfg.slstm_every
        new_m, new_s = [], []
        for g in range(groups):
            grp = jax.tree.map(lambda p: p[g], params["mlstm_blocks"])
            grp_c = jax.tree.map(lambda c: c[g], caches["mlstm"])
            x, nm = jax.lax.scan(m_block, x, (grp, grp_c))
            new_m.append(nm)
            sb = jax.tree.map(lambda p: p[g], params["slstm_blocks"])
            sc = jax.tree.map(lambda c: c[g], caches["slstm"])
            h, ns = XL.apply_slstm(sb["slstm"],
                                   L.apply_norm(sb["norm1"], x, cfg), cfg,
                                   state=sc)
            x = x + h
            new_s.append(ns)
        caches = {"mlstm": jax.tree.map(lambda *xs: jnp.stack(xs), *new_m),
                  "slstm": jax.tree.map(lambda *xs: jnp.stack(xs), *new_s)}
    else:
        raise ValueError(f)

    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.logits_from(params["embed"], x, cfg)
    return logits[:, -1], caches
