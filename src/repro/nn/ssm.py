"""Mamba2 (SSD) block — used by zamba2 and available standalone.

Implements the scalar-A-per-head state space duality form:
  h_t = exp(dt_t * A) h_{t-1} + dt_t * (B_t ⊗ x_t)
  y_t = C_t · h_t + D * x_t
with a causal depthwise conv front-end and gated output, matching the
Mamba2 architecture.  The sequence recurrence uses a chunked parallel scan
(jax.lax.associative_scan over chunk states) — TPU-friendly: the inner
chunk work is batched matmuls, the cross-chunk recurrence is logarithmic.

Projections are stored as separate leaves (w_z / w_x / w_B / w_C / w_dt and
conv_x / conv_bc) so tensor parallelism can shard the d_inner channels
while keeping the small B/C/dt heads replicated (repro.sharding.rules).

Decode path: O(1) recurrent state update per token (the reason the hybrid
archs run the 500k-context cell).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init


def init_mamba2(key, cfg: ModelConfig):
    pd = jnp.dtype(cfg.param_dtype)
    d, di, ns, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 8)
    return {
        "w_z": dense_init(ks[0], (d, di), d, pd),      # output gate
        "w_x": dense_init(ks[1], (d, di), d, pd),      # ssm input channels
        "w_B": dense_init(ks[2], (d, ns), d, pd),
        "w_C": dense_init(ks[3], (d, ns), d, pd),
        "w_dt": dense_init(ks[4], (d, H), d, pd),
        "conv_x": dense_init(ks[5], (cfg.conv_width, di), cfg.conv_width, pd),
        "conv_bc": dense_init(ks[6], (cfg.conv_width, 2 * ns),
                              cfg.conv_width, pd),
        "conv_b_x": jnp.zeros((di,), pd),
        "conv_b_bc": jnp.zeros((2 * ns,), pd),
        "A_log": jnp.zeros((H,), pd),                  # A = -exp(A_log)
        "D": jnp.ones((H,), pd),
        "dt_bias": jnp.zeros((H,), pd),
        "norm_scale": jnp.ones((di,), pd),
        "w_out": dense_init(ks[7], (di, d), di, pd),
    }


def _causal_conv(u, w, b, state=None):
    """u: (B, S, C); w: (K, C) depthwise.  state: (B, K-1, C) for decode."""
    K = w.shape[0]
    if state is not None:
        u_ext = jnp.concatenate([state.astype(u.dtype), u], axis=1)
    else:
        u_ext = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    new_state = u_ext[:, -(K - 1):, :]
    out = sum(u_ext[:, i:i + u.shape[1], :] * w[i][None, None] for i in range(K))
    return out + b[None, None], new_state


def _ssd_chunked(x, dt, A, B, C, chunk: int):
    """Chunked SSD scan.
    x: (b, S, H, hd)   dt: (b, S, H)   A: (H,) negative
    B, C: (b, S, N)    returns y: (b, S, H, hd)
    """
    b, S, H, hd = x.shape
    N = B.shape[-1]
    nc = S // chunk
    xc = x.reshape(b, nc, chunk, H, hd)
    dtc = dt.reshape(b, nc, chunk, H)
    Bc = B.reshape(b, nc, chunk, N)
    Cc = C.reshape(b, nc, chunk, N)

    la = dtc * A[None, None, None]                    # log decay per step (<=0)
    seg = jnp.cumsum(la, axis=2)                      # (b,nc,chunk,H)
    total = seg[:, :, -1]                             # (b,nc,H)

    # intra-chunk (local) attention-like term
    dmat = seg[:, :, :, None, :] - seg[:, :, None, :, :]   # (b,nc,i,j,H)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    dmat = jnp.where(causal[None, None, :, :, None], dmat, -jnp.inf)
    Lw = jnp.exp(dmat)
    cb = jnp.einsum("bnik,bnjk->bnij", Cc, Bc)        # (b,nc,i,j)
    y_local = jnp.einsum("bnij,bnijh,bnjh,bnjhd->bnihd", cb, Lw, dtc, xc)

    # chunk summary states: S_n = sum_j exp(total - seg_j) dt_j B_j x_j^T
    wdecay = jnp.exp(total[:, :, None, :] - seg)      # (b,nc,chunk,H)
    states = jnp.einsum("bnjh,bnjh,bnjk,bnjhd->bnhkd",
                        wdecay, dtc, Bc, xc)          # (b,nc,H,N,hd)

    # cross-chunk recurrence: carry_n = exp(total_n) carry_{n-1} + states_n
    decay = jnp.exp(total)                            # (b,nc,H)

    def combine(a, c):
        d1, s1 = a
        d2, s2 = c
        return d1 * d2, s2 + d2[..., None, None] * s1

    dec_sc, st_sc = jax.lax.associative_scan(combine, (decay, states), axis=1)
    carry_in = jnp.concatenate(
        [jnp.zeros_like(st_sc[:, :1]), st_sc[:, :-1]], axis=1)  # (b,nc,H,N,hd)

    y_carry = jnp.einsum("bnik,bnih,bnhkd->bnihd", Cc, jnp.exp(seg), carry_in)
    final_state = st_sc[:, -1]                        # (b,H,N,hd)
    return (y_local + y_carry).reshape(b, S, H, hd), final_state


def apply_mamba2(p, x, cfg: ModelConfig, *, ssm_state=None, conv_state=None,
                 chunk: int = 64):
    """x: (B, S, d).  Training/prefill: chunked scan.  Decode (S == 1):
    recurrent update using (ssm_state, conv_state)."""
    ct = x.dtype
    B_, S, d = x.shape
    di, ns, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    hd = di // H

    z = x @ p["w_z"].astype(ct)
    xs_raw = x @ p["w_x"].astype(ct)
    bc_raw = jnp.concatenate([x @ p["w_B"].astype(ct),
                              x @ p["w_C"].astype(ct)], axis=-1)
    dt_raw = x @ p["w_dt"].astype(ct)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,S,H)

    xs_c, new_conv_x = _causal_conv(
        xs_raw, p["conv_x"].astype(ct), p["conv_b_x"].astype(ct),
        None if conv_state is None else conv_state[0])
    bc_c, new_conv_bc = _causal_conv(
        bc_raw, p["conv_bc"].astype(ct), p["conv_b_bc"].astype(ct),
        None if conv_state is None else conv_state[1])
    xs_c = jax.nn.silu(xs_c)
    bc_c = jax.nn.silu(bc_c)
    xs = xs_c.reshape(B_, S, H, hd)
    Bv, Cv = bc_c[..., :ns], bc_c[..., ns:]

    if S == 1 and ssm_state is not None:
        dec = jnp.exp(dt[:, 0] * A[None])                     # (B,H)
        upd = jnp.einsum("bh,bk,bhd->bhkd", dt[:, 0],
                         Bv[:, 0].astype(jnp.float32),
                         xs[:, 0].astype(jnp.float32))
        new_ssm = dec[..., None, None] * ssm_state + upd      # (B,H,N,hd)
        y = jnp.einsum("bk,bhkd->bhd", Cv[:, 0].astype(jnp.float32), new_ssm)
        y = y[:, None].astype(ct)                             # (B,1,H,hd)
    else:
        y, new_ssm = _ssd_chunked(xs.astype(jnp.float32), dt, A,
                                  Bv.astype(jnp.float32),
                                  Cv.astype(jnp.float32),
                                  chunk=min(chunk, S))
        y = y.astype(ct)

    y = y + xs * p["D"].astype(ct)[None, None, :, None]
    y = y.reshape(B_, S, di)
    # gated RMSNorm (mamba2)
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    yf = yf * jax.lax.rsqrt((yf ** 2).mean(-1, keepdims=True) + 1e-6)
    y = (yf * p["norm_scale"].astype(jnp.float32)).astype(ct)
    out = y @ p["w_out"].astype(ct)
    return out, (new_ssm, (new_conv_x, new_conv_bc))


def init_mamba2_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    H, ns = cfg.ssm_heads, cfg.ssm_state
    hd = cfg.d_inner // H
    return (jnp.zeros((batch, H, ns, hd), jnp.float32),
            (jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner), dtype),
             jnp.zeros((batch, cfg.conv_width - 1, 2 * ns), dtype)))
