"""Mixture-of-Experts layer (GShard-style dense dispatch, EP-shardable).

Top-k routing with capacity: tokens are dispatched to experts via one-hot
einsums so every shape is static and the expert dimension can be sharded
over the `model` mesh axis (expert parallelism).  Supports shared experts
(deepseek-v2) that every token passes through.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init


def init_moe(key, cfg: ModelConfig):
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    d, ff, E = cfg.d_model, cfg.moe_ff, cfg.moe_experts
    p = {
        "router": dense_init(ks[0], (d, E), d, pd),
        "w_gate": dense_init(ks[1], (E, d, ff), d, pd),
        "w_up": dense_init(ks[2], (E, d, ff), d, pd),
        "w_down": dense_init(ks[3], (E, ff, d), ff, pd),
    }
    if cfg.moe_shared > 0:
        sff = ff * cfg.moe_shared
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {"w_gate": dense_init(kk[0], (d, sff), d, pd),
                       "w_up": dense_init(kk[1], (d, sff), d, pd),
                       "w_down": dense_init(kk[2], (sff, d), sff, pd)}
    return p


def capacity(tokens: int, cfg: ModelConfig) -> int:
    c = math.ceil(tokens * cfg.moe_top_k / cfg.moe_experts * cfg.capacity_factor)
    return max(8, int(math.ceil(c / 8) * 8))  # lane-align


def apply_moe(p, x, cfg: ModelConfig):
    """x: (B, S, d) -> (B, S, d), plus aux load-balance loss.

    Sort-based dispatch (static shapes): the classic GShard one-hot
    einsums cost T*E*C*d flops — measured 36x the useful expert compute at
    T=131k (EXPERIMENTS.md §Perf M1).  Here token slots are assigned by a
    stable sort over expert ids and moved with gather/scatter; only the
    E*C*d expert matmuls remain.
    """
    ct = x.dtype
    B, S, d = x.shape
    T = B * S
    E, k = cfg.moe_experts, cfg.moe_top_k
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                        # (T,k)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    C = capacity(T, cfg)
    eflat = gate_idx.reshape(-1)                                         # (T*k,)
    order = jnp.argsort(eflat, stable=True)
    sorted_e = eflat[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    pos = jnp.arange(T * k) - starts[sorted_e]                           # rank
    keep = pos < C
    slot = jnp.where(keep, sorted_e * C + pos, E * C)                    # drop
    token_of = order // k

    xe = jnp.zeros((E * C, d), ct).at[slot].set(
        xt[token_of], mode="drop").reshape(E, C, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(ct)))
    h = h * jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(ct))
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(ct))           # (E,C,d)

    y_slots = ye.reshape(E * C, d)[jnp.minimum(slot, E * C - 1)]
    gv = (gate_vals.reshape(-1)[order] * keep).astype(ct)
    out = jnp.zeros((T, d), ct).at[token_of].add(y_slots * gv[:, None])

    if cfg.moe_shared > 0:
        sp = p["shared"]
        hs = jax.nn.silu(xt @ sp["w_gate"].astype(ct)) * (xt @ sp["w_up"].astype(ct))
        out = out + hs @ sp["w_down"].astype(ct)

    # load-balance aux loss (Switch-style)
    me = probs.mean(0)                                                   # (E,)
    counts = jnp.bincount(jnp.where(keep, sorted_e, E), length=E + 1)[:E]
    ce = counts.astype(jnp.float32) / max(T, 1)
    aux = E * jnp.sum(me * ce)
    return out.reshape(B, S, d), aux
