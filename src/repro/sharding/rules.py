"""Partition rules: parameter leaf -> PartitionSpec on the production mesh.

Rules are keyed on the leaf's dict key (the nn modules use stable names) and
applied to the *trailing* dims; leading stack dims (scan layer stacking) are
padded with None.  `fsdp=True` (qwen1.5-110b) additionally shards the big
matmul weights over the `data` axis (DESIGN.md Sec. 5).

All specs are divisibility-checked against the mesh at build time; an axis
that does not divide the dim is dropped (with the drop recorded) rather than
producing a lowering error.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.nn.config import ModelConfig

M = "model"
D = "data"

# (base spec, fsdp spec) per leaf name; specs target the trailing dims
_RULES: Dict[str, Tuple[tuple, tuple]] = {
    # embeddings / head
    "tok_tied": ((M, None), (M, (D,))),           # vocab-sharded (tied)
    "tok": ((None, M), ((D,), M)),                # d-sharded (untied input)
    "head": ((None, M), ((D,), M)),
    "proj": ((None, M), ((D,), M)),
    # attention
    "wq": ((None, M, None), ((D,), M, None)),
    "wk": ((None, M, None), ((D,), M, None)),
    "wv": ((None, M, None), ((D,), M, None)),
    "bq": ((M, None), (M, None)),
    "bk": ((M, None), (M, None)),
    "bv": ((M, None), (M, None)),
    "wo": ((M, None, None), (M, None, (D,))),
    # MLA
    "w_dkv": ((None, None), ((D,), None)),
    "w_uk": ((None, M, None), ((D,), M, None)),
    "w_uv": ((None, M, None), ((D,), M, None)),
    "kv_norm": ((None,), (None,)),
    # MLP (dense + shared experts)
    "w_gate": ((None, M), ((D,), M)),
    "w_up": ((None, M), ((D,), M)),
    "w_down": ((M, None), (M, (D,))),
    # MoE experts (leading expert dim -> EP over model)
    "w_gate_e": ((M, None, None), (M, (D,), None)),
    "w_up_e": ((M, None, None), (M, (D,), None)),
    "w_down_e": ((M, None, None), (M, None, (D,))),
    "router": ((None, None), (None, None)),
    # mamba2
    "w_z": ((None, M), ((D,), M)),
    "w_x": ((None, M), ((D,), M)),
    "w_B": ((None, None), (None, None)),
    "w_C": ((None, None), (None, None)),
    "w_dt": ((None, None), (None, None)),
    "conv_x": ((None, M), (None, M)),
    "conv_bc": ((None, None), (None, None)),
    "conv_b_x": ((M,), (M,)),
    "conv_b_bc": ((None,), (None,)),
    "A_log": ((None,), (None,)),
    "D": ((None,), (None,)),
    "dt_bias": ((None,), (None,)),
    "norm_scale": ((M,), (M,)),
    "w_out": ((M, None), (M, (D,))),
    # xlstm
    "w_xin": ((None, M), ((D,), M)),
    "w_zgate": ((None, M), ((D,), M)),
    "w_q": ((None, None, M), ((D,), None, M)),   # (H, hd, hd) per-head
    "w_k": ((None, None, M), ((D,), None, M)),
    "w_v": ((None, None, M), ((D,), None, M)),
    "w_if": ((None, None), (None, None)),
    "b_if": ((None,), (None,)),
    "w_h": ((None, M), (None, M)),
    # norms
    "scale": ((None,), (None,)),
    "bias": ((None,), (None,)),
    "b": ((None,), (None,)),
}


def _leaf_rule(path: Tuple, leaf, cfg: ModelConfig, fsdp: bool) -> tuple:
    keys = [getattr(k, "key", str(k)) for k in path]
    name = keys[-1]
    if name == "tok":
        name = "tok_tied" if cfg.tie_embeddings else "tok"
    if name in ("w_gate", "w_up", "w_down") and "moe" in keys and \
            "shared" not in keys:
        name = name + "_e"
    if "slstm" in keys:
        # sLSTM weights are replicated: the sequential per-step matmuls on
        # (B, d) states make sharded weights a collective pathology
        # (EXPERIMENTS.md §Perf xlstm iteration); 0.8 GB replicated total.
        return (None,) * len(leaf.shape)
    base, fs = _RULES.get(name, ((None,) * 1, (None,) * 1))
    spec = fs if fsdp else base
    # pad/truncate to leaf ndim (leading stack dims -> None)
    nd = len(leaf.shape)
    spec = tuple(spec)[-nd:]
    return (None,) * (nd - len(spec)) + spec


def _check_divisible(spec: tuple, shape: Tuple[int, ...],
                     axis_sizes: Dict[str, int]) -> tuple:
    out = []
    dropped = []
    for dim, e in zip(shape, spec):
        if e is None:
            out.append(None)
            continue
        axes = (e,) if isinstance(e, str) else tuple(e)
        total = int(np.prod([axis_sizes[a] for a in axes]))
        if dim % total == 0:
            out.append(e)
        else:
            out.append(None)
            dropped.extend(axes)
    # fallback: re-place dropped axes on another dim that divides (e.g.
    # phi3's 40 heads don't divide model=16 -> shard head_dim=128 instead).
    for ax in dropped:
        sz = axis_sizes[ax]
        for i in range(len(out) - 1, -1, -1):
            if out[i] is not None:
                continue
            if shape[i] % sz == 0 and shape[i] >= sz:
                out[i] = ax
                break
    return tuple(out)


def param_specs(params_shapes, cfg: ModelConfig, mesh: Mesh,
                fsdp: bool = False):
    """Pytree of PartitionSpec congruent to the params pytree."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def rule(path, leaf):
        spec = _leaf_rule(path, leaf, cfg, fsdp)
        spec = _check_divisible(spec, leaf.shape, sizes)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, params_shapes)


def param_shardings(params_shapes, cfg: ModelConfig, mesh: Mesh,
                    fsdp: bool = False):
    specs = param_specs(params_shapes, cfg, mesh, fsdp)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def grads_specs(params_shapes, cfg: ModelConfig, mesh: Mesh,
                coding_axes: Tuple[str, ...], fsdp: bool = False):
    """Specs for per-coding-rank gradient stacks: leading coding dim."""
    specs = param_specs(params_shapes, cfg, mesh, fsdp)
    axes = tuple(a for a in coding_axes if a in mesh.axis_names)
    lead = axes if len(axes) > 1 else (axes[0] if axes else None)
    return jax.tree.map(lambda s: P(lead, *tuple(s)), specs)


# --------------------------------------------------------------------------
# cache specs (serving)
# --------------------------------------------------------------------------

def cache_specs(caches_shapes, cfg: ModelConfig, mesh: Mesh,
                batch_axes: Tuple[str, ...], global_batch: int):
    """KV/state caches: the batch dim (identified by size == global_batch)
    over dp axes where divisible, trailing feature dim over model where
    divisible.  `pos` bookkeeping arrays stay replicated."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    b_axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    nb = int(np.prod([sizes[a] for a in b_axes])) if b_axes else 1

    def rule(path, leaf):
        keys = [getattr(k, "key", str(k)) for k in path]
        shape = leaf.shape
        nd = len(shape)
        spec: List[Any] = [None] * nd
        if keys and keys[-1] == "pos":
            return P(*spec)
        for i, dim in enumerate(shape):
            if dim == global_batch and dim % nb == 0 and nb > 1:
                spec[i] = b_axes if len(b_axes) > 1 else b_axes[0]
                break
        if nd >= 2 and shape[-1] % sizes.get(M, 1) == 0 and sizes.get(M, 1) > 1:
            spec[-1] = M
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, caches_shapes)
