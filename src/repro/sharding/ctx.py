"""Activation-sharding context.

Model code calls `constrain(x, (..., "model", ...))` at strategic points
(residual stream, recurrent carries).  Outside a mesh context these are
no-ops, so smoke tests and the paper-reproduction experiments run unchanged
on one device; inside `use_mesh(mesh)` they become GSPMD sharding
constraints.  Axis names not present on the active mesh are dropped.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE: contextvars.ContextVar[Optional[Mesh]] = contextvars.ContextVar(
    "repro_active_mesh", default=None)
_WEIGHT_GATHER: contextvars.ContextVar = contextvars.ContextVar(
    "repro_weight_gather", default=None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], weight_gather=None):
    token = _ACTIVE.set(mesh)
    tok2 = _WEIGHT_GATHER.set(weight_gather)
    try:
        yield mesh
    finally:
        _ACTIVE.reset(token)
        _WEIGHT_GATHER.reset(tok2)


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE.get()


def gather_block(block_params, compute_dtype):
    """ZeRO-3-style just-in-time weight gathering (DESIGN.md Sec. 5 /
    EXPERIMENTS.md §Perf): when the training setup registers a
    weight_gather fn (FSDP archs), cast each layer-slice weight to the
    compute dtype and re-constrain it to its TP-only sharding *inside* the
    layer scan — the all-gather then moves bf16 weight shards instead of
    f32 activation partial-sums.  No-op otherwise."""
    fn = _WEIGHT_GATHER.get()
    if fn is None:
        return block_params
    return fn(block_params, compute_dtype)


UNC = "*"  # sentinel: leave this dim's sharding to the compiler


def _filter(spec_entry, axis_names):
    if spec_entry is None:
        return None
    if spec_entry == UNC:
        return UNC
    if isinstance(spec_entry, str):
        return spec_entry if spec_entry in axis_names else None
    # tuple of axis names
    kept = tuple(a for a in spec_entry if a in axis_names)
    return kept if kept else None


def constrain(x, spec: Sequence[Union[str, None, Tuple[str, ...]]]):
    """Apply a sharding constraint if a mesh is active (else identity)."""
    mesh = _ACTIVE.get()
    if mesh is None:
        return x
    names = set(mesh.axis_names)
    entries = [_filter(e, names) for e in spec]
    # divisibility guard: drop axes that don't divide the dim
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    clean = []
    for dim, e in zip(x.shape[-len(entries):] if len(entries) <= x.ndim
                      else x.shape, entries):
        if e is None or e == UNC:
            clean.append(e)
            continue
        axes = (e,) if isinstance(e, str) else e
        total = 1
        for a in axes:
            total *= sizes[a]
        clean.append(e if dim % total == 0 else UNC)
    # left-pad for leading dims (vmap/scan may add axes): leave them to the
    # compiler (the vmap coding dim / inner batch keep their sharding)
    pad = x.ndim - len(clean)
    full = [P.UNCONSTRAINED if e == UNC else e
            for e in [UNC] * pad + clean]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*full)))
