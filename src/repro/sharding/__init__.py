from . import ctx, rules  # noqa: F401
