"""simulate_run: turn any benchmark trial into (time, loss) curves.

A paper-repro trial (`benchmarks/_repro_common.run_trial`) records loss at
step indices; a `StragglerProcess` + `StepTimer` pair independently yields
the simulated wall-clock of every step and the bytes each step put on the
wire.  `simulate_run` joins them: given the SAME process and mask key the
trial trained with, it replays the mask trace through the cost model and
returns cumulative time / bytes aligned to any recorded step axis — the
loss-vs-time story the paper motivates but loss-vs-iteration cannot tell.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from .cost_model import StepTimer
from .stragglers import StragglerProcess

__all__ = ["SimRun", "simulate_run", "attach_times", "time_to_target"]


@dataclasses.dataclass(frozen=True)
class SimRun:
    """Per-step simulated timeline of one run (arrays of length T)."""

    step_time_s: np.ndarray
    cum_time_s: np.ndarray
    bytes_up: np.ndarray
    bytes_down: np.ndarray
    participants: np.ndarray

    @property
    def total_time_s(self) -> float:
        return float(self.cum_time_s[-1])

    @property
    def total_bytes_on_wire(self) -> int:
        return int(self.bytes_up.sum() + self.bytes_down.sum())

    def at_steps(self, steps: Sequence[int]) -> Dict[str, List[float]]:
        """Cumulative time/bytes AFTER each recorded step index."""
        idx = np.asarray(steps, np.int64)
        return {
            "time_s": self.cum_time_s[idx].tolist(),
            "bytes_up_cum": np.cumsum(self.bytes_up)[idx].tolist(),
            "bytes_down_cum": np.cumsum(self.bytes_down)[idx].tolist(),
        }


def simulate_run(process: StragglerProcess, timer: StepTimer, T: int,
                 key) -> SimRun:
    """Simulate T steps: the mask trace is `process.sample_trace(key, T)` —
    pass the trial's mask key so timing and dynamics share one trace."""
    trace = process.sample_trace(key, T)
    times, b_up, b_down = timer.steps(trace)
    return SimRun(step_time_s=times, cum_time_s=np.cumsum(times),
                  bytes_up=b_up, bytes_down=b_down,
                  participants=trace.sum(axis=1))


def attach_times(hist: Dict[str, list], sim: SimRun) -> Dict[str, list]:
    """Join a recorded trial history {step, loss, ...} with the simulated
    timeline: adds time_s / bytes_*_cum columns aligned to hist['step']."""
    out = dict(hist)
    out.update(sim.at_steps(hist["step"]))
    return out


def time_to_target(times: Sequence[float], losses: Sequence[float],
                   target: float) -> Optional[float]:
    """First time the loss curve reaches `target` (linear interpolation
    between recorded points); None if it never does."""
    t = np.asarray(times, np.float64)
    l = np.asarray(losses, np.float64)
    below = np.nonzero(l <= target)[0]
    if below.size == 0:
        return None
    j = int(below[0])
    if j == 0:
        return float(t[0])
    # interpolate the crossing between the recorded points j-1 and j
    l0, l1, t0, t1 = l[j - 1], l[j], t[j - 1], t[j]
    if l0 == l1:
        return float(t1)
    frac = (l0 - target) / (l0 - l1)
    return float(t0 + frac * (t1 - t0))
