"""Auto-tuning planner over the (d, wire, k) configuration plane.

Closes ROADMAP item 3.  The paper's frontier — redundancy d buys straggler
tolerance, biased compression buys uplink bytes, EF absorbs the bias (the
computation-communication tradeoff Ye & Abbe characterize analytically) —
is searched empirically in three stages:

  enumerate  `enumerate_candidates` spans the PlanSpec grid: redundancy x
             compressor x sparsity budget (+ solve_k_budgets per-rank
             budgets when the link is heterogeneous).
  prune      `prune_candidates` scores every candidate ANALYTICALLY:
             StepTimer expected step time under the rate profile x a
             convergence-penalty proxy for compression aggressiveness
             (the Beznosikov et al. contraction delta, tempered because EF
             recovers most of the bias) / the coded coverage the
             allocation achieves at those rates.  Cheap: no sampling, no
             dynamics — one StepTimer evaluation per candidate.
  confirm    `plan_search` re-ranks the top-K survivors with short
             simulated linreg runs: real EF dynamics (`core.error_feedback`)
             driven by the straggler process's masks, joined to the SAME
             trace's simulated wall clock (`simulate_run` + `attach_times`),
             ranked by time-to-target.

The analytic score and the brute-force StepTimer ranking agree by
construction on where the optimum lies (tested: the brute-force top-1 is
never pruned), so `top_k` is a confirmation budget, not a correctness knob.

`elastic_replan_hook` adapts the pruning stage for the live coding plane:
attach it to `CodingPlan.replan_hook` and every drift-triggered
re-allocation also re-ranks the candidate grid under the NEW rate
estimates, surfacing the ranking in the replan info record.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import coding, compression as C, error_feedback as EF
from repro.core.plan import PlanSpec
from .cost_model import (ComputeProfile, DEFAULT_COMPUTE, DEFAULT_LINK,
                         LinkProfile, StepTimer, solve_k_budgets)
from .simulate import attach_times, simulate_run, time_to_target
from .stragglers import HeterogeneousRates, StragglerProcess

__all__ = ["PlanCandidate", "PlanSearchResult", "enumerate_candidates",
           "plan_allocation", "plan_timer", "convergence_penalty",
           "analytic_step_s", "expected_step_s", "score_candidates",
           "prune_candidates", "plan_search", "toy_compressor",
           "elastic_replan_hook",
           "PLAN_SEARCH_SCHEMA"]

PLAN_SEARCH_SCHEMA = "repro.plan_search/v1"


# --------------------------------------------------------------------------
# candidate grid
# --------------------------------------------------------------------------

def enumerate_candidates(num_ranks: int, *,
                         d_options: Sequence[int] = (1, 2, 3),
                         k_options: Sequence[int] = (4, 8, 32),
                         allocations: Sequence[str] = ("uniform",),
                         group_size: int = 512, block_size: int = 256,
                         num_buckets: int = 1,
                         bucket_schedule: str = "pipelined",
                         backend: str = "auto",
                         link: Optional[LinkProfile] = None,
                         n: Optional[int] = None) -> List[PlanSpec]:
    """The fixed (d, wire, k) grid the planner searches.

    Every cell is a full PlanSpec (num_ranks bound), so the same list
    parameterizes the planner, the fig12 brute-force sweep, and — winner
    chosen — `TrainRun(plan=...)` directly.  When `link` carries per-rank
    bandwidths and `n` is given, a `solve_k_budgets` per-rank-budget cell
    joins the grid for each redundancy (the heterogeneous-uplink play).
    """
    plans: List[PlanSpec] = []
    for d in d_options:
        if d > num_ranks:
            continue
        for allocation in allocations:
            base = dict(d=d, allocation=allocation, group_size=group_size,
                        block_size=block_size, num_buckets=num_buckets,
                        bucket_schedule=bucket_schedule, backend=backend,
                        num_ranks=num_ranks)
            plans.append(PlanSpec(compressor="sign", **base))
            plans.append(PlanSpec(compressor="identity", **base))
            for k in k_options:
                if k > block_size:
                    continue
                plans.append(PlanSpec(compressor="block_topk",
                                      k_per_block=int(k), **base))
            if link is not None and link.rank_bandwidth_gbps and n \
                    and n % block_size == 0:
                ks = solve_k_budgets(n, num_ranks, link,
                                     block_size=block_size)
                if len(set(ks)) > 1:          # uniform budgets already in grid
                    plans.append(PlanSpec(compressor="block_topk",
                                          k_per_block=ks, **base))
    # dedupe preserving order (e.g. k_options collisions)
    seen, out = set(), []
    for p in plans:
        key = p.to_json()
        if key not in seen:
            seen.add(key)
            out.append(p)
    return out


def plan_allocation(plan: PlanSpec, rates: np.ndarray) -> coding.Allocation:
    """The coded allocation this plan deploys at the given rate profile —
    the same uniform-cyclic / rate-aware / exact-load dispatch
    `launch.train.build_train_setup` performs."""
    m = plan.num_ranks or len(rates)
    if m <= 1:
        return coding.Allocation(S=np.ones((1, 1), np.int8))
    if plan.allocation == "uniform":
        return coding.cyclic_allocation(m, m, plan.d)
    return coding.rate_aware_allocation(
        np.asarray(rates, np.float64), m, plan.d,
        exact_load=(plan.allocation == "exact_load"))


def plan_timer(plan: PlanSpec, n: int, link: LinkProfile = DEFAULT_LINK,
               compute: ComputeProfile = DEFAULT_COMPUTE,
               pack_s: float = 0.0) -> StepTimer:
    """StepTimer priced on exactly the wire/schedule this plan ships —
    "the config priced is the config run" for the planner and fig12."""
    return StepTimer(wire=plan.wire(n, 1), n=n, link=link, compute=compute,
                     num_buckets=plan.num_buckets, overlap=plan.overlap,
                     pack_s=pack_s)


# --------------------------------------------------------------------------
# analytic pruning stage
# --------------------------------------------------------------------------

def convergence_penalty(plan: PlanSpec, rates: np.ndarray,
                        n: int) -> float:
    """Steps-to-target multiplier proxy for a plan's statistical cost.

    Two factors, both >= 1:

      compression  the biased-compressor contraction delta (Beznosikov et
                   al.): keep fraction f -> (1/f)^0.25.  The 1/4 exponent
                   tempers the worst-case 1/delta iteration blow-up because
                   error feedback empirically recovers most of it (fig2/
                   fig8: sign and top-k track dense per-iteration closely);
                   sign-bit keeps magnitude-of-mean info, charged a flat
                   1.2.
      coverage     1 / mean expected coverage of the coded allocation at
                   the rate profile: subsets with no surviving holder drop
                   out of the aggregate, scaling down the useful signal
                   (the redundancy-d axis of the paper's tradeoff).

    A proxy, not a convergence bound — it only needs to rank plans well
    enough that the simulated-confirmation stage sees the true optimum
    (tested against the brute-force ranking).
    """
    if plan.compressor == "identity":
        comp = 1.0
    elif plan.compressor == "sign":
        comp = 1.2
    elif plan.compressor == "block_topk":
        ks = plan.k_per_block
        k_mean = float(np.mean(ks)) if isinstance(ks, tuple) else float(ks)
        f = min(1.0, k_mean / plan.block_size)
        comp = (1.0 / f) ** 0.25
    elif plan.compressor == "topk":
        f = min(1.0, plan.topk_k / max(n, 1))
        comp = (1.0 / f) ** 0.25
    else:                                    # pragma: no cover (validated)
        raise ValueError(f"unknown compressor {plan.compressor!r}")
    cov = float(np.mean(coding.expected_coverage(
        plan_allocation(plan, rates), rates=np.asarray(rates, np.float64))))
    return comp / max(cov, 1e-3)


def analytic_step_s(plan: PlanSpec, n: int, link: LinkProfile,
                    compute: ComputeProfile, rates: np.ndarray) -> float:
    """Closed-form expected step seconds: one StepTimer evaluation on the
    FRACTIONAL rate profile (every rank with q_i > 0 participates at its
    rate).  Pessimistic on the compute max (the slowest sometimes-alive
    rank always bounds it) but monotone in the wire/link quantities the
    grid varies — the cheap stand-in the pruning stage sorts by."""
    t, _, _ = plan_timer(plan, n, link, compute).steps(
        np.asarray(rates, np.float64)[None, :])
    return float(t[0])


def expected_step_s(plan: PlanSpec, n: int, link: LinkProfile,
                    compute: ComputeProfile, process: StragglerProcess,
                    key, T: int = 256) -> float:
    """Brute-force expected step seconds: mean StepTimer time over a
    sampled (T, N) mask trace — the ground truth `analytic_step_s`
    approximates (and the fig12 sweep prices cells with)."""
    trace = process.sample_trace(key, T)
    t, _, _ = plan_timer(plan, n, link, compute).steps(trace)
    return float(t.mean())


@dataclasses.dataclass
class PlanCandidate:
    """One scored cell of the search: analytic stage always filled,
    simulated-confirmation fields filled for survivors."""

    plan: PlanSpec
    step_s: float                       # analytic expected step seconds
    penalty: float                      # convergence-penalty proxy
    score: float                        # step_s * penalty (ranking key)
    confirmed: bool = False
    sim_time_to_target_s: Optional[float] = None
    sim_final_loss: Optional[float] = None

    def to_dict(self) -> Dict:
        return {"plan": self.plan.to_dict(), "step_s": self.step_s,
                "penalty": self.penalty, "score": self.score,
                "confirmed": self.confirmed,
                "sim_time_to_target_s": self.sim_time_to_target_s,
                "sim_final_loss": self.sim_final_loss}


def score_candidates(candidates: Sequence[PlanSpec], rates: np.ndarray,
                     n: int, link: LinkProfile,
                     compute: ComputeProfile) -> List[PlanCandidate]:
    """Analytic stage: score every candidate, return sorted best-first.
    Fully deterministic (ties broken on the serialized plan)."""
    out = []
    for p in candidates:
        step_s = analytic_step_s(p, n, link, compute, rates)
        pen = convergence_penalty(p, rates, n)
        out.append(PlanCandidate(plan=p, step_s=step_s, penalty=pen,
                                 score=step_s * pen))
    out.sort(key=lambda c: (c.score, c.plan.to_json()))
    return out


def prune_candidates(candidates: Sequence[PlanSpec], rates: np.ndarray,
                     n: int, link: LinkProfile = DEFAULT_LINK,
                     compute: ComputeProfile = DEFAULT_COMPUTE,
                     top_k: int = 4) -> List[PlanCandidate]:
    """Keep the `top_k` best analytic scores (the confirmation budget)."""
    return score_candidates(candidates, rates, n, link, compute)[:top_k]


# --------------------------------------------------------------------------
# simulated confirmation stage
# --------------------------------------------------------------------------

def toy_compressor(plan: PlanSpec, dim: int, n: int):
    """Map a plan's wire to the reference compressor driving the linreg
    confirmation dynamics at toy dimension `dim` (the fig8 convention:
    dynamics at toy scale, wire priced at production scale).  Block-top-K
    budgets keep their KEEP FRACTION: k_toy/block_toy = k/block (per-rank
    tuples use the mean budget — the dynamics see one fleet-average
    compressor; the per-rank byte asymmetry is priced by the timer)."""
    if plan.compressor == "identity":
        return None                                   # uncompressed step
    if plan.compressor == "sign":
        return C.GroupedSign()
    if plan.compressor == "block_topk":
        ks = plan.k_per_block
        k_mean = float(np.mean(ks)) if isinstance(ks, tuple) else float(ks)
        block_toy = dim if dim <= plan.block_size else plan.block_size
        while dim % block_toy:
            block_toy -= 1                            # largest divisor
        k_toy = max(1, int(round(block_toy * k_mean / plan.block_size)))
        return C.BlockTopK(k_per_block=k_toy, block_size=block_toy)
    if plan.compressor == "topk":
        f = min(1.0, plan.topk_k / max(n, 1))
        return C.TopK(k=max(1, int(round(dim * f))))
    raise ValueError(f"unknown compressor {plan.compressor!r}")


def _confirm_curve(plan: PlanSpec, process: StragglerProcess,
                   rates: np.ndarray, n: int, link: LinkProfile,
                   compute: ComputeProfile, *, T: int, trials: int,
                   seed: int, dim: int, gamma: float,
                   record_every: int) -> Dict[str, list]:
    """Short simulated linreg run: EF dynamics under the process's masks,
    joined to the same trace's simulated wall clock.  Returns the
    trial-mean {step, loss, time_s} curve."""
    from repro.data import tasks                      # lazy: toy-task dep
    N = process.num_devices
    alloc = plan_allocation(plan, rates)
    W = coding.encode_weights(alloc, rates=np.asarray(rates, np.float64))
    comp = toy_compressor(plan, dim, n)
    timer = plan_timer(plan, n, link, compute)
    curves = []
    for s in range(trials):
        grad_fn, loss_fn, theta0, _ = tasks.linreg_task(
            seed=seed + s, num_subsets=alloc.num_subsets, dim=dim)
        mask_key = jax.random.PRNGKey(1000 + seed + s)
        st = EF.EFState.init(theta0, N)
        hist = {"step": [], "loss": []}
        for t in range(T):
            mask = process.mask(mask_key, t)
            if comp is None:
                st = EF.uncompressed_step(st, grad_fn, W, mask, gamma,
                                          step=t)
            else:
                st = EF.cocoef_step(st, grad_fn, W, mask, gamma, comp,
                                    step=t)
            if t % record_every == 0 or t == T - 1:
                hist["step"].append(t)
                hist["loss"].append(float(loss_fn(st.theta)))
        sim = simulate_run(process, timer, T, mask_key)
        curves.append(attach_times(hist, sim))
    arr = lambda k: np.array([c[k] for c in curves])
    return {"step": curves[0]["step"], "loss": arr("loss").mean(0).tolist(),
            "time_s": arr("time_s").mean(0).tolist()}


@dataclasses.dataclass
class PlanSearchResult:
    """Ranked output of `plan_search` (best first by simulated
    time-to-target among the confirmed, then analytic score)."""

    candidates: List[PlanCandidate]
    target_loss: float
    num_enumerated: int
    pruned_to: int

    @property
    def best(self) -> PlanCandidate:
        return self.candidates[0]

    def to_dict(self) -> Dict:
        return {"schema": PLAN_SEARCH_SCHEMA,
                "target_loss": self.target_loss,
                "num_enumerated": self.num_enumerated,
                "pruned_to": self.pruned_to,
                "best": self.best.to_dict(),
                "ranking": [c.to_dict() for c in self.candidates]}

    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    def save(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")


def plan_search(n: int, *, link: LinkProfile = DEFAULT_LINK,
                compute: ComputeProfile = DEFAULT_COMPUTE,
                process: Optional[StragglerProcess] = None,
                rates: Optional[Sequence[float]] = None,
                candidates: Optional[Sequence[PlanSpec]] = None,
                top_k: int = 4, confirm_steps: int = 300,
                trials: int = 2, seed: int = 0, dim: int = 256,
                gamma: float = 1e-5, record_every: int = 20,
                target_frac: float = 0.8) -> PlanSearchResult:
    """The full three-stage search.  Deterministic in `seed`.

    Provide a `process` (simulated deployment) or live `rates` (e.g. a
    `RateEstimator` snapshot — a per-rank Bernoulli process is synthesized
    for the confirmation masks).  `n` is the production flat gradient size
    the wires are priced at; the confirmation dynamics run a linreg at toy
    `dim` under the SAME masks and the priced wall clock (fig8's
    convention).  Time-to-target uses the shared relative-drop convention
    (`target_frac` of the way from the common initial loss to the worst
    survivor's floor), so every survivor can reach it.
    """
    if process is None:
        if rates is None:
            raise ValueError("plan_search needs a StragglerProcess or a "
                             "rates vector")
        q = np.clip(np.asarray(rates, np.float64), 0.0, 1.0)
        process = HeterogeneousRates(
            num_devices=len(q),
            p_ranks=tuple(float(min(max(1.0 - r, 0.0), 0.999))
                          for r in q))
    q = np.asarray(process.rates(), np.float64)
    num_ranks = process.num_devices
    if candidates is None:
        candidates = enumerate_candidates(num_ranks, link=link, n=n)
    ranked = score_candidates(candidates, q, n, link, compute)
    survivors = ranked[:max(1, top_k)]

    curves = {}
    for cand in survivors:
        curves[id(cand)] = _confirm_curve(
            cand.plan, process, q, n, link, compute, T=confirm_steps,
            trials=trials, seed=seed, dim=dim, gamma=gamma,
            record_every=record_every)
    # shared drop target: frac of the way from the common initial loss to
    # the worst survivor's floor (every survivor reaches it)
    loss0 = max(c["loss"][0] for c in curves.values())
    floor = max(min(c["loss"]) for c in curves.values())
    target = loss0 - target_frac * (loss0 - floor)
    for cand in survivors:
        c = curves[id(cand)]
        cand.confirmed = True
        cand.sim_time_to_target_s = time_to_target(c["time_s"], c["loss"],
                                                   target)
        cand.sim_final_loss = float(c["loss"][-1])
    inf = float("inf")
    survivors.sort(key=lambda c: (
        c.sim_time_to_target_s if c.sim_time_to_target_s is not None
        else inf, c.score, c.plan.to_json()))
    return PlanSearchResult(candidates=survivors + ranked[len(survivors):],
                            target_loss=float(target),
                            num_enumerated=len(candidates),
                            pruned_to=len(survivors))


# --------------------------------------------------------------------------
# elastic integration
# --------------------------------------------------------------------------

def elastic_replan_hook(n: int, *, link: LinkProfile = DEFAULT_LINK,
                        compute: ComputeProfile = DEFAULT_COMPUTE,
                        candidates: Optional[Sequence[PlanSpec]] = None,
                        top_k: int = 4):
    """Pruning-stage re-invocation for the live coding plane.

    Returns a callable suitable for `CodingPlan.replan_hook`: on every
    drift-triggered re-allocation it re-scores the candidate grid under
    the NEW rate estimates and returns the analytic ranking as a list of
    dicts (JSON-able — it lands in the replan info record /
    MetricsLogger.log_replan).  Advisory by design: the running wire's
    payload shapes cannot change mid-jit, so the ranking tells the
    operator (or a restart controller) what the planner would now pick.
    """
    def hook(rates: np.ndarray):
        q = np.asarray(rates, np.float64)
        cands = candidates
        if cands is None:
            cands = enumerate_candidates(len(q), link=link, n=n)
        ranked = prune_candidates(cands, q, n, link, compute, top_k=top_k)
        return [c.to_dict() for c in ranked]
    return hook
