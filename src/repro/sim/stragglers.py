"""Pluggable straggler processes: per-step participation masks I^t.

The repo's seed straggler model is the iid Bernoulli coin flip of eq. (8)
(`repro.core.coding.straggler_mask`).  Real clusters are not iid: devices go
slow in *bursts* (thermal throttling, co-tenant interference), different
devices have persistently different speeds (heterogeneous fleets, Song &
Choi 2021), and recorded incidents should be replayable.  A
`StragglerProcess` abstracts all of these behind one contract:

  mask(key, step) -> (N,) f32 in {0,1}   1 = device participates this step.

`mask` is a PURE function of `(key, step)` — exactly the property the
training path relies on (every mesh rank / host derives the same mask from
the threaded `jax.random` key without communication, and the call is
jit-traceable with `step` a traced scalar).  Processes with temporal state
(MarkovBursty) realize it through common randomness: the per-step uniforms
u_s = U(fold_in(key, s)) are shared between adjacent steps' lookback
windows, so masks at different steps are jointly distributed as the chain.

Implementations:

  IIDBernoulli        wraps the legacy eq.-(8) model BIT-FOR-BIT.
  MarkovBursty        per-rank two-state (fast/slow) Markov chain:
                      geometric slow bursts of configurable mean length,
                      stationary straggle probability p.
  HeterogeneousRates  independent Bernoulli with per-rank p_i (linear or
                      two-class speed profiles, or explicit rates).
  TraceReplay         deterministic masks replayed from a recorded trace —
                      mask JSON or per-rank availability CSV (cyclic
                      beyond the trace length).

`sample_trace(key, T)` materializes the host-side (T, N) mask matrix the
simulation/cost-model layer consumes; it is definitionally
`[mask(key, t) for t in range(T)]`, so simulated wall-clock time and the
training dynamics always see the SAME mask sequence.
"""
from __future__ import annotations

import dataclasses
import json
from functools import cached_property
from pathlib import Path
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import coding

__all__ = [
    "StragglerProcess",
    "IIDBernoulli",
    "MarkovBursty",
    "HeterogeneousRates",
    "TraceReplay",
    "get_straggler_process",
    "STRAGGLER_PROCESSES",
]


@dataclasses.dataclass(frozen=True)
class StragglerProcess:
    """Base class; subclasses are frozen dataclasses => valid static args."""

    num_devices: int

    def mask(self, key: jax.Array, step) -> jnp.ndarray:
        """(N,) f32 participation indicators; pure in (key, step)."""
        raise NotImplementedError

    def rates(self) -> np.ndarray:
        """(N,) marginal participation probability per rank (1 - p_i)."""
        raise NotImplementedError

    def sample_trace(self, key: jax.Array, T: int) -> np.ndarray:
        """(T, N) float 0/1 masks — the exact sequence training would see.

        Definitionally `[mask(key, t) for t in range(T)]` (vmapped), so the
        cost model and the optimizer dynamics are driven by identical masks.
        """
        steps = jnp.arange(T, dtype=jnp.int32)
        tr = jax.vmap(lambda s: self.mask(key, s))(steps)
        return np.asarray(tr)


@dataclasses.dataclass(frozen=True)
class IIDBernoulli(StragglerProcess):
    """The paper's eq.-(8) model: each device independently straggles with
    probability p each step.  Delegates to the legacy
    `coding.straggler_mask`, so masks are bit-for-bit identical to the
    pre-subsystem training path for the same (key, step)."""

    p: float = 0.0

    def __post_init__(self):
        if not 0.0 <= self.p < 1.0:
            raise ValueError(f"straggle probability p={self.p} not in [0, 1)")

    def mask(self, key, step):
        return coding.straggler_mask(key, step, self.num_devices, self.p)

    def rates(self):
        return np.full((self.num_devices,), 1.0 - self.p)


@dataclasses.dataclass(frozen=True)
class MarkovBursty(StragglerProcess):
    """Per-rank two-state Markov chain: slow periods arrive in geometric
    bursts (mean length `mean_burst`), stationary straggle probability `p`.

    Transition probabilities: exit q = 1/mean_burst (slow -> fast), entry
    r = p*q/(1-p) (fast -> slow), so P_stationary(slow) = r/(r+q) = p and
    slow-run lengths are Geometric(q) with mean 1/q.

    Purity in (key, step) uses the monotone-coupling collapse: with the
    shared uniforms u_s = U(fold_in(key, s)) and r <= 1-q, the event
    {u_s < r} forces slow and {u_s >= 1-q} forces fast REGARDLESS of the
    previous state, so the chain state at `step` is determined by the last
    coalescing event in a lookback window of `window` steps (seeded with a
    stationary draw at the window's far edge).  Adjacent steps share their
    uniforms, so the joint law across steps is the chain's; the truncated
    pre-window memory contributes O((1-q-r)^window) total-variation error
    (~2e-4 at the defaults).
    """

    p: float = 0.1
    mean_burst: float = 8.0
    window: int = 64

    def __post_init__(self):
        if not 0.0 <= self.p < 1.0:
            raise ValueError(f"stationary straggle p={self.p} not in [0, 1)")
        if self.mean_burst < 1.0:
            raise ValueError("mean_burst must be >= 1 step")
        q, r = self._qr()
        if r > 1.0 - q:
            raise ValueError(
                f"entry rate r={r:.3f} > 1-q={1-q:.3f}: burst too short for "
                f"this straggle probability (raise mean_burst or lower p)")

    def _qr(self) -> Tuple[float, float]:
        q = 1.0 / self.mean_burst
        r = self.p * q / (1.0 - self.p) if self.p > 0 else 0.0
        return q, r

    def mask(self, key, step):
        n, w = self.num_devices, self.window
        q, r = self._qr()
        t = jnp.asarray(step, jnp.int32)
        # shared per-step uniforms for the lookback window t-w+1 .. t
        # (negative steps wrap through uint32 — a consistent virtual past,
        # so the chain is stationary from step 0)
        steps = (t - (w - 1) + jnp.arange(w, dtype=jnp.int32)).astype(
            jnp.uint32)
        u = jax.vmap(lambda s: jax.random.uniform(
            jax.random.fold_in(key, s), (n,)))(steps)          # (w, n)
        # stationary seed at the window's far edge (distinct fold stream)
        seed_key = jax.random.fold_in(jax.random.fold_in(key, steps[0]),
                                      jnp.uint32(0x5EED))
        slow0 = jax.random.uniform(seed_key, (n,)) < self.p

        def chain(slow, u_row):
            thr = jnp.where(slow, 1.0 - q, r)
            return u_row < thr, None

        slow, _ = lax.scan(chain, slow0, u)
        return (~slow).astype(jnp.float32)

    def rates(self):
        return np.full((self.num_devices,), 1.0 - self.p)


def _linear_rates(num_devices: int, p: float, spread: float) -> Tuple[float, ...]:
    """Per-rank straggle probabilities p_i = p * (1 +/- spread), linearly
    spaced rank 0 (fastest) -> rank N-1 (slowest).

    Raises unless every p_i lands in [0, 1) — silently clipping out-of-range
    rates used to surface later as NaNs / biased marginals deep inside jit.
    """
    if spread < 0.0:
        raise ValueError(f"straggler spread={spread} must be >= 0")
    lo, hi = p * (1.0 - spread), p * (1.0 + spread)
    if lo < 0.0 or hi >= 1.0:
        raise ValueError(
            f"spread={spread} puts per-rank straggle probabilities in "
            f"[{lo:.3f}, {hi:.3f}], outside [0, 1) — lower p or spread")
    ps = np.linspace(lo, hi, max(num_devices, 1))
    return tuple(float(x) for x in ps)


@dataclasses.dataclass(frozen=True)
class HeterogeneousRates(StragglerProcess):
    """Independent Bernoulli stragglers with per-rank probability p_i —
    persistent speed heterogeneity (slow edge devices straggle often, fast
    ones rarely), the fleet model of Song & Choi 2021."""

    p_ranks: Tuple[float, ...] = ()

    def __post_init__(self):
        if len(self.p_ranks) != self.num_devices:
            raise ValueError(f"need {self.num_devices} per-rank rates, got "
                             f"{len(self.p_ranks)}")
        # vectorized: a per-element python loop dominated construction at
        # 1000+-rank fleet sizes
        ps = np.asarray(self.p_ranks, np.float64)
        if ps.size and (np.any(ps < 0.0) or np.any(ps >= 1.0)):
            raise ValueError("every p_i must be in [0, 1)")

    @classmethod
    def linear(cls, num_devices: int, p: float,
               spread: float = 0.5) -> "HeterogeneousRates":
        """Linear speed profile around mean straggle probability p."""
        return cls(num_devices=num_devices,
                   p_ranks=_linear_rates(num_devices, p, spread))

    @classmethod
    def two_class(cls, num_devices: int, p_slow: float, p_fast: float = 0.0,
                  slow_fraction: float = 0.25) -> "HeterogeneousRates":
        """A slow minority (first ceil(f*N) ranks) in a fast fleet."""
        n_slow = int(np.ceil(slow_fraction * num_devices))
        ps = (p_slow,) * n_slow + (p_fast,) * (num_devices - n_slow)
        return cls(num_devices=num_devices, p_ranks=ps)

    def mask(self, key, step):
        k = jax.random.fold_in(key, jnp.asarray(step, dtype=jnp.uint32))
        pv = jnp.asarray(self.p_ranks, jnp.float32)
        return (jax.random.uniform(k, (self.num_devices,)) >= pv).astype(
            jnp.float32)

    def rates(self):
        return 1.0 - np.asarray(self.p_ranks, np.float64)


@dataclasses.dataclass(frozen=True)
class TraceReplay(StragglerProcess):
    """Deterministic replay of a recorded mask trace — the PRNG key is
    ignored, so every device/host/step derives the identical mask from the
    trace alone.  Steps beyond the trace length wrap around (cyclic)."""

    masks: Tuple[Tuple[int, ...], ...] = ()

    def __post_init__(self):
        if not self.masks:
            raise ValueError("empty trace")
        if any(len(row) != self.num_devices for row in self.masks):
            raise ValueError("every trace row must have num_devices entries")
        # vectorized 0/1 check: the O(T*N) python loop took longer than the
        # simulation it fed at (T=1000, N=1024)
        arr = np.asarray(self.masks)
        if not np.isin(arr, (0, 1)).all():
            raise ValueError("trace entries must be 0/1")

    @cached_property
    def _arr(self) -> jnp.ndarray:
        return jnp.asarray(self.masks, jnp.float32)

    @property
    def length(self) -> int:
        return len(self.masks)

    def mask(self, key, step):
        t = jnp.asarray(step, jnp.int32) % self.length
        return lax.dynamic_index_in_dim(self._arr, t, keepdims=False)

    def rates(self):
        return np.asarray(self.masks, np.float64).mean(axis=0)

    @classmethod
    def from_array(cls, masks) -> "TraceReplay":
        arr = np.asarray(masks)
        # bulk int conversion via .tolist(): ~50x faster than per-element
        # python int() at (T=1000, N=1024)
        return cls(num_devices=arr.shape[1],
                   masks=tuple(map(tuple,
                                   np.rint(arr).astype(np.int64).tolist())))

    @classmethod
    def from_json(cls, path: Union[str, Path]) -> "TraceReplay":
        obj = json.loads(Path(path).read_text())
        return cls.from_array(obj["masks"])

    @classmethod
    def from_csv(cls, path: Union[str, Path]) -> "TraceReplay":
        """Per-rank availability CSV: one row per step, one column per rank
        (1 = participated, 0 = straggled) — the shape real cluster logs
        export to.  A leading non-numeric header row is skipped; fractional
        availabilities round to the nearest of {0, 1} (>= 0.5 counts as
        available)."""
        path = Path(path)
        rows = []
        with open(path) as f:
            for ln, line in enumerate(f):
                cells = [c.strip() for c in line.strip().split(",")]
                if not any(cells):
                    continue                       # blank line
                try:
                    vals = [float(c) for c in cells]
                except ValueError:
                    if ln == 0 and not rows:
                        continue                   # header row
                    raise ValueError(
                        f"{path}: non-numeric entry on line {ln + 1} "
                        f"(only line 1 may be a header)")
                if rows and len(vals) != len(rows[0]):
                    raise ValueError(
                        f"{path}: line {ln + 1} has {len(vals)} columns, "
                        f"expected {len(rows[0])} (one per rank)")
                rows.append(vals)
        if not rows:
            raise ValueError(f"{path}: empty availability CSV")
        return cls.from_array(np.asarray(rows, np.float64))

    @classmethod
    def from_file(cls, path: Union[str, Path]) -> "TraceReplay":
        """Load a recorded trace from either on-disk format: `*.csv` routes
        through `from_csv` (per-rank availability columns), anything else
        through `from_json` (the recorded-mask format `to_json` writes —
        bit-compatible with the legacy path)."""
        path = Path(path)
        if path.suffix.lower() == ".csv":
            return cls.from_csv(path)
        return cls.from_json(path)

    def to_json(self, path: Union[str, Path]) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(
            {"num_devices": self.num_devices,
             "masks": [list(row) for row in self.masks]}))
        return path


STRAGGLER_PROCESSES = ("iid", "markov", "hetero", "trace")


def get_straggler_process(name: str, num_devices: int, p: float = 0.0, *,
                          mean_burst: float = 8.0, spread: float = 0.5,
                          trace: Optional[Union[str, Path]] = None,
                          ) -> StragglerProcess:
    """Name-based registry (the `--straggler` CLI surface).

    iid     IIDBernoulli(p)                  — legacy eq. (8), bit-for-bit
    markov  MarkovBursty(p, mean_burst)      — correlated slow bursts
    hetero  HeterogeneousRates.linear(p, spread) — per-rank p_i profile
    trace   TraceReplay.from_file(trace)     — recorded masks (JSON) or a
            per-rank availability CSV (one row per step, one column per
            rank; real cluster traces)

    All knobs are validated here (p in [0, 1), mean_burst >= 1,
    spread >= 0 with every p_i in [0, 1)) so bad CLI values fail with a
    clear ValueError instead of NaNs deep inside jit.
    """
    if name != "trace" and not 0.0 <= p < 1.0:
        raise ValueError(f"straggle probability p={p} must be in [0, 1)")
    if name == "iid":
        return IIDBernoulli(num_devices=num_devices, p=p)
    if name == "markov":
        return MarkovBursty(num_devices=num_devices, p=p,
                            mean_burst=mean_burst)
    if name == "hetero":
        return HeterogeneousRates.linear(num_devices, p, spread)
    if name == "trace":
        if trace is None:
            raise ValueError("straggler='trace' needs a trace path "
                             "(recorded-mask JSON or availability CSV)")
        proc = TraceReplay.from_file(trace)
        if proc.num_devices != num_devices:
            raise ValueError(f"trace has {proc.num_devices} devices, the run "
                             f"has {num_devices}")
        return proc
    raise KeyError(f"unknown straggler process {name!r}; "
                   f"have {STRAGGLER_PROCESSES}")
