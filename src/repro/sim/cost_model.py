"""Wire-aware wall-clock cost model for one coded-training step.

The paper's device -> server -> device exchange (repro.core.collectives)
has three legs per step, and each leg's simulated duration comes straight
from the quantities the runtime actually uses:

  compute   per-rank local gradient time: a measured-or-flops-derived base
            seconds x a per-rank speed factor (heterogeneous fleets).
  phase 1   each participating rank uplinks its packed payload of
            `wire.wire_bytes(n)` bytes — the SAME accounting the comm-volume
            tables print and the collective transmits (single source of
            truth in `WireFormat`); an optional server fan-in serializes
            ingest into ceil(P / fanin) waves.
  phase 2   the aggregated dense chunk is broadcast back
            (n x phase2 itemsize bytes) over the downlink.

The step completes when the server has heard from every PARTICIPANT — the
straggler cutoff: masked-out ranks are dropped by the coded aggregation and
never extend the step (that is the point of the redundancy).  So

  t_step(mask) = max_{i: mask_i=1} t_comp_i + waves * t_up + t_down .

`StepTimer.steps(trace)` vectorizes this over a (T, N) mask trace and also
returns the bytes-on-wire ledger, which `repro.sim.simulate` joins with
recorded loss curves into time-to-accuracy data.

The default link profile is an edge/WAN-flavored cluster (the heterogeneous
setting that motivates gradient coding): 10 Gbit/s uplinks, a 100 Gbit/s
effective broadcast tree down, 1 ms message latency, unbounded fan-in.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.collectives import SparseWire, WireFormat

__all__ = ["LinkProfile", "ComputeProfile", "StepTimer", "solve_k_budgets",
           "DEFAULT_LINK", "DEFAULT_COMPUTE"]


@dataclasses.dataclass(frozen=True)
class LinkProfile:
    """Per-rank link: bandwidth + latency (+ optional server fan-in).

    bandwidth_gbps: nominal uplink Gbit/s per rank (phase-1 payload).
    rank_bandwidth_gbps: optional per-rank uplink Gbit/s overriding the
      nominal value (heterogeneous last-mile links — the setting the
      per-rank wire budgets of `solve_k_budgets` target); () = uniform.
    down_bandwidth_gbps: downlink Gbit/s for the phase-2 broadcast; None =
      same as uplink.  Server broadcast usually rides a multicast/reduce
      tree, hence the faster default.
    latency_s: fixed per-message latency (one per leg).
    server_fanin: how many uplinks the server ingests concurrently;
      0 = unbounded (full bisection), f > 0 serializes P participants into
      ceil(P / f) transfer waves.
    """

    bandwidth_gbps: float = 10.0
    down_bandwidth_gbps: Optional[float] = 100.0
    latency_s: float = 1e-3
    server_fanin: int = 0
    rank_bandwidth_gbps: Tuple[float, ...] = ()

    def __post_init__(self):
        if self.bandwidth_gbps <= 0:
            raise ValueError("uplink bandwidth must be positive")
        if self.rank_bandwidth_gbps and \
                np.any(np.asarray(self.rank_bandwidth_gbps,
                                  np.float64) <= 0):
            raise ValueError("every per-rank uplink bandwidth must be "
                             "positive")

    def up_bandwidths(self, num_ranks: int) -> np.ndarray:
        """(num_ranks,) effective uplink Gbit/s per rank."""
        if not self.rank_bandwidth_gbps:
            return np.full((num_ranks,), self.bandwidth_gbps, np.float64)
        if len(self.rank_bandwidth_gbps) != num_ranks:
            raise ValueError(
                f"link has {len(self.rank_bandwidth_gbps)} per-rank "
                f"bandwidths, asked for {num_ranks} ranks")
        return np.asarray(self.rank_bandwidth_gbps, np.float64)

    def up_s(self, nbytes: int) -> float:
        return self.latency_s + nbytes * 8.0 / (self.bandwidth_gbps * 1e9)

    def up_s_ranks(self, nbytes: Sequence[float]) -> np.ndarray:
        """(num_ranks,) uplink seconds for per-rank payload byte counts."""
        nb = np.asarray(nbytes, np.float64)
        bw = self.up_bandwidths(nb.shape[0])
        return self.latency_s + nb * 8.0 / (bw * 1e9)

    def down_s(self, nbytes: int) -> float:
        bw = self.down_bandwidth_gbps or self.bandwidth_gbps
        return self.latency_s + nbytes * 8.0 / (bw * 1e9)


@dataclasses.dataclass(frozen=True)
class ComputeProfile:
    """Per-rank local-gradient time = base seconds x per-rank speed factor.

    grad_s: base seconds for one local coded gradient (measured, or derive
      via `from_flops`).
    speed_factors: per-rank multiplier (>= 1 = slower rank); () = all 1.0.
    """

    grad_s: float = 5e-3
    speed_factors: Tuple[float, ...] = ()

    @classmethod
    def from_flops(cls, flops_per_step: float, device_flops: float = 1e14,
                   mfu: float = 0.4, speed_factors: Tuple[float, ...] = ()
                   ) -> "ComputeProfile":
        """Derive the base compute time from a flop count and device peak."""
        return cls(grad_s=flops_per_step / (device_flops * mfu),
                   speed_factors=speed_factors)

    @classmethod
    def from_compiled_hlo(cls, hlo_text: str, ndev: int,
                          device_flops: float = 1e14, mfu: float = 0.4,
                          speed_factors: Tuple[float, ...] = ()
                          ) -> "ComputeProfile":
        """Per-model compute profile from a compiled train step: the
        while-aware `repro.launch.hlo_cost` flop count of the optimized HLO
        (per device) through `from_flops`.  This is how the model-zoo sweep
        (benchmarks/fig10_model_zoo.py) replaces the fixed 5 ms default
        with architecture-dependent step compute."""
        from repro.launch import hlo_cost   # lazy: sim must not pull launch
        cost = hlo_cost.analyze(hlo_text, ndev)
        return cls.from_flops(cost.flops, device_flops=device_flops,
                              mfu=mfu, speed_factors=speed_factors)

    def rank_seconds(self, num_devices: int) -> np.ndarray:
        if not self.speed_factors:
            return np.full((num_devices,), self.grad_s)
        if len(self.speed_factors) != num_devices:
            raise ValueError(f"need {num_devices} speed factors, got "
                             f"{len(self.speed_factors)}")
        return self.grad_s * np.asarray(self.speed_factors, np.float64)


DEFAULT_LINK = LinkProfile()
DEFAULT_COMPUTE = ComputeProfile()


@dataclasses.dataclass(frozen=True)
class StepTimer:
    """Simulated wall-clock + bytes ledger for one coded step.

    wire: the phase-1 `WireFormat` (bytes via `wire.wire_bytes(n)` — the
      single source of truth shared with benchmarks/comm_volume.py).
    n: flat coords per rank on the wire (the padded local gradient size).
    phase2_itemsize: bytes/coord of the aggregated broadcast (4 = the
      paper-faithful f32 server broadcast, 2 = bf16 beyond-paper option).
    num_buckets: buckets the flat vector is split into (one phase-1 +
      phase-2 exchange each, so serial mode pays the per-message latency
      per bucket) — mirrors CocoEFConfig.num_buckets.
    overlap: model the pipelined bucket schedule
      (CocoEFConfig.bucket_schedule="pipelined"): with B buckets the step
      is a 3-stage pipeline pack -> uplink -> downlink over B items, so
      after filling, the per-bucket BOTTLENECK stage is paid B-1 times
      instead of the full per-bucket sum.  Requires num_buckets > 1 to
      change anything.
    pack_s: per-step local pack/compress seconds fed into the overlap
      pipeline as its compute stage (measure with benchmarks/
      kernel_bench.py: the fused ef_*_local_step time); 0.0 keeps the
      packing inside `compute` exactly as before.
    """

    wire: WireFormat
    n: int
    link: LinkProfile = DEFAULT_LINK
    compute: ComputeProfile = DEFAULT_COMPUTE
    phase2_itemsize: int = 4
    num_buckets: int = 1
    overlap: bool = False
    pack_s: float = 0.0

    def __post_init__(self):
        if self.num_buckets < 1:
            raise ValueError("num_buckets must be >= 1")
        if self.pack_s < 0:
            raise ValueError("pack_s must be >= 0")

    def bytes_up(self) -> int:
        """Phase-1 payload bytes for one rank — `wire.wire_bytes(n)` (the
        shipped payload shape; per-rank budget wires refine this via
        `bytes_up_ranks`)."""
        return int(self.wire.wire_bytes(self.n))

    def bytes_up_ranks(self, num_ranks: int) -> np.ndarray:
        """(num_ranks,) per-rank phase-1 bytes — `wire.rank_wire_bytes`,
        the same per-rank accounting `benchmarks/comm_volume.py` audits."""
        return self.wire.rank_wire_bytes(self.n, num_ranks)

    def bytes_down(self) -> int:
        """Phase-2 broadcast bytes received by one rank."""
        return self.n * self.phase2_itemsize

    def _waves(self, participants: np.ndarray) -> np.ndarray:
        f = self.link.server_fanin
        if f <= 0:
            return np.ones_like(participants, dtype=np.float64)
        return np.ceil(participants / f)

    def step_time(self, mask: Sequence[float]) -> float:
        """Seconds for one step under participation mask (N,)."""
        t, _, _ = self.steps(np.asarray(mask)[None, :])
        return float(t[0])

    def steps(self, trace: np.ndarray
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Vectorized over a (T, N) mask trace.

        Returns (step_time_s (T,), bytes_up (T,), bytes_down (T,)):
        per-step seconds, total uplink bytes (participants x payload), and
        total downlink bytes (every rank receives the broadcast).
        """
        trace = np.asarray(trace, np.float64)
        T, N = trace.shape
        comp = self.compute.rank_seconds(N)                    # (N,)
        b_up_r = self.bytes_up_ranks(N).astype(np.float64)     # (N,)
        up_r = self.link.up_s_ranks(b_up_r)                    # (N,)
        participants = trace.sum(axis=1)                       # (T,)
        # slowest participating rank's compute, then the slowest
        # participating uplink (per-rank bytes x per-rank bandwidth).
        # ALL-STRAGGLER SEMANTICS (the single definition, mirrored by the
        # training step and tested end to end): the server waits out the
        # slowest rank's compute window (its timeout), receives nothing on
        # the uplink (zero uplink time and bytes), and still broadcasts the
        # zero aggregate so every rank stays in lockstep — the training
        # step applies ghat = 0 and leaves the error vectors untouched.
        t_comp = np.where(participants > 0,
                          np.max(np.where(trace > 0, comp[None, :], 0.0),
                                 axis=1),
                          comp.max())
        # split latency from transfer so bucketing can divide the transfer
        # while charging the per-message latency per bucket
        lat = self.link.latency_s
        B = self.num_buckets
        xfer_r = up_r - lat                                    # (N,) s
        xfer_max = np.max(np.where(trace > 0, xfer_r[None, :], 0.0), axis=1)
        waves = self._waves(participants)
        has_up = (participants > 0).astype(np.float64)
        down_xfer = self.link.down_s(self.bytes_down()) - lat
        if self.overlap and B > 1:
            # pipelined bucket schedule: pack -> uplink -> downlink stream
            # over B equal buckets; after the pipeline fills, each extra
            # bucket costs only the bottleneck stage.  All-straggler steps
            # still broadcast the zero aggregate per bucket (zero uplink).
            pack_b = self.pack_s / B
            up_b = has_up * waves * (lat + xfer_max / B)
            down_b = lat + down_xfer / B
            bottleneck = np.maximum(np.maximum(pack_b, up_b), down_b)
            t_agg = pack_b + up_b + down_b + (B - 1) * bottleneck
        else:
            t_up = has_up * waves * (B * lat + xfer_max)
            t_down = B * lat + down_xfer
            t_agg = self.pack_s + t_up + t_down
        times = t_comp + t_agg
        bytes_up = trace @ b_up_r
        bytes_down = np.full((T,), float(N * self.bytes_down()))
        return times, bytes_up, bytes_down


def solve_k_budgets(n: int, num_ranks: int, link: LinkProfile, *,
                    block_size: int = 512, value_dtype: str = "float32",
                    k_ref: int = 8, deadline_s: Optional[float] = None,
                    k_min: int = 1) -> Tuple[int, ...]:
    """Equal-time per-rank top-K wire budgets for heterogeneous uplinks.

    Picks k_i per rank so every rank's phase-1 uplink of a
    `SparseWire(k_i, block_size)` payload fits one deadline — by default
    the uplink seconds of the uniform reference wire `SparseWire(k_ref)`
    on the nominal `link.bandwidth_gbps`.  Slow-uplink ranks therefore
    send fewer coordinates per block instead of stretching the step:

        k_i = floor( (deadline_bytes_i / nblocks - scale_bytes)
                     / (index_bytes + value_bytes) )

    clipped to [k_min, block_size] (the k_min floor keeps a rank
    contributing even when its link cannot meet the deadline).  Feed the
    result to `SparseWire(k_per_block=ks)` / `CocoEFConfig.k_per_block`.
    """
    if n % block_size:
        raise ValueError(f"n={n} must be a multiple of block_size="
                         f"{block_size} (pad upstream)")
    ref = SparseWire(k_per_block=k_ref, block_size=block_size,
                     value_dtype=value_dtype)
    if deadline_s is None:
        deadline_s = link.latency_s + \
            ref.wire_bytes(n) * 8.0 / (link.bandwidth_gbps * 1e9)
    if deadline_s <= link.latency_s:
        raise ValueError(f"deadline {deadline_s}s is not above the link "
                         f"latency {link.latency_s}s")
    bw = link.up_bandwidths(num_ranks)                         # Gbit/s
    budget_bytes = (deadline_s - link.latency_s) * bw * 1e9 / 8.0
    nb = n // block_size
    idx_b = 2 if block_size <= (1 << 16) else 4
    val_b = np.dtype(value_dtype).itemsize
    # epsilon before the floor: the deadline->bytes round trip loses an ulp,
    # which would otherwise knock an exactly-affordable k down by one
    k = np.floor((budget_bytes / nb - 4.0) / (idx_b + val_b) + 1e-9)
    k = np.clip(k, k_min, block_size).astype(np.int64)
    return tuple(int(v) for v in k)
