"""Wire-aware wall-clock cost model for one coded-training step.

The paper's device -> server -> device exchange (repro.core.collectives)
has three legs per step, and each leg's simulated duration comes straight
from the quantities the runtime actually uses:

  compute   per-rank local gradient time: a measured-or-flops-derived base
            seconds x a per-rank speed factor (heterogeneous fleets).
  phase 1   each participating rank uplinks its packed payload of
            `wire.wire_bytes(n)` bytes — the SAME accounting the comm-volume
            tables print and the collective transmits (single source of
            truth in `WireFormat`); an optional server fan-in serializes
            ingest into ceil(P / fanin) waves.
  phase 2   the aggregated dense chunk is broadcast back
            (n x phase2 itemsize bytes) over the downlink.

The step completes when the server has heard from every PARTICIPANT — the
straggler cutoff: masked-out ranks are dropped by the coded aggregation and
never extend the step (that is the point of the redundancy).  So

  t_step(mask) = max_{i: mask_i=1} t_comp_i + waves * t_up + t_down .

`StepTimer.steps(trace)` vectorizes this over a (T, N) mask trace and also
returns the bytes-on-wire ledger, which `repro.sim.simulate` joins with
recorded loss curves into time-to-accuracy data.

The default link profile is an edge/WAN-flavored cluster (the heterogeneous
setting that motivates gradient coding): 10 Gbit/s uplinks, a 100 Gbit/s
effective broadcast tree down, 1 ms message latency, unbounded fan-in.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.collectives import WireFormat

__all__ = ["LinkProfile", "ComputeProfile", "StepTimer", "DEFAULT_LINK",
           "DEFAULT_COMPUTE"]


@dataclasses.dataclass(frozen=True)
class LinkProfile:
    """Per-rank link: bandwidth + latency (+ optional server fan-in).

    bandwidth_gbps: uplink Gbit/s per rank (phase-1 payload).
    down_bandwidth_gbps: downlink Gbit/s for the phase-2 broadcast; None =
      same as uplink.  Server broadcast usually rides a multicast/reduce
      tree, hence the faster default.
    latency_s: fixed per-message latency (one per leg).
    server_fanin: how many uplinks the server ingests concurrently;
      0 = unbounded (full bisection), f > 0 serializes P participants into
      ceil(P / f) transfer waves.
    """

    bandwidth_gbps: float = 10.0
    down_bandwidth_gbps: Optional[float] = 100.0
    latency_s: float = 1e-3
    server_fanin: int = 0

    def up_s(self, nbytes: int) -> float:
        return self.latency_s + nbytes * 8.0 / (self.bandwidth_gbps * 1e9)

    def down_s(self, nbytes: int) -> float:
        bw = self.down_bandwidth_gbps or self.bandwidth_gbps
        return self.latency_s + nbytes * 8.0 / (bw * 1e9)


@dataclasses.dataclass(frozen=True)
class ComputeProfile:
    """Per-rank local-gradient time = base seconds x per-rank speed factor.

    grad_s: base seconds for one local coded gradient (measured, or derive
      via `from_flops`).
    speed_factors: per-rank multiplier (>= 1 = slower rank); () = all 1.0.
    """

    grad_s: float = 5e-3
    speed_factors: Tuple[float, ...] = ()

    @classmethod
    def from_flops(cls, flops_per_step: float, device_flops: float = 1e14,
                   mfu: float = 0.4, speed_factors: Tuple[float, ...] = ()
                   ) -> "ComputeProfile":
        """Derive the base compute time from a flop count and device peak."""
        return cls(grad_s=flops_per_step / (device_flops * mfu),
                   speed_factors=speed_factors)

    def rank_seconds(self, num_devices: int) -> np.ndarray:
        if not self.speed_factors:
            return np.full((num_devices,), self.grad_s)
        if len(self.speed_factors) != num_devices:
            raise ValueError(f"need {num_devices} speed factors, got "
                             f"{len(self.speed_factors)}")
        return self.grad_s * np.asarray(self.speed_factors, np.float64)


DEFAULT_LINK = LinkProfile()
DEFAULT_COMPUTE = ComputeProfile()


@dataclasses.dataclass(frozen=True)
class StepTimer:
    """Simulated wall-clock + bytes ledger for one coded step.

    wire: the phase-1 `WireFormat` (bytes via `wire.wire_bytes(n)` — the
      single source of truth shared with benchmarks/comm_volume.py).
    n: flat coords per rank on the wire (the padded local gradient size).
    phase2_itemsize: bytes/coord of the aggregated broadcast (4 = the
      paper-faithful f32 server broadcast, 2 = bf16 beyond-paper option).
    """

    wire: WireFormat
    n: int
    link: LinkProfile = DEFAULT_LINK
    compute: ComputeProfile = DEFAULT_COMPUTE
    phase2_itemsize: int = 4

    def bytes_up(self) -> int:
        """Phase-1 payload bytes for one rank — `wire.wire_bytes(n)`."""
        return int(self.wire.wire_bytes(self.n))

    def bytes_down(self) -> int:
        """Phase-2 broadcast bytes received by one rank."""
        return self.n * self.phase2_itemsize

    def _waves(self, participants: np.ndarray) -> np.ndarray:
        f = self.link.server_fanin
        if f <= 0:
            return np.ones_like(participants, dtype=np.float64)
        return np.ceil(participants / f)

    def step_time(self, mask: Sequence[float]) -> float:
        """Seconds for one step under participation mask (N,)."""
        t, _, _ = self.steps(np.asarray(mask)[None, :])
        return float(t[0])

    def steps(self, trace: np.ndarray
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Vectorized over a (T, N) mask trace.

        Returns (step_time_s (T,), bytes_up (T,), bytes_down (T,)):
        per-step seconds, total uplink bytes (participants x payload), and
        total downlink bytes (every rank receives the broadcast).
        """
        trace = np.asarray(trace, np.float64)
        T, N = trace.shape
        comp = self.compute.rank_seconds(N)                    # (N,)
        participants = trace.sum(axis=1)                       # (T,)
        # slowest participating rank; an all-straggler step still burns the
        # full compute window (the server times out waiting)
        t_comp = np.where(participants > 0,
                          np.max(np.where(trace > 0, comp[None, :], 0.0),
                                 axis=1),
                          comp.max())
        t_up = np.where(participants > 0,
                        self._waves(participants) *
                        self.link.up_s(self.bytes_up()), 0.0)
        t_down = self.link.down_s(self.bytes_down())
        times = t_comp + t_up + t_down
        bytes_up = participants * self.bytes_up()
        bytes_down = np.full((T,), float(N * self.bytes_down()))
        return times, bytes_up, bytes_down
