"""Cluster simulation subsystem: straggler processes + wall-clock cost model.

Two halves (ISSUE 3 / ROADMAP Notes):

  repro.sim.stragglers — pluggable `StragglerProcess` generators of the
    per-step participation masks I^t (iid Bernoulli, bursty Markov,
    heterogeneous per-rank rates, recorded-trace replay).  The training
    path consumes them through the mask-provider hook of
    `repro.core.cocoef.cocoef_update` / `repro.launch.train.TrainRun`.

  repro.sim.cost_model / repro.sim.simulate — `StepTimer` composes per-rank
    compute, wire bytes (straight from `WireFormat.wire_bytes`) over a
    configurable link, and the straggler cutoff into simulated step times;
    `simulate_run` converts any benchmark trial into (time, loss) curves
    and a bytes-on-wire ledger (benchmarks/fig8_time_to_accuracy.py).
"""
from .cost_model import (DEFAULT_COMPUTE, DEFAULT_LINK, ComputeProfile,
                         LinkProfile, StepTimer, solve_k_budgets)
from .planner import (PlanCandidate, PlanSearchResult, elastic_replan_hook,
                      enumerate_candidates, plan_allocation, plan_search,
                      plan_timer, prune_candidates)
from .simulate import SimRun, attach_times, simulate_run, time_to_target
from .stragglers import (STRAGGLER_PROCESSES, HeterogeneousRates,
                         IIDBernoulli, MarkovBursty, StragglerProcess,
                         TraceReplay, get_straggler_process)

__all__ = [
    "StragglerProcess", "IIDBernoulli", "MarkovBursty", "HeterogeneousRates",
    "TraceReplay", "get_straggler_process", "STRAGGLER_PROCESSES",
    "LinkProfile", "ComputeProfile", "StepTimer", "solve_k_budgets",
    "DEFAULT_LINK", "DEFAULT_COMPUTE", "SimRun", "simulate_run",
    "attach_times", "time_to_target",
    "PlanCandidate", "PlanSearchResult", "enumerate_candidates",
    "plan_allocation", "plan_timer", "prune_candidates", "plan_search",
    "elastic_replan_hook",
]
