"""Jit'd public wrappers: pick the Pallas kernel or the jnp reference.

On TPU the Pallas path lowers to Mosaic; on CPU (this container) it runs in
interpret mode.  `use_pallas=False` (the default inside the dry-run
lowering) uses the jnp path — identical math, so roofline terms are
unaffected.  The jnp hot paths for the sparse wire live in topk_fast.py
(barrier-fixed `lax.top_k`); kernels/ref.py stays the barrier-free oracle
that everything is tested against."""
from __future__ import annotations

import warnings

import jax

from . import (ref, sign_pack as sp, topk_block as tb, topk_fast as tf,
               topk_pack as tp)


def default_use_pallas() -> bool:
    return jax.default_backend() == "tpu"


BACKENDS = ("auto", "pallas", "jnp")


def backend_use_pallas(backend: str):
    """Map the train-path `backend` knob onto the `use_pallas` tristate.

    auto   -> None (Pallas on TPU, jnp reference elsewhere)
    pallas -> True (interpret mode off-TPU — the parity-suite setting)
    jnp    -> False
    """
    if backend == "auto":
        return None
    if backend == "pallas":
        return True
    if backend == "jnp":
        return False
    raise ValueError(f"unknown backend {backend!r}; have {BACKENDS}")


_fallback_warned = set()


def resolve_use_pallas(use_pallas, n: int, tile_elems: int, op: str = "",
                       dtype=None) -> bool:
    """Concrete kernel choice for a flat length `n`: the tristate
    `use_pallas` (None = Pallas iff on TPU) guarded by the kernel's row
    tile — shapes not divisible by `tile_elems` (G_BLK/R_BLK rows worth of
    elements) fall back to the jnp path, which has no tile.

    When Pallas was EXPLICITLY requested (`use_pallas=True`, i.e.
    backend="pallas") and the tile guard rejects the shape, warn once per
    (op, shape, dtype) — a silent fallback here used to make "pallas"
    benchmark numbers quietly measure the jnp path.  Keying on the shape
    alone used to swallow the warning when a LATER call hit the same
    shape through a different op or value dtype (e.g. the f32 sparse wire
    warned, then the bf16 one fell back silently); callers pass `op` and
    `dtype` so each distinct dispatch site gets its own warning."""
    use = default_use_pallas() if use_pallas is None else use_pallas
    fits = n % tile_elems == 0
    if use_pallas is True and not fits:
        key = (op, n, tile_elems, str(dtype))
        if key not in _fallback_warned:
            _fallback_warned.add(key)
            warnings.warn(
                f"backend='pallas' requested but n={n} is not a multiple of "
                f"the kernel tile ({tile_elems} elements); falling back to "
                f"the jnp path for {op or 'this op'} (warned once per "
                f"(op, shape, dtype))",
                RuntimeWarning, stacklevel=3)
    return bool(use) and fits


def sign_pack(x, group_size: int, use_pallas=None):
    use = default_use_pallas() if use_pallas is None else use_pallas
    if use:
        return sp.sign_pack(x, group_size,
                            interpret=jax.default_backend() != "tpu")
    return ref.sign_pack_ref(x, group_size)


def sign_unpack(words, scales, group_size: int):
    return ref.sign_unpack_ref(words, scales, group_size)


def ef_sign_fused(g, e, gamma, mask_self, group_size: int,
                  want_c: bool = True, use_pallas=None):
    use = default_use_pallas() if use_pallas is None else use_pallas
    if use:
        return sp.ef_sign_fused(g, e, gamma, mask_self, group_size,
                                want_c=want_c,
                                interpret=jax.default_backend() != "tpu")
    w, s, c, e_new = ref.ef_sign_fused_ref(g, e, gamma, mask_self, group_size)
    return w, s, (c if want_c else None), e_new


def sign_decode_reduce(words, scales, mask, group_size: int, use_pallas=None):
    use = default_use_pallas() if use_pallas is None else use_pallas
    if use:
        return sp.sign_decode_reduce(words, scales, mask, group_size,
                                     interpret=jax.default_backend() != "tpu")
    return ref.sign_decode_reduce_scan(words, scales, mask, group_size)


def ef_topk_fused(g, e, gamma, mask_self, k: int, block_size: int,
                  want_c: bool = True, value_dtype: str = "float32",
                  use_pallas=None):
    use = default_use_pallas() if use_pallas is None else use_pallas
    if use:
        return tp.ef_topk_fused(g, e, gamma, mask_self, k, block_size,
                                want_c=want_c, value_dtype=value_dtype,
                                interpret=jax.default_backend() != "tpu")
    return tf.ef_topk_fused_fast(g, e, gamma, mask_self, k, block_size,
                                 value_dtype=value_dtype, want_c=want_c)


def dense_decode_reduce(values, mask, use_pallas=None):
    # no Pallas variant: the payload carries no decode step to fuse with.
    # The scan variant keeps the canonical sender-order accumulation every
    # other wire's decode path uses (reference-vs-mesh parity).
    return ref.dense_decode_reduce_scan(values, mask)


def block_topk(x, k: int, block_size: int, use_pallas=None):
    use = default_use_pallas() if use_pallas is None else use_pallas
    if use:
        return tb.block_topk(x, k, block_size,
                             interpret=jax.default_backend() != "tpu")
    return ref.block_topk_ref(x, k, block_size)


def topk_pack(x, k: int, block_size: int, use_pallas=None):
    use = default_use_pallas() if use_pallas is None else use_pallas
    if use:
        return tp.topk_pack(x, k, block_size,
                            interpret=jax.default_backend() != "tpu")
    return tf.topk_pack_fast(x, k, block_size)


def topk_unpack(indices, values, scales, block_size: int):
    return ref.topk_unpack_ref(indices, values, scales, block_size)


def topk_decode_reduce(indices, values, scales, mask, block_size: int,
                       use_pallas=None):
    use = default_use_pallas() if use_pallas is None else use_pallas
    if use:
        return tp.topk_decode_reduce(indices, values, scales, mask,
                                     block_size,
                                     interpret=jax.default_backend() != "tpu")
    return ref.topk_decode_reduce_scan(indices, values, scales, mask,
                                       block_size)
