"""Jit'd public wrappers: pick the Pallas kernel or the jnp reference.

On TPU the Pallas path lowers to Mosaic; on CPU (this container) it runs in
interpret mode.  `use_pallas=False` (the default inside the dry-run
lowering) uses the pure-jnp reference — identical math, so roofline terms
are unaffected."""
from __future__ import annotations

import jax

from . import ref, sign_pack as sp, topk_block as tb, topk_pack as tp


def default_use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def sign_pack(x, group_size: int, use_pallas=None):
    use = default_use_pallas() if use_pallas is None else use_pallas
    if use:
        return sp.sign_pack(x, group_size,
                            interpret=jax.default_backend() != "tpu")
    return ref.sign_pack_ref(x, group_size)


def sign_unpack(words, scales, group_size: int):
    return ref.sign_unpack_ref(words, scales, group_size)


def ef_sign_fused(g, e, gamma, mask_self, group_size: int, use_pallas=None):
    use = default_use_pallas() if use_pallas is None else use_pallas
    if use:
        return sp.ef_sign_fused(g, e, gamma, mask_self, group_size,
                                interpret=jax.default_backend() != "tpu")
    return ref.ef_sign_fused_ref(g, e, gamma, mask_self, group_size)


def sign_decode_reduce(words, scales, mask, group_size: int, use_pallas=None):
    use = default_use_pallas() if use_pallas is None else use_pallas
    if use:
        return sp.sign_decode_reduce(words, scales, mask, group_size,
                                     interpret=jax.default_backend() != "tpu")
    return ref.sign_decode_reduce_ref(words, scales, mask, group_size)


def block_topk(x, k: int, block_size: int, use_pallas=None):
    use = default_use_pallas() if use_pallas is None else use_pallas
    if use:
        return tb.block_topk(x, k, block_size,
                             interpret=jax.default_backend() != "tpu")
    return ref.block_topk_ref(x, k, block_size)


def topk_pack(x, k: int, block_size: int, use_pallas=None):
    use = default_use_pallas() if use_pallas is None else use_pallas
    if use:
        return tp.topk_pack(x, k, block_size,
                            interpret=jax.default_backend() != "tpu")
    return ref.topk_pack_ref(x, k, block_size)


def topk_unpack(indices, values, scales, block_size: int):
    return ref.topk_unpack_ref(indices, values, scales, block_size)


def topk_decode_reduce(indices, values, scales, mask, block_size: int,
                       use_pallas=None):
    use = default_use_pallas() if use_pallas is None else use_pallas
    if use:
        return tp.topk_decode_reduce(indices, values, scales, mask,
                                     block_size,
                                     interpret=jax.default_backend() != "tpu")
    return ref.topk_decode_reduce_ref(indices, values, scales, mask,
                                      block_size)
