"""Pure-jnp oracles for every kernel in this package.

These define the semantics; the Pallas kernels must match them (allclose,
or bit-exact where noted) across the shape/dtype sweeps in
tests/test_kernels.py.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def mul_add(gamma, g: jnp.ndarray, e: jnp.ndarray) -> jnp.ndarray:
    """THE Algorithm-1 accumulate  acc = gamma * g + e  with the multiply
    and the add kept as two separately-rounded IEEE f32 ops (the
    optimization_barrier blocks XLA's FMA contraction).  Every
    implementation of the accumulate — the reference (N, D) EF loop, the
    jnp fused kernels here, and the per-rank-budget path of cocoef_update —
    routes through this one definition, so their accumulators agree
    BIT-FOR-BIT instead of drifting an FMA-ulp apart depending on the
    surrounding fusion (caught by repro.launch.parity)."""
    return jax.lax.optimization_barrier(
        gamma * g.astype(jnp.float32)) + e.astype(jnp.float32)


def sign_pack_ref(x: jnp.ndarray, group_size: int
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (n,) f32 -> (words (n/32,) u32, scales (n/g,) f32).
    scales = mean |x| per group; bit j of word w = x[32w+j] >= 0."""
    xf = x.astype(jnp.float32)
    scales = jnp.mean(jnp.abs(xf.reshape(-1, group_size)), axis=-1)
    bits = (xf >= 0).reshape(-1, 32).astype(jnp.uint32)
    words = (bits << jnp.arange(32, dtype=jnp.uint32)).sum(-1, dtype=jnp.uint32)
    return words, scales


def sign_unpack_ref(words: jnp.ndarray, scales: jnp.ndarray,
                    group_size: int) -> jnp.ndarray:
    bits = (words[:, None] >> jnp.arange(32, dtype=jnp.uint32)) & jnp.uint32(1)
    signs = bits.astype(jnp.float32).reshape(-1, group_size) * 2.0 - 1.0
    # per-group scale via broadcast (jnp.repeat lowers to a scatter loop)
    return (signs * scales.astype(jnp.float32)[:, None]).reshape(-1)


def ef_sign_fused_ref(g: jnp.ndarray, e: jnp.ndarray, gamma, mask_self,
                      group_size: int):
    """Fused Algorithm-1 local step (one pass over the model-sized vectors):
      acc = gamma * g + e
      (words, scales) = sign_pack(acc)
      c = sign_unpack(words, scales)
      e_new = mask_self ? acc - c : e
    Returns (words, scales, c, e_new)."""
    ef = e.astype(jnp.float32)
    accg = mul_add(gamma, g, e).reshape(-1, group_size)
    scales = jnp.mean(jnp.abs(accg), axis=-1)
    bits = (accg.reshape(-1, 32) >= 0).astype(jnp.uint32)
    words = (bits << jnp.arange(32, dtype=jnp.uint32)).sum(-1, dtype=jnp.uint32)
    # c == sign_unpack_ref(words, scales) bit-for-bit, but straight from acc
    # (no bit unpack): sign(acc) * group scale — matches the Pallas kernel.
    # Staying 2D until the end keeps XLA's fusions on one layout.
    c = jnp.where(accg >= 0, 1.0, -1.0) * scales[:, None]
    e_new = jnp.where(mask_self > 0, accg - c,
                      ef.reshape(-1, group_size))
    return words, scales, c.reshape(-1), e_new.reshape(-1)


def sign_decode_reduce_ref(words: jnp.ndarray, scales: jnp.ndarray,
                           mask: jnp.ndarray, group_size: int) -> jnp.ndarray:
    """Server-side decode+aggregate: words (N, n/32), scales (N, n/g),
    mask (N,) -> sum_i mask_i * unpack(words_i, scales_i)   (n,)."""
    dec = jax.vmap(lambda w, s: sign_unpack_ref(w, s, group_size)
                   )(words, scales)
    return (mask[:, None] * dec).sum(0)


def sign_decode_reduce_scan(words: jnp.ndarray, scales: jnp.ndarray,
                            mask: jnp.ndarray, group_size: int) -> jnp.ndarray:
    """Streaming jnp implementation of `sign_decode_reduce_ref` — identical
    sender-order accumulation (bit-for-bit), but scans over senders so the
    (N, n) dense tensor is never materialized.  This is the backend's jnp
    fused decode path; the vmap oracle above stays the test reference."""
    n = words.shape[1] * 32

    def body(acc, inp):
        w, s, m = inp
        return acc + m * sign_unpack_ref(w, s, group_size), None
    return jax.lax.scan(body, jnp.zeros((n,), jnp.float32),
                        (words, scales, mask))[0]


def topk_pack_ref(x: jnp.ndarray, k: int, block_size: int
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Sparse (block top-K) wire pack — repro.core.collectives.SparseWire.

    x: (n,) -> (indices (n/B, k) i32 in decreasing-|.| order (first
    occurrence wins ties, matching lax.top_k / the Pallas kernel),
    values (n/B, k) f32 normalized by the block scale, scales (n/B,) f32 =
    per-block max |x| with 1.0 substituted for all-zero blocks)."""
    blocks = x.astype(jnp.float32).reshape(-1, block_size)
    mag = jnp.abs(blocks)
    topv, idx = jax.lax.top_k(mag, k)
    sv = jnp.take_along_axis(blocks, idx, axis=-1)
    scale = topv[:, 0]                     # block max |x| = first top-k value
    safe = jnp.where(scale == 0, 1.0, scale)
    return idx.astype(jnp.int32), sv / safe[:, None], safe


def ef_topk_fused_ref(g: jnp.ndarray, e: jnp.ndarray, gamma, mask_self,
                      k: int, block_size: int,
                      value_dtype: str = "float32"):
    """Fused Algorithm-1 local step on the sparse (block top-K) wire:
      acc = gamma * g + e
      (indices, values, scales) = topk_pack(acc), values rounded to
          value_dtype (the wire's payload precision), carried in f32
      c = scatter of values * scale — the TRANSMITTED reconstruction,
          i.e. exactly what `topk_unpack_ref` gives a receiver, so the
          error update tracks the wire and no unpack-of-pack is needed
      e_new = mask_self ? acc - c : e
    Returns (indices, values, scales, c, e_new).  `c + e_new == acc` stays
    bit-exact at kept coordinates: c is within a factor of two of acc
    there (value_dtype relative error << 1/2), so Sterbenz's lemma makes
    the subtraction exact and the sum rounds back to acc."""
    accb = mul_add(gamma, g, e).reshape(-1, block_size)
    mag = jnp.abs(accb)
    topv, idx = jax.lax.top_k(mag, k)
    sv = jnp.take_along_axis(accb, idx, axis=-1)
    scale = topv[:, 0]
    safe = jnp.where(scale == 0, 1.0, scale)
    val = (sv / safe[:, None]).astype(jnp.dtype(value_dtype)).astype(
        jnp.float32)
    nb = accb.shape[0]
    base = jnp.arange(nb, dtype=jnp.int32)[:, None] * block_size
    flat_idx = (base + idx).reshape(-1)
    c = jnp.zeros((nb * block_size,), jnp.float32
                  ).at[flat_idx].set((val * safe[:, None]).reshape(-1))
    acc = accb.reshape(-1)
    e_new = jnp.where(mask_self > 0, acc - c, e.astype(jnp.float32))
    return idx.astype(jnp.int32), val, safe, c, e_new


def dense_decode_reduce_ref(values: jnp.ndarray, mask: jnp.ndarray
                            ) -> jnp.ndarray:
    """Dense-wire decode+aggregate: values (N, n) any float dtype,
    mask (N,) -> sum_i mask_i * f32(values_i)   (n,)."""
    return (mask[:, None] * values.astype(jnp.float32)).sum(0)


def dense_decode_reduce_scan(values: jnp.ndarray, mask: jnp.ndarray
                             ) -> jnp.ndarray:
    """Streaming variant of `dense_decode_reduce_ref` with the SAME
    sender-order accumulation the sign/topk scan decoders use (XLA's .sum(0)
    may reduce pairwise — a different rounding).  This is the backend's jnp
    decode path so every wire aggregates in one canonical order."""
    n = values.shape[1]

    def body(acc, inp):
        v, m = inp
        return acc + m * v.astype(jnp.float32), None
    return jax.lax.scan(body, jnp.zeros((n,), jnp.float32),
                        (values, mask))[0]


def topk_unpack_ref(indices: jnp.ndarray, values: jnp.ndarray,
                    scales: jnp.ndarray, block_size: int) -> jnp.ndarray:
    """Inverse of topk_pack_ref: scatter the kept entries back, flat (n,)."""
    nb = indices.shape[0]
    sv = values.astype(jnp.float32) * scales[:, None]
    base = jnp.arange(nb, dtype=jnp.int32)[:, None] * block_size
    flat_idx = (base + indices.astype(jnp.int32)).reshape(-1)
    return jnp.zeros((nb * block_size,), jnp.float32
                     ).at[flat_idx].set(sv.reshape(-1))


def topk_decode_reduce_ref(indices: jnp.ndarray, values: jnp.ndarray,
                           scales: jnp.ndarray, mask: jnp.ndarray,
                           block_size: int) -> jnp.ndarray:
    """Server-side sparse decode+aggregate: indices/values (N, n/B, k),
    scales (N, n/B), mask (N,) -> sum_i mask_i * unpack(payload_i)  (n,)."""
    dec = jax.vmap(lambda i, v, s: topk_unpack_ref(i, v, s, block_size)
                   )(indices, values, scales)
    return (mask[:, None] * dec).sum(0)


def topk_decode_reduce_scan(indices: jnp.ndarray, values: jnp.ndarray,
                            scales: jnp.ndarray, mask: jnp.ndarray,
                            block_size: int) -> jnp.ndarray:
    """Streaming jnp implementation of `topk_decode_reduce_ref` — identical
    sender-order accumulation (bit-for-bit) without the (N, n) dense
    tensor; the backend's jnp fused decode path."""
    n = indices.shape[1] * block_size

    def body(acc, inp):
        i, v, s, m = inp
        return acc + m * topk_unpack_ref(i, v, s, block_size), None
    return jax.lax.scan(body, jnp.zeros((n,), jnp.float32),
                        (indices, values, scales, mask))[0]


def block_topk_ref(x: jnp.ndarray, k: int, block_size: int) -> jnp.ndarray:
    """Block-local top-k sparsification (repro.core.compression.BlockTopK):
    keep the k largest-|.| entries of each contiguous block."""
    blocks = x.reshape(-1, block_size)
    topv = jax.lax.top_k(jnp.abs(blocks), k)[0]
    thr = topv[:, -1:]
    keep = jnp.abs(blocks) >= thr
    cum = jnp.cumsum(keep.astype(jnp.int32), axis=-1)
    keep = keep & (cum <= k)
    return jnp.where(keep, blocks, 0).reshape(x.shape)


def flash_attention_ref(q, k, v, softcap: float = 0.0, window: int = 0,
                        groups: int = 1):
    """q: (B,H,S,hd) pre-scaled; k,v: (B,Hkv,S,hd).  Causal+window+softcap."""
    B, H, S, hd = q.shape
    w = window if window > 0 else (1 << 30)
    kk = jnp.repeat(k, groups, axis=1)
    vv = jnp.repeat(v, groups, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32))
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    keep = (kp <= qp) & (kp > qp - w)
    s = jnp.where(keep[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)
                      ).astype(q.dtype)
