"""Pure-jnp oracles for every kernel in this package.

These define the semantics; the Pallas kernels must match them (allclose,
or bit-exact where noted) across the shape/dtype sweeps in
tests/test_kernels.py.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def sign_pack_ref(x: jnp.ndarray, group_size: int
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (n,) f32 -> (words (n/32,) u32, scales (n/g,) f32).
    scales = mean |x| per group; bit j of word w = x[32w+j] >= 0."""
    xf = x.astype(jnp.float32)
    scales = jnp.mean(jnp.abs(xf.reshape(-1, group_size)), axis=-1)
    bits = (xf >= 0).reshape(-1, 32).astype(jnp.uint32)
    words = (bits << jnp.arange(32, dtype=jnp.uint32)).sum(-1, dtype=jnp.uint32)
    return words, scales


def sign_unpack_ref(words: jnp.ndarray, scales: jnp.ndarray,
                    group_size: int) -> jnp.ndarray:
    bits = (words[:, None] >> jnp.arange(32, dtype=jnp.uint32)) & jnp.uint32(1)
    signs = bits.astype(jnp.float32).reshape(-1) * 2.0 - 1.0
    n = signs.shape[0]
    per = jnp.repeat(scales.astype(jnp.float32), group_size,
                     total_repeat_length=n)
    return signs * per


def ef_sign_fused_ref(g: jnp.ndarray, e: jnp.ndarray, gamma, mask_self,
                      group_size: int):
    """Fused Algorithm-1 local step (one pass over the model-sized vectors):
      acc = gamma * g + e
      (words, scales) = sign_pack(acc)
      c = sign_unpack(words, scales)
      e_new = mask_self ? acc - c : e
    Returns (words, scales, c, e_new)."""
    acc = gamma * g.astype(jnp.float32) + e.astype(jnp.float32)
    words, scales = sign_pack_ref(acc, group_size)
    c = sign_unpack_ref(words, scales, group_size)
    e_new = jnp.where(mask_self > 0, acc - c, e.astype(jnp.float32))
    return words, scales, c, e_new


def sign_decode_reduce_ref(words: jnp.ndarray, scales: jnp.ndarray,
                           mask: jnp.ndarray, group_size: int) -> jnp.ndarray:
    """Server-side decode+aggregate: words (N, n/32), scales (N, n/g),
    mask (N,) -> sum_i mask_i * unpack(words_i, scales_i)   (n,)."""
    dec = jax.vmap(lambda w, s: sign_unpack_ref(w, s, group_size)
                   )(words, scales)
    return (mask[:, None] * dec).sum(0)


def topk_pack_ref(x: jnp.ndarray, k: int, block_size: int
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Sparse (block top-K) wire pack — repro.core.collectives.SparseWire.

    x: (n,) -> (indices (n/B, k) i32 in decreasing-|.| order (first
    occurrence wins ties, matching lax.top_k / the Pallas kernel),
    values (n/B, k) f32 normalized by the block scale, scales (n/B,) f32 =
    per-block max |x| with 1.0 substituted for all-zero blocks)."""
    blocks = x.astype(jnp.float32).reshape(-1, block_size)
    mag = jnp.abs(blocks)
    _, idx = jax.lax.top_k(mag, k)
    sv = jnp.take_along_axis(blocks, idx, axis=-1)
    scale = jnp.max(mag, axis=-1)
    safe = jnp.where(scale == 0, 1.0, scale)
    return idx.astype(jnp.int32), sv / safe[:, None], safe


def topk_unpack_ref(indices: jnp.ndarray, values: jnp.ndarray,
                    scales: jnp.ndarray, block_size: int) -> jnp.ndarray:
    """Inverse of topk_pack_ref: scatter the kept entries back, flat (n,)."""
    nb = indices.shape[0]
    sv = values.astype(jnp.float32) * scales[:, None]
    base = jnp.arange(nb, dtype=jnp.int32)[:, None] * block_size
    flat_idx = (base + indices.astype(jnp.int32)).reshape(-1)
    return jnp.zeros((nb * block_size,), jnp.float32
                     ).at[flat_idx].set(sv.reshape(-1))


def topk_decode_reduce_ref(indices: jnp.ndarray, values: jnp.ndarray,
                           scales: jnp.ndarray, mask: jnp.ndarray,
                           block_size: int) -> jnp.ndarray:
    """Server-side sparse decode+aggregate: indices/values (N, n/B, k),
    scales (N, n/B), mask (N,) -> sum_i mask_i * unpack(payload_i)  (n,)."""
    dec = jax.vmap(lambda i, v, s: topk_unpack_ref(i, v, s, block_size)
                   )(indices, values, scales)
    return (mask[:, None] * dec).sum(0)


def block_topk_ref(x: jnp.ndarray, k: int, block_size: int) -> jnp.ndarray:
    """Block-local top-k sparsification (repro.core.compression.BlockTopK):
    keep the k largest-|.| entries of each contiguous block."""
    blocks = x.reshape(-1, block_size)
    topv = jax.lax.top_k(jnp.abs(blocks), k)[0]
    thr = topv[:, -1:]
    keep = jnp.abs(blocks) >= thr
    cum = jnp.cumsum(keep.astype(jnp.int32), axis=-1)
    keep = keep & (cum <= k)
    return jnp.where(keep, blocks, 0).reshape(x.shape)


def flash_attention_ref(q, k, v, softcap: float = 0.0, window: int = 0,
                        groups: int = 1):
    """q: (B,H,S,hd) pre-scaled; k,v: (B,Hkv,S,hd).  Causal+window+softcap."""
    B, H, S, hd = q.shape
    w = window if window > 0 else (1 << 30)
    kk = jnp.repeat(k, groups, axis=1)
    vv = jnp.repeat(v, groups, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32))
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    keep = (kp <= qp) & (kp > qp - w)
    s = jnp.where(keep[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)
                      ).astype(q.dtype)
