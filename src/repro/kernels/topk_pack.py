"""Pallas TPU kernels for the sparse (block top-K) wire format.

Mirrors kernels/sign_pack.py for the SparseWire of
`repro.core.collectives`: per contiguous block of `block_size` coords the
wire carries the k largest-|.| entries as (in-block indices, values
normalized by the per-block scale, the f32 scale).  Selection runs k rounds
of (row-max |x| over unselected, mark argmax) — pure VPU work, no sort, k is
small (4-32); tie-breaking matches kernels/ref.topk_pack_ref (lax.top_k:
first occurrence wins).

Tiling: the flat vector is processed as (rows of R_BLK blocks) x
(block_size lanes); block_size is a multiple of 128 in production so every
BlockSpec is VPU aligned:

  x block       (R_BLK, block_size)  f32  VMEM
  indices block (R_BLK, k)           i32  VMEM
  values block  (R_BLK, k)           f32  VMEM
  scales block  (R_BLK, 1)           f32  VMEM

The narrow wire dtypes (uint16 indices, bf16 values) are cast OUTSIDE the
kernel by SparseWire.pack — Mosaic keeps 32-bit lanes internally.

On this CPU container the kernels run with interpret=True (pure-JAX
semantics) and are validated against kernels/ref.py; on real TPU the same
pallas_call lowers to Mosaic.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

R_BLK = 8  # blocks (rows) per grid step


def _select_topk(x, k: int):
    """x: (R, B) f32 -> (idx (R, k) i32, sval (R, k) f32, scale (R, 1) f32).

    Indices in decreasing-magnitude order, first occurrence wins ties."""
    B = x.shape[-1]
    mag = jnp.abs(x)
    pos = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    scale = jnp.max(mag, axis=-1, keepdims=True)               # (R, 1)
    avail = jnp.ones(x.shape, jnp.bool_)
    idx_cols, val_cols = [], []
    for _ in range(k):                                         # static rounds
        m = jnp.where(avail, mag, -1.0)
        row_max = jnp.max(m, axis=-1, keepdims=True)
        is_max = (m == row_max) & avail
        first = jnp.min(jnp.where(is_max, pos, B), axis=-1, keepdims=True)
        sel = pos == first
        idx_cols.append(first.astype(jnp.int32))               # (R, 1)
        val_cols.append(jnp.sum(jnp.where(sel, x, 0.0), axis=-1,
                                keepdims=True))                # (R, 1)
        avail = avail & ~sel
    return (jnp.concatenate(idx_cols, axis=-1),
            jnp.concatenate(val_cols, axis=-1), scale)


def _topk_pack_kernel(x_ref, idx_ref, val_ref, scale_ref, *, k: int):
    x = x_ref[...].astype(jnp.float32)
    idx, sval, scale = _select_topk(x, k)
    safe = jnp.where(scale == 0, 1.0, scale)
    idx_ref[...] = idx
    val_ref[...] = sval / safe
    scale_ref[...] = safe


@functools.partial(jax.jit, static_argnames=("k", "block_size", "interpret"))
def topk_pack(x: jnp.ndarray, k: int, block_size: int, interpret: bool = True
              ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (n,) f32, n % (R_BLK * block_size) == 0 ->
    (indices (n/B, k) i32, values (n/B, k) f32, scales (n/B,) f32)."""
    n = x.shape[0]
    rows = n // block_size
    if n % (R_BLK * block_size):
        raise ValueError(f"topk_pack needs n % (R_BLK*block_size) == 0, got "
                         f"n={n}, R_BLK={R_BLK}, block_size={block_size}")
    grid = (rows // R_BLK,)
    idx, val, scale = pl.pallas_call(
        functools.partial(_topk_pack_kernel, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((R_BLK, block_size), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((R_BLK, k), lambda i: (i, 0)),
            pl.BlockSpec((R_BLK, k), lambda i: (i, 0)),
            pl.BlockSpec((R_BLK, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, k), jnp.int32),
            jax.ShapeDtypeStruct((rows, k), jnp.float32),
            jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x.reshape(rows, block_size))
    return idx, val, scale.reshape(-1)


def _topk_decode_reduce_kernel(idx_ref, val_ref, scale_ref, mask_ref, out_ref,
                               *, k: int, n_senders: int):
    pos = jax.lax.broadcasted_iota(jnp.int32, out_ref.shape, 1)  # (R, B)
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for i in range(n_senders):                                   # static loop
        sv = val_ref[i] * scale_ref[i]                           # (R, k)
        dense = jnp.zeros(out_ref.shape, jnp.float32)
        for r in range(k):                                       # static loop
            dense = dense + jnp.where(pos == idx_ref[i][:, r:r + 1],
                                      sv[:, r:r + 1], 0.0)
        acc = acc + mask_ref[i] * dense
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_size", "interpret"))
def topk_decode_reduce(indices: jnp.ndarray, values: jnp.ndarray,
                       scales: jnp.ndarray, mask: jnp.ndarray,
                       block_size: int, interpret: bool = True) -> jnp.ndarray:
    """Server-side sparse decode + masked aggregate.
    indices: (N, rows, k) i32; values: (N, rows, k) f32;
    scales: (N, rows) f32; mask: (N,) f32 -> (rows * block_size,)."""
    N, rows, k = indices.shape
    if rows % R_BLK:
        raise ValueError(f"topk_decode_reduce needs rows % R_BLK == 0, got "
                         f"rows={rows}, R_BLK={R_BLK}")
    grid = (rows // R_BLK,)
    out = pl.pallas_call(
        functools.partial(_topk_decode_reduce_kernel, k=k, n_senders=N),
        grid=grid,
        in_specs=[
            pl.BlockSpec((N, R_BLK, k), lambda i: (0, i, 0)),
            pl.BlockSpec((N, R_BLK, k), lambda i: (0, i, 0)),
            pl.BlockSpec((N, R_BLK, 1), lambda i: (0, i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((R_BLK, block_size), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, block_size), jnp.float32),
        interpret=interpret,
    )(indices.astype(jnp.int32), values.astype(jnp.float32),
      scales.reshape(N, rows, 1).astype(jnp.float32), mask)
    return out.reshape(-1)
