"""Pallas TPU kernels for the sparse (block top-K) wire format.

Mirrors kernels/sign_pack.py for the SparseWire of
`repro.core.collectives`: per contiguous block of `block_size` coords the
wire carries the k largest-|.| entries as (in-block indices, values
normalized by the per-block scale, the f32 scale).  Selection is
`topk_block.block_select` — a sort-free per-row threshold search on the
|x| bit patterns (31 monotone halving steps seeded by the block max) plus
one compaction pass, replacing the old k-round argmax whose vector
reductions grew linearly in k; tie-breaking matches
kernels/ref.topk_pack_ref (lax.top_k: first occurrence wins).

Tiling: the flat vector is processed as (rows of R_BLK blocks) x
(block_size lanes); block_size is a multiple of 128 in production so every
BlockSpec is VPU aligned:

  x block       (R_BLK, block_size)  f32  VMEM
  indices block (R_BLK, k)           i32  VMEM
  values block  (R_BLK, k)           f32  VMEM
  scales block  (R_BLK, 1)           f32  VMEM

The narrow wire dtypes (uint16 indices, bf16 values) are cast OUTSIDE the
kernel by SparseWire.pack — Mosaic keeps 32-bit lanes internally.

On this CPU container the kernels run with interpret=True (pure-JAX
semantics) and are validated against kernels/ref.py; on real TPU the same
pallas_call lowers to Mosaic.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.topk_block import block_select

R_BLK = 8  # blocks (rows) per grid step


def _topk_pack_kernel(x_ref, idx_ref, val_ref, scale_ref, *, k: int):
    x = x_ref[...].astype(jnp.float32)
    idx, sval, scale = block_select(x, k)
    safe = jnp.where(scale == 0, 1.0, scale)
    idx_ref[...] = idx
    val_ref[...] = sval / safe
    scale_ref[...] = safe


@functools.partial(jax.jit, static_argnames=("k", "block_size", "interpret"))
def topk_pack(x: jnp.ndarray, k: int, block_size: int, interpret: bool = True
              ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (n,) f32, n % (R_BLK * block_size) == 0 ->
    (indices (n/B, k) i32, values (n/B, k) f32, scales (n/B,) f32)."""
    n = x.shape[0]
    rows = n // block_size
    if n % (R_BLK * block_size):
        raise ValueError(f"topk_pack needs n % (R_BLK*block_size) == 0, got "
                         f"n={n}, R_BLK={R_BLK}, block_size={block_size}")
    grid = (rows // R_BLK,)
    idx, val, scale = pl.pallas_call(
        functools.partial(_topk_pack_kernel, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((R_BLK, block_size), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((R_BLK, k), lambda i: (i, 0)),
            pl.BlockSpec((R_BLK, k), lambda i: (i, 0)),
            pl.BlockSpec((R_BLK, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, k), jnp.int32),
            jax.ShapeDtypeStruct((rows, k), jnp.float32),
            jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x.reshape(rows, block_size))
    return idx, val, scale.reshape(-1)


def _scatter_rows(idx, sval, shape):
    """Dense (R, B) image of k kept entries per row: pos==idx_r selects."""
    pos = jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    dense = jnp.zeros(shape, jnp.float32)
    for r in range(idx.shape[-1]):                             # static loop
        dense = dense + jnp.where(pos == idx[:, r:r + 1],
                                  sval[:, r:r + 1], 0.0)
    return dense


def _ef_topk_fused_kernel(g_ref, e_ref, gamma_ref, mask_ref,
                          idx_ref, val_ref, scale_ref, *out_refs,
                          k: int, want_c: bool, value_dtype: str):
    gamma = gamma_ref[0]
    mask = mask_ref[0]
    e = e_ref[...].astype(jnp.float32)
    acc = gamma * g_ref[...].astype(jnp.float32) + e                # (R, B)
    idx, sval, scale = block_select(acc, k)
    safe = jnp.where(scale == 0, 1.0, scale)
    # normalize -> wire precision -> denormalize IN-REGISTER: c is the
    # transmitted reconstruction (== topk_unpack of the payload), so the
    # error update tracks the wire without an unpack-of-pack round trip
    val = (sval / safe).astype(jnp.dtype(value_dtype)).astype(jnp.float32)
    c = _scatter_rows(idx, val * safe, acc.shape)
    idx_ref[...] = idx
    val_ref[...] = val
    scale_ref[...] = safe
    if want_c:
        out_refs[0][...] = c
    out_refs[-1][...] = jnp.where(mask > 0, acc - c, e)


@functools.partial(jax.jit,
                   static_argnames=("k", "block_size", "want_c",
                                    "value_dtype", "interpret"))
def ef_topk_fused(g: jnp.ndarray, e: jnp.ndarray, gamma, mask_self,
                  k: int, block_size: int, want_c: bool = True,
                  value_dtype: str = "float32", interpret: bool = True):
    """Fused local COCO-EF step on the sparse wire: one HBM pass over g/e
    producing the wire payload (indices, values rounded to value_dtype,
    scales), the transmitted reconstruction C(acc) and the new error.
    g, e: (n,) f32; gamma, mask_self: scalars.
    Semantics match kernels.ref.ef_topk_fused_ref bit-for-bit.
    want_c=False skips the full-vector c store (the train path only ships
    the payload; a custom call's outputs are not DCE-able)."""
    n = g.shape[0]
    rows = n // block_size
    if n % (R_BLK * block_size):
        raise ValueError(f"ef_topk_fused needs n % (R_BLK*block_size) == 0, "
                         f"got n={n}, R_BLK={R_BLK}, block_size={block_size}")
    grid = (rows // R_BLK,)
    gamma = jnp.asarray(gamma, jnp.float32).reshape(1)
    mask_self = jnp.asarray(mask_self, jnp.float32).reshape(1)
    full = [pl.BlockSpec((R_BLK, block_size), lambda i: (i, 0)),
            jax.ShapeDtypeStruct((rows, block_size), jnp.float32)]
    outs = pl.pallas_call(
        functools.partial(_ef_topk_fused_kernel, k=k, want_c=want_c,
                          value_dtype=value_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((R_BLK, block_size), lambda i: (i, 0)),
            pl.BlockSpec((R_BLK, block_size), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[
            pl.BlockSpec((R_BLK, k), lambda i: (i, 0)),
            pl.BlockSpec((R_BLK, k), lambda i: (i, 0)),
            pl.BlockSpec((R_BLK, 1), lambda i: (i, 0)),
        ] + [full[0]] * (1 + want_c),
        out_shape=[
            jax.ShapeDtypeStruct((rows, k), jnp.int32),
            jax.ShapeDtypeStruct((rows, k), jnp.float32),
            jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        ] + [full[1]] * (1 + want_c),
        interpret=interpret,
    )(g.reshape(rows, block_size), e.reshape(rows, block_size), gamma,
      mask_self)
    idx, val, scale = outs[0], outs[1], outs[2]
    c = outs[3].reshape(-1) if want_c else None
    return idx, val, scale.reshape(-1), c, outs[-1].reshape(-1)


def _topk_decode_reduce_kernel(idx_ref, val_ref, scale_ref, mask_ref, out_ref,
                               *, k: int, n_senders: int):
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for i in range(n_senders):                                   # static loop
        sv = val_ref[i] * scale_ref[i]                           # (R, k)
        acc = acc + mask_ref[i] * _scatter_rows(idx_ref[i], sv, out_ref.shape)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_size", "interpret"))
def topk_decode_reduce(indices: jnp.ndarray, values: jnp.ndarray,
                       scales: jnp.ndarray, mask: jnp.ndarray,
                       block_size: int, interpret: bool = True) -> jnp.ndarray:
    """Server-side sparse decode + masked aggregate.
    indices: (N, rows, k) i32; values: (N, rows, k) f32;
    scales: (N, rows) f32; mask: (N,) f32 -> (rows * block_size,)."""
    N, rows, k = indices.shape
    if rows % R_BLK:
        raise ValueError(f"topk_decode_reduce needs rows % R_BLK == 0, got "
                         f"rows={rows}, R_BLK={R_BLK}")
    grid = (rows // R_BLK,)
    out = pl.pallas_call(
        functools.partial(_topk_decode_reduce_kernel, k=k, n_senders=N),
        grid=grid,
        in_specs=[
            pl.BlockSpec((N, R_BLK, k), lambda i: (0, i, 0)),
            pl.BlockSpec((N, R_BLK, k), lambda i: (0, i, 0)),
            pl.BlockSpec((N, R_BLK, 1), lambda i: (0, i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((R_BLK, block_size), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, block_size), jnp.float32),
        interpret=interpret,
    )(indices.astype(jnp.int32), values.astype(jnp.float32),
      scales.reshape(N, rows, 1).astype(jnp.float32), mask)
    return out.reshape(-1)
