"""Pallas TPU flash-style attention (VMEM-resident KV, q-block grid).

The gemma2 train cell's memory term is dominated by S x S score traffic
(EXPERIMENTS.md §Perf): XLA materializes the (B,S,H,S) score tensor in HBM
each pass.  This kernel keeps one q block + the full K/V of one kv-head in
VMEM and never writes scores to HBM:

  grid  (batch, q_heads, S // BLK_Q)
  q     block (1, 1, BLK_Q, hd)   VMEM
  k, v  block (1, 1, S, hd)       VMEM (kv-head = q_head // group)
  out   block (1, 1, BLK_Q, hd)   VMEM

Supports causal masking, sliding windows and gemma2's logit softcap.
VMEM budget limits S to ~8k at hd<=288 — the train_4k / smoke regime; the
32k prefill path stays on the XLA implementation.

Backward: custom_vjp recomputes attention per kv block in pure JAX
(repro.nn.layers chunked path) — fwd gets kernel speed, bwd is the
standard recompute strategy.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLK_Q = 256
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, softcap: float,
                  window: int, blk_q: int):
    i = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32)              # (blk_q, hd)
    k = k_ref[0, 0].astype(jnp.float32)              # (S, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    S = k.shape[0]
    q_pos = i * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, 1), 0)
    k_pos = jax.lax.broadcasted_iota(jnp.int32, (1, S), 1)

    s = q @ k.T                                      # (blk_q, S) — VMEM only
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    keep = (k_pos <= q_pos) & (k_pos > q_pos - window)
    s = jnp.where(keep, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = (p @ v) / jnp.maximum(l, 1e-30)
    o_ref[0, 0] = o.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("softcap", "window", "groups",
                                             "interpret"))
def flash_attention(q, k, v, *, softcap: float = 0.0, window: int = 0,
                    groups: int = 1, interpret: bool = True):
    """q: (B, H, S, hd) pre-scaled; k, v: (B, Hkv, S, hd) with
    H = groups * Hkv.  Causal (+ sliding window) attention output
    (B, H, S, hd)."""
    B, H, S, hd = q.shape
    w = window if window > 0 else (1 << 30)
    blk_q = min(BLK_Q, S)
    grid = (B, H, S // blk_q)
    return pl.pallas_call(
        functools.partial(_flash_kernel, softcap=softcap, window=w,
                          blk_q=blk_q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, blk_q, hd), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, S, hd),
                         lambda b, h, i, g=groups: (b, h // g, 0, 0)),
            pl.BlockSpec((1, 1, S, hd),
                         lambda b, h, i, g=groups: (b, h // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_q, hd),
                               lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
