"""Pallas TPU kernels for the COCO-EF wire format + oracles (ref.py)."""
from . import ops, ref  # noqa: F401
