"""Pallas TPU kernels for the sign wire format (pack / unpack-reduce).

These are the per-iteration hot spots of COCO-EF: every training step each
rank makes one pass over its model-sized accumulator to (a) compress it to
the wire format and (b) update the error vector.  Fusing the whole local
step (ef_sign_fused) turns three HBM round-trips (acc, C(acc), e') into one.

Tiling: the flat vector is processed as (rows of ROW_GROUPS groups) x
(group_size lanes).  group_size is a multiple of 128 (lane width) and 32
(bit-pack word), so every BlockSpec is MXU/VPU aligned:

  x block      (G_BLK, group)            f32   VMEM
  words block  (G_BLK, group // 32)      u32   VMEM
  scales block (G_BLK, 1)                f32   VMEM

On this CPU container the kernels run with interpret=True (pure-JAX
semantics) and are validated against kernels/ref.py; on real TPU the same
pallas_call lowers to Mosaic.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

G_BLK = 8  # groups per grid step


def _pack_block(x_blk):
    """x_blk: (G_BLK, group) f32 -> (words (G_BLK, group//32) u32,
    scales (G_BLK, 1) f32)."""
    g = x_blk.shape[-1]
    scales = jnp.mean(jnp.abs(x_blk), axis=-1, keepdims=True)     # (G,1)
    bits = (x_blk >= 0).reshape(G_BLK, g // 32, 32).astype(jnp.uint32)
    words = (bits << jnp.arange(32, dtype=jnp.uint32)).sum(
        -1, dtype=jnp.uint32)                                     # (G, g/32)
    return words, scales


def _sign_pack_kernel(x_ref, words_ref, scales_ref):
    words, scales = _pack_block(x_ref[...].astype(jnp.float32))
    words_ref[...] = words
    scales_ref[...] = scales


@functools.partial(jax.jit, static_argnames=("group_size", "interpret"))
def sign_pack(x: jnp.ndarray, group_size: int, interpret: bool = True
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (n,) f32, n % (G_BLK * group_size) == 0."""
    n = x.shape[0]
    if n % (G_BLK * group_size):
        raise ValueError(f"sign_pack needs n % (G_BLK*group_size) == 0, got "
                         f"n={n}, G_BLK={G_BLK}, group_size={group_size}")
    ng = n // group_size
    xg = x.reshape(ng, group_size)
    grid = (ng // G_BLK,)
    words, scales = pl.pallas_call(
        _sign_pack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((G_BLK, group_size), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((G_BLK, group_size // 32), lambda i: (i, 0)),
            pl.BlockSpec((G_BLK, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((ng, group_size // 32), jnp.uint32),
            jax.ShapeDtypeStruct((ng, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xg)
    return words.reshape(-1), scales.reshape(-1)


def _ef_fused_kernel(g_ref, e_ref, gamma_ref, mask_ref,
                     words_ref, scales_ref, *out_refs, want_c: bool):
    gamma = gamma_ref[0]
    mask = mask_ref[0]
    acc = gamma * g_ref[...].astype(jnp.float32) + e_ref[...].astype(jnp.float32)
    words, scales = _pack_block(acc)
    c = (jnp.where(acc >= 0, 1.0, -1.0) * scales)                  # (G, group)
    words_ref[...] = words
    scales_ref[...] = scales
    if want_c:
        out_refs[0][...] = c
    out_refs[-1][...] = jnp.where(mask > 0, acc - c,
                                  e_ref[...].astype(jnp.float32))


@functools.partial(jax.jit,
                   static_argnames=("group_size", "want_c", "interpret"))
def ef_sign_fused(g: jnp.ndarray, e: jnp.ndarray, gamma, mask_self,
                  group_size: int, want_c: bool = True,
                  interpret: bool = True):
    """Fused local COCO-EF step: one HBM pass over g/e producing the wire
    payload (words, scales), the decompressed C(acc) and the new error.
    g, e: (n,) f32; gamma, mask_self: scalars.  want_c=False skips the
    full-vector c store (the train path only ships the payload; a custom
    call's outputs are not DCE-able, so the skip must be explicit)."""
    n = g.shape[0]
    if n % (G_BLK * group_size):
        raise ValueError(f"ef_sign_fused needs n % (G_BLK*group_size) == 0, "
                         f"got n={n}, G_BLK={G_BLK}, group_size={group_size}")
    ng = n // group_size
    grid = (ng // G_BLK,)
    gamma = jnp.asarray(gamma, jnp.float32).reshape(1)
    mask_self = jnp.asarray(mask_self, jnp.float32).reshape(1)
    full = [pl.BlockSpec((G_BLK, group_size), lambda i: (i, 0)),
            jax.ShapeDtypeStruct((ng, group_size), jnp.float32)]
    outs = pl.pallas_call(
        functools.partial(_ef_fused_kernel, want_c=want_c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((G_BLK, group_size), lambda i: (i, 0)),
            pl.BlockSpec((G_BLK, group_size), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[
            pl.BlockSpec((G_BLK, group_size // 32), lambda i: (i, 0)),
            pl.BlockSpec((G_BLK, 1), lambda i: (i, 0)),
        ] + [full[0]] * (1 + want_c),
        out_shape=[
            jax.ShapeDtypeStruct((ng, group_size // 32), jnp.uint32),
            jax.ShapeDtypeStruct((ng, 1), jnp.float32),
        ] + [full[1]] * (1 + want_c),
        interpret=interpret,
    )(g.reshape(ng, group_size), e.reshape(ng, group_size), gamma, mask_self)
    words, scales = outs[0], outs[1]
    c = outs[2].reshape(-1) if want_c else None
    return words.reshape(-1), scales.reshape(-1), c, outs[-1].reshape(-1)


def _decode_reduce_kernel(words_ref, scales_ref, mask_ref, out_ref,
                          *, group_size: int, n_senders: int):
    acc = jnp.zeros(out_ref.shape, jnp.float32)                    # (G, group)
    for i in range(n_senders):                                     # static loop
        w = words_ref[i]                                           # (G, g/32)
        s = scales_ref[i]                                          # (G, 1)
        bits = (w[..., None] >> jnp.arange(32, dtype=jnp.uint32)) & jnp.uint32(1)
        signs = bits.astype(jnp.float32).reshape(out_ref.shape) * 2.0 - 1.0
        acc = acc + mask_ref[i] * signs * s
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("group_size", "interpret"))
def sign_decode_reduce(words: jnp.ndarray, scales: jnp.ndarray,
                       mask: jnp.ndarray, group_size: int,
                       interpret: bool = True) -> jnp.ndarray:
    """Server-side decode + masked aggregate.
    words: (N, n/32) u32; scales: (N, n/g) f32; mask: (N,) f32 -> (n,)."""
    N = words.shape[0]
    n = words.shape[1] * 32
    if n % (G_BLK * group_size):
        raise ValueError(f"sign_decode_reduce needs n % (G_BLK*group_size) "
                         f"== 0, got n={n}, G_BLK={G_BLK}, "
                         f"group_size={group_size}")
    ng = n // group_size
    grid = (ng // G_BLK,)
    out = pl.pallas_call(
        functools.partial(_decode_reduce_kernel, group_size=group_size,
                          n_senders=N),
        grid=grid,
        in_specs=[
            pl.BlockSpec((N, G_BLK, group_size // 32), lambda i: (0, i, 0)),
            pl.BlockSpec((N, G_BLK, 1), lambda i: (0, i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((G_BLK, group_size), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ng, group_size), jnp.float32),
        interpret=interpret,
    )(words.reshape(N, ng, group_size // 32),
      scales.reshape(N, ng, 1), mask)
    return out.reshape(-1)
