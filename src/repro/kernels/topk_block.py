"""Pallas TPU kernels: block-select top-k (threshold search, sort-free).

`block_select` is THE in-kernel selection primitive for the sparse-wire
kernels (`topk_pack`, `ef_topk_fused`, and the `block_topk` sparsifier
here).  Instead of k rounds of (row-max, argmax) over the whole block —
whose vector-reduction count grows linearly in k — or `lax.top_k`'s full
sort (which Mosaic cannot lower inside a kernel body anyway), it

  1. binary-searches the k-th largest |x| BIT PATTERN per row: IEEE f32
     magnitudes compare exactly like their int32 bit patterns, so 31
     monotone halving steps on [0, block_max_bits + 1] find the threshold
     exactly — denormals, zeros and duplicate values included;
  2. cuts threshold ties by first-occurrence rank (a lane prefix sum), so
     the selected SET matches `lax.top_k` on |x| bit-for-bit;
  3. compacts the k survivors into slots in position order (prefix sum +
     per-slot one-hot reductions) and orders the k slots by
     (magnitude desc, position asc) with a k-round argmax over k lanes —
     k*k lane work where the old loop paid k*block_size.

Everything is plain VPU-friendly jnp — compares, where, sum/max
reductions, static lane shifts via concatenate, `lax.fori_loop` — so the
same function runs inside Pallas kernel bodies (Mosaic on TPU, interpret
mode here) and as a host-traceable reference.  Tie-breaking matches
kernels/ref.py / `lax.top_k` exactly (first occurrence wins), which is
what the reference-vs-mesh parity gate demands of every payload.

The full-sort perf story on CPU lives in kernels/topk_fast.py (the jnp
hot path); this module is the TPU/in-kernel side of ROADMAP open item 3.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

R_BLK = 8  # rows (blocks) per grid step


def _cumsum_lanes(x: jnp.ndarray) -> jnp.ndarray:
    """Inclusive prefix sum along the last (lane) axis via log2 doubling.

    Static shift-and-add only (concatenate of a zero slab + a lane slice),
    because `jnp.cumsum` lowers to a serial loop / reduce_window that
    Mosaic does not support inside kernel bodies."""
    B = x.shape[-1]
    shift = 1
    while shift < B:
        z = jnp.zeros(x.shape[:-1] + (shift,), x.dtype)
        x = x + jnp.concatenate([z, x[..., :B - shift]], axis=-1)
        shift *= 2
    return x


def block_select_mask(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """(R, B) -> boolean keep-mask of each row's k largest-|.| entries,
    first occurrence winning magnitude ties (the `lax.top_k` set).

    Per-row threshold refinement: the binary search below maintains
    count(bits >= lo) >= k > count(bits >= hi), seeded by the block max
    (hi = max_bits + 1, lo = 0); 31 steps cover the full non-negative f32
    bit range, so `lo` lands exactly on the k-th largest magnitude's bit
    pattern.  Ties at the threshold are cut by first-occurrence rank."""
    if not 0 < k <= x.shape[-1]:
        raise ValueError(f"need 0 < k <= block width, got {k} / {x.shape[-1]}")
    mag = jnp.abs(x)
    # non-negative IEEE floats order like their int32 bit patterns
    bits = lax.bitcast_convert_type(mag, jnp.int32)

    def body(_, lohi):
        lo, hi = lohi
        mid = lo + (hi - lo) // 2
        ge = jnp.sum((bits >= mid).astype(jnp.int32), axis=-1, keepdims=True)
        take = ge >= k
        return jnp.where(take, mid, lo), jnp.where(take, hi, mid)

    lo0 = jnp.zeros(x.shape[:-1] + (1,), jnp.int32)
    hi0 = jnp.max(bits, axis=-1, keepdims=True) + 1
    thr, _ = lax.fori_loop(0, 31, body, (lo0, hi0))

    gt = bits > thr
    eq = bits == thr
    n_gt = jnp.sum(gt.astype(jnp.int32), axis=-1, keepdims=True)
    tie_rank = _cumsum_lanes(eq.astype(jnp.int32))      # 1-based among ties
    return gt | (eq & (tie_rank <= k - n_gt))


def block_select(x: jnp.ndarray, k: int):
    """x: (R, B) f32 -> (idx (R, k) i32, sval (R, k) f32, scale (R, 1) f32).

    Exact block top-|.|-k; indices in decreasing-magnitude order, first
    occurrence wins ties — elementwise identical to `lax.top_k` on |x|
    (and to kernels/ref.topk_pack_ref's selection).  sval are the SIGNED
    kept values, scale is the per-row max |x|."""
    R, B = x.shape
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    sel = block_select_mask(x, k)
    pos = lax.broadcasted_iota(jnp.int32, (R, B), 1)

    # compact the k survivors into slots, in position order
    slot = _cumsum_lanes(sel.astype(jnp.int32)) - 1     # 0-based among kept
    idx_cols, val_cols = [], []
    for j in range(k):                                  # static unrolled
        oh = sel & (slot == j)
        idx_cols.append(jnp.sum(jnp.where(oh, pos, 0), axis=-1,
                                keepdims=True))
        val_cols.append(jnp.sum(jnp.where(oh, x, 0.0), axis=-1,
                                keepdims=True))
    idx_c = jnp.concatenate(idx_cols, axis=-1)          # (R, k), pos asc
    val_c = jnp.concatenate(val_cols, axis=-1)

    # order the k slots by (magnitude desc, position asc): slots are
    # already position-ascending, so first-slot-wins == lax.top_k ties.
    # k rounds over k lanes — negligible next to the B-lane stages above.
    cbits = lax.bitcast_convert_type(jnp.abs(val_c), jnp.int32)
    spos = lax.broadcasted_iota(jnp.int32, (R, k), 1)
    avail = jnp.ones((R, k), jnp.bool_)
    idx_cols, val_cols = [], []
    for _ in range(k):
        m = jnp.where(avail, cbits, -1)
        row_max = jnp.max(m, axis=-1, keepdims=True)
        first = jnp.min(jnp.where((m == row_max) & avail, spos, k),
                        axis=-1, keepdims=True)
        take = spos == first
        idx_cols.append(jnp.sum(jnp.where(take, idx_c, 0), axis=-1,
                                keepdims=True))
        val_cols.append(jnp.sum(jnp.where(take, val_c, 0.0), axis=-1,
                                keepdims=True))
        avail = avail & ~take
    return (jnp.concatenate(idx_cols, axis=-1),
            jnp.concatenate(val_cols, axis=-1), scale)


def _topk_kernel(x_ref, o_ref, *, k: int):
    x = x_ref[...].astype(jnp.float32)          # (R, B)
    keep = block_select_mask(x, k)
    o_ref[...] = jnp.where(keep, x, 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("k", "block_size", "interpret"))
def block_topk(x: jnp.ndarray, k: int, block_size: int,
               interpret: bool = True) -> jnp.ndarray:
    """x: (n,) with n % (R_BLK * block_size) == 0 -> sparsified (n,)."""
    n = x.shape[0]
    rows = n // block_size
    out = pl.pallas_call(
        functools.partial(_topk_kernel, k=k),
        grid=(rows // R_BLK,),
        in_specs=[pl.BlockSpec((R_BLK, block_size), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((R_BLK, block_size), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, block_size), x.dtype),
        interpret=interpret,
    )(x.reshape(rows, block_size))
    return out.reshape(-1)
