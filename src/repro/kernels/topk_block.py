"""Pallas TPU kernel: block-local top-k sparsification.

TPU adaptation of top-K (DESIGN.md Sec. 2): instead of a global sort, keep
the k largest-magnitude entries per contiguous block.  The kernel runs k
rounds of (row-max |x| over unselected, mark argmax) — pure VPU work with
no sort, k is small (8-32).  Tie-breaking matches ref.py (first occurrence
wins via position penalty).

  x block (R_BLK, block_size) f32 VMEM -> same-shape sparsified output.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

R_BLK = 8  # rows (blocks) per grid step


def _topk_kernel(x_ref, o_ref, *, k: int):
    x = x_ref[...].astype(jnp.float32)          # (R, B)
    B = x.shape[-1]
    mag = jnp.abs(x)
    pos = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    keep = jnp.zeros(x.shape, jnp.bool_)
    avail = jnp.ones(x.shape, jnp.bool_)
    for _ in range(k):                          # static unrolled rounds
        m = jnp.where(avail, mag, -1.0)
        row_max = jnp.max(m, axis=-1, keepdims=True)
        # first position achieving the max
        is_max = (m == row_max) & avail
        first = jnp.min(jnp.where(is_max, pos, B), axis=-1, keepdims=True)
        sel = pos == first
        keep = keep | sel
        avail = avail & ~sel
    o_ref[...] = jnp.where(keep, x, 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("k", "block_size", "interpret"))
def block_topk(x: jnp.ndarray, k: int, block_size: int,
               interpret: bool = True) -> jnp.ndarray:
    """x: (n,) with n % (R_BLK * block_size) == 0 -> sparsified (n,)."""
    n = x.shape[0]
    rows = n // block_size
    out = pl.pallas_call(
        functools.partial(_topk_kernel, k=k),
        grid=(rows // R_BLK,),
        in_specs=[pl.BlockSpec((R_BLK, block_size), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((R_BLK, block_size), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, block_size), x.dtype),
        interpret=interpret,
    )(x.reshape(rows, block_size))
    return out.reshape(-1)
