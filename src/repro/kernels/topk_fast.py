"""Fast jnp hot path for the sparse wire: `lax.top_k` plus the fusion barrier.

THE perf bug this module fixes (jax 0.4.37, XLA:CPU — the backend both CI
and the bench host run): `lax.top_k` itself is cheap (~13 ms at n=1M,
K=16, B=512), but when its outputs are consumed inside the surrounding
fusion XLA re-materializes the sort once per consumer fusion.  The fused
EF local step traced at ~214 ms against ~18 ms of actual stage work — an
order-of-magnitude pathology that left `ef_topk_local_step` benching at
1.03x fused-over-unfused and made the fusion look useless.  Pinning an
`optimization_barrier` IMMEDIATELY AFTER the top_k forces a single
materialization of (values, indices) that every consumer then reads:
214 ms -> ~13 ms on the same input.  A barrier placed before the top_k
does nothing; the placement is the whole fix.

The barrier changes no values — every function here is bit-for-bit equal
to its kernels/ref.py counterpart, which deliberately stays barrier-free
as the semantic oracle.  `kernels.ops` dispatches the jnp backend here;
the Pallas kernels (topk_pack.py / topk_block.block_select) cover the
in-kernel TPU side with a sort-free threshold search.

Quantized-transmission semantics (`value_dtype`): the fused step emits
`val` as float32 holding value_dtype-ROUNDED numbers and builds `c` from
`val * scale` — exactly what a receiver reconstructs from the wire — so
the error update `e_new = acc - c` tracks the transmitted compression and
callers no longer need an unpack-of-pack round trip per bucket.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.kernels.ref import mul_add


def _barrier_top_k(mag: jnp.ndarray, k: int):
    """`lax.top_k` with the consumer-fusion barrier pinned on its outputs.

    One barrier per output, NOT `optimization_barrier((topv, idx))`: XLA's
    TupleSimplifier rewrites barrier(tuple(gte, gte)) into a barrier that
    consumes the TopK op directly, which crashes TopkDecomposer on the
    multi-device CPU path (it requires every TopK user to be a
    get-tuple-element)."""
    topv, idx = lax.top_k(mag, k)
    return lax.optimization_barrier(topv), lax.optimization_barrier(idx)


def topk_pack_fast(x: jnp.ndarray, k: int, block_size: int):
    """Bit-for-bit `ref.topk_pack_ref`, minus the re-run-the-sort fusions."""
    blocks = x.astype(jnp.float32).reshape(-1, block_size)
    topv, idx = _barrier_top_k(jnp.abs(blocks), k)
    sv = jnp.take_along_axis(blocks, idx, axis=-1)
    scale = topv[:, 0]
    safe = jnp.where(scale == 0.0, 1.0, scale)
    return idx.astype(jnp.int32), sv / safe[:, None], safe


def _scatter_blocks(idx: jnp.ndarray, sv: jnp.ndarray, rows: int,
                    block_size: int) -> jnp.ndarray:
    """Dense (rows*block_size,) with sv at per-block idx; `.at[].set` over
    a flat index — ~2x faster than a K-term where-accumulate on CPU."""
    base = jnp.arange(rows, dtype=jnp.int32)[:, None] * block_size
    flat_idx = (base + idx).reshape(-1)
    return jnp.zeros((rows * block_size,), jnp.float32).at[flat_idx].set(
        sv.reshape(-1))


def ef_topk_fused_fast(g: jnp.ndarray, e: jnp.ndarray, gamma, mask_self,
                       k: int, block_size: int,
                       value_dtype: str = "float32", want_c: bool = True):
    """Fused EF top-k local step, bit-for-bit `ref.ef_topk_fused_ref`.

    Returns (idx (R,k) i32, val (R,k) f32 value_dtype-rounded, scale (R,),
    c (n,) f32 or None, e_new (n,) f32) — `c` is the TRANSMITTED
    reconstruction (normalize -> value_dtype -> denormalize), so
    `c + e_new == acc` holds bit-exactly at kept coordinates (Sterbenz:
    c is within a factor of two of acc there, making `acc - c` exact)."""
    acc = mul_add(gamma, g, e)
    rows = acc.shape[0] // block_size
    accb = acc.reshape(rows, block_size)
    topv, idx = _barrier_top_k(jnp.abs(accb), k)
    sv = jnp.take_along_axis(accb, idx, axis=-1)
    scale = topv[:, 0]
    safe = jnp.where(scale == 0.0, 1.0, scale)
    val = (sv / safe[:, None]).astype(jnp.dtype(value_dtype)).astype(
        jnp.float32)
    c = _scatter_blocks(idx, val * safe[:, None], rows, block_size)
    e_new = jnp.where(mask_self > 0, acc - c, e)
    return (idx.astype(jnp.int32), val, safe, c if want_c else None, e_new)
