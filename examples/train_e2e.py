"""End-to-end driver: distributed COCO-EF training of a transformer LM with
checkpoint/restart, on whatever devices exist (CPU: set device count below).

Demonstrates the full production path: mesh -> sharding rules -> stage-1
coded gradients -> stage-2 wire-compressed aggregation -> server update ->
checkpoint -> crash-resume.

  PYTHONPATH=src python examples/train_e2e.py [--steps 60]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import REGISTRY
from repro.configs.common import ShapeCfg
from repro.core.plan import PLAN_SCHEMA, PlanSpec
from repro.launch.train import (TrainRun, batch_stream, build_train_setup,
                                elastic_coding_state)

PLAN_N_WIRE = 1 << 16     # flat size the auto-planner prices wires at
                          # (matches the --rank-uplink-gbps budget solve)


def _load_plan(path: str) -> PlanSpec:
    """A saved plan: either a bare PlanSpec JSON (PlanSpec.save) or a
    planner emission whose "plan" field carries the winning spec."""
    obj = json.loads(Path(path).read_text())
    if isinstance(obj, dict) and obj.get("schema") != PLAN_SCHEMA \
            and "plan" in obj:
        obj = obj["plan"]
    return PlanSpec.from_dict(obj)


def _auto_plan(args, spec, n_code, trace_path, plan_out):
    """`--plan auto`: run the three-stage sim planner over THIS run's
    straggler profile, print the ranking, persist the emission (winner +
    ranking + provenance, CI schema-validates it), return the winner."""
    import sys
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]
                           / "benchmarks"))
    from _repro_common import run_metadata
    from repro.sim import get_straggler_process, plan_search
    p = spec.coding.straggler_p
    if args.straggler != "iid" or p > 0:
        proc = get_straggler_process(
            args.straggler, n_code, p, mean_burst=args.straggler_burst,
            spread=args.straggler_spread, trace=trace_path)
        res = plan_search(PLAN_N_WIRE, process=proc,
                          confirm_steps=120, seed=0)
    else:       # fully reliable fleet: rates-only search, no masks to sim
        res = plan_search(PLAN_N_WIRE, rates=np.ones((n_code,)),
                          confirm_steps=120, seed=0)
    print(f"planner: {res.num_enumerated} candidates -> "
          f"{res.pruned_to} confirmed; ranking:")
    for c in res.candidates[:res.pruned_to]:
        t2t = (f"{c.sim_time_to_target_s:.3f}s"
               if c.sim_time_to_target_s is not None else "never")
        print(f"  d={c.plan.d} {c.plan.compressor:10s} "
              f"alloc={c.plan.allocation:10s} score={c.score:.4f} "
              f"sim-t2t={t2t}")
    emission = {**res.to_dict(),
                "plan": res.best.plan.to_dict(),
                "meta": run_metadata(
                    arch=args.arch, straggler=args.straggler,
                    straggler_p=p, n_code=n_code, n_wire=PLAN_N_WIRE)}
    Path(plan_out).parent.mkdir(parents=True, exist_ok=True)
    Path(plan_out).write_text(json.dumps(emission, indent=1) + "\n")
    print(f"plan emission -> {plan_out}")
    return res.best.plan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-1b-7b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--compressor", default="sign",
                    choices=["sign", "block_topk", "topk", "identity"],
                    help="phase-1 wire compressor (WireFormat selection)")
    ap.add_argument("--num-buckets", type=int, default=1,
                    help="flat-vector buckets for comm overlap")
    ap.add_argument("--bucket-schedule", default="pipelined",
                    choices=["pipelined", "serial"],
                    help="per-bucket collective issue order: pipelined "
                         "double-buffers so bucket i's wire transfer "
                         "overlaps bucket i+1's compression (bit-for-bit "
                         "equal to serial)")
    ap.add_argument("--prefetch", type=int, default=0,
                    help="host->device batches staged ahead of the step "
                         "by a background thread (0 = synchronous; opt-in "
                         "on CPU fake devices, can race the in-process "
                         "collective rendezvous)")
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "pallas", "jnp"],
                    help="fused-kernel dispatch for the wire hot path "
                         "(auto = Pallas on TPU, jnp reference elsewhere)")
    def _prob(s):
        v = float(s)
        if not 0.0 <= v < 1.0:
            raise argparse.ArgumentTypeError(
                f"straggle probability {v} must be in [0, 1)")
        return v

    ap.add_argument("--straggler", default="iid",
                    choices=["iid", "markov", "hetero", "trace"],
                    help="straggler process driving the per-step "
                         "participation masks (repro.sim)")
    ap.add_argument("--straggler-p", type=_prob, default=None,
                    help="override the arch's Bernoulli/stationary "
                         "straggle probability (in [0, 1))")
    ap.add_argument("--straggler-burst", type=float, default=8.0,
                    help="markov: mean slow-burst length in steps (>= 1)")
    ap.add_argument("--straggler-spread", type=float, default=0.5,
                    help="hetero: per-rank p_i in p*(1 +/- spread), every "
                         "p_i must land in [0, 1)")
    ap.add_argument("--straggler-trace", default=None,
                    help="recorded-mask JSON for --straggler trace "
                         "(default: synthesize a bursty trace and save it)")
    ap.add_argument("--elastic", action="store_true",
                    help="dynamic coding plane: a live CodingState (rate "
                         "estimates + encode weights) rides the jitted "
                         "step as a donated argument; masks observed on "
                         "the host feed an online RateEstimator, drift "
                         "past --replan-threshold regenerates the "
                         "allocation mid-run (epoch bump, no retrace)")
    ap.add_argument("--replan-threshold", type=float, default=0.1,
                    help="elastic: max |q_est - q_planned| tolerated "
                         "before rate_aware_allocation is re-run")
    ap.add_argument("--mean-rate-coding", action="store_true",
                    help="encode weights from the scalar mean rate p "
                         "(paper eq. 3) instead of the per-rank rates "
                         "q_i of the straggler process (rate-aware, "
                         "unbiased under non-iid stragglers; the default)")
    ap.add_argument("--rank-uplink-gbps", default=None,
                    help="comma-separated per-coding-rank uplink Gbit/s; "
                         "with --compressor block_topk, solves equal-time "
                         "per-rank wire budgets (sim.solve_k_budgets) so "
                         "slow-uplink ranks send fewer coords per block")
    ap.add_argument("--plan", default=None,
                    help="'auto' runs the sim planner (enumerate -> "
                         "analytic prune -> simulated confirm) over this "
                         "run's straggler profile, prints the ranking, and "
                         "trains the winner; a path loads a saved PlanSpec "
                         "JSON (PlanSpec.save or a planner emission). "
                         "Overrides --compressor/--num-buckets/"
                         "--bucket-schedule/--backend")
    ap.add_argument("--plan-out", default="/tmp/repro_e2e_plan.json",
                    help="where --plan auto writes the winner + ranking + "
                         "run_metadata provenance JSON")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    ap.add_argument("--metrics", action="store_true",
                    help="step-level telemetry (repro.obs): in-graph "
                         "MetricsFrame -> JSONL metrics + a Chrome trace "
                         "of measured host spans alongside the StepTimer-"
                         "PREDICTED schedule for the observed masks")
    ap.add_argument("--metrics-dir", default="/tmp/repro_e2e_metrics",
                    help="where --metrics writes metrics.jsonl + trace.json")
    args = ap.parse_args()

    from repro.compat import make_mesh
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    shape = ShapeCfg("train", seq_len=64, global_batch=16)
    spec = REGISTRY[args.arch]
    coding_over = dict(group_size=32, block_size=64, k_per_block=8)
    if args.straggler_p is not None:
        coding_over["straggler_p"] = args.straggler_p
    spec = dataclasses.replace(
        spec, coding=dataclasses.replace(spec.coding, **coding_over))

    trace_path = args.straggler_trace
    if args.straggler == "trace" and trace_path is None:
        # synthesize a bursty incident trace for the demo and replay it
        from repro.sim import MarkovBursty, TraceReplay
        n_code = 4    # pod x data of the mesh below
        p = spec.coding.straggler_p
        if args.straggler_p is None and p == 0:
            p = 0.2   # demo default; an explicit --straggler-p 0.0 stands
        proc = MarkovBursty(num_devices=n_code, p=p, mean_burst=6.0)
        trace = TraceReplay.from_array(
            proc.sample_trace(jax.random.PRNGKey(42), 128))
        trace_path = str(trace.to_json("/tmp/repro_e2e_trace.json"))
        print(f"synthesized bursty trace -> {trace_path}")

    k_budgets = None
    if args.rank_uplink_gbps:
        if args.compressor != "block_topk":
            ap.error("--rank-uplink-gbps needs --compressor block_topk "
                     "(per-rank budgets ride the sparse wire)")
        from repro.sim import LinkProfile, solve_k_budgets
        bws = tuple(float(b) for b in args.rank_uplink_gbps.split(","))
        link = LinkProfile(rank_bandwidth_gbps=bws)
        k_budgets = solve_k_budgets(
            1 << 16, len(bws), link, block_size=spec.coding.block_size,
            k_ref=spec.coding.k_per_block)
        print(f"per-rank wire budgets (equal-time): k={k_budgets} for "
              f"uplinks {bws} Gbit/s")

    plan = None
    if args.plan:
        if k_budgets is not None:
            ap.error("--rank-uplink-gbps solves k_budgets, which conflicts "
                     "with an explicit --plan (per-rank budgets live in "
                     "the plan's k_per_block)")
        axis = dict(zip(mesh.axis_names, mesh.devices.shape))
        n_code = int(np.prod([axis[a] for a in spec.coding.coding_axes
                              if a in axis])) or 1
        if args.plan == "auto":
            plan = _auto_plan(args, spec, n_code, trace_path, args.plan_out)
        else:
            plan = _load_plan(args.plan)
        print(f"plan: d={plan.d} compressor={plan.compressor} "
              f"alloc={plan.allocation} buckets={plan.num_buckets} "
              f"({plan.bucket_schedule})")

    # with a plan, the ONE PlanSpec replaces the wire/bucket alias knobs
    # (TrainRun rejects mixing them)
    wire_kw = (dict(plan=plan) if plan is not None else
               dict(compressor=args.compressor,
                    num_buckets=args.num_buckets,
                    bucket_schedule=args.bucket_schedule,
                    backend=args.backend,
                    k_budgets=k_budgets))
    try:
        run = TrainRun(base_lr=5e-3, mode="cocoef",
                       prefetch=args.prefetch,
                       straggler=args.straggler,
                       straggler_burst=args.straggler_burst,
                       straggler_spread=args.straggler_spread,
                       straggler_trace=trace_path,
                       rate_aware=not args.mean_rate_coding,
                       elastic=args.elastic,
                       replan_threshold=args.replan_threshold,
                       metrics=args.metrics, **wire_kw)
        setup = build_train_setup(spec, mesh, shape, run, smoke=True)
    except ValueError as e:        # bad straggler/coding knobs fail HERE,
        ap.error(str(e))           # not as NaNs deep inside jit
    proc = setup.straggler_process
    rates = setup.cocoef_cfg.straggler_rates
    print(f"arch={args.arch} coding ranks={setup.n_code} "
          f"per-rank batch={setup.b_loc} local flat={setup.flat_pad} "
          f"straggler={type(proc).__name__ if proc else 'none'} "
          f"coding={'rate-aware q_i' if rates is not None else 'mean-rate p'}")

    estimator = state = None
    if args.elastic:
        from repro.core.coding_state import RateEstimator
        estimator = RateEstimator(setup.n_code)
        state, _ = elastic_coding_state(setup)   # epoch 0: planned rates
        print(f"elastic coding plane: replan threshold "
              f"{args.replan_threshold}, epoch 0 rates "
              f"{[round(float(x), 3) for x in state.rates_estimate]}")

    key = jax.random.PRNGKey(0)
    params, e, opt = setup.init_state(key)
    start = 0
    if latest_step(args.ckpt_dir) is not None:
        start, st = restore_checkpoint(
            args.ckpt_dir, {"params": params, "e": e},
            shardings={"params": setup.param_shardings})
        params, e = st["params"], st["e"]
        print(f"resumed from step {start}")

    logger = rec = None
    masks = []
    if args.metrics:
        import sys
        sys.path.insert(0, str(Path(__file__).resolve().parents[1]
                               / "benchmarks"))
        from _repro_common import run_metadata
        from repro.obs import MetricsLogger, SpanRecorder, frame_to_host
        mdir = Path(args.metrics_dir)
        meta = run_metadata(
            arch=args.arch, steps=args.steps, seed=run.seed,
            mode=run.mode, compressor=setup.plan.compressor,
            num_buckets=setup.plan.num_buckets,
            bucket_schedule=setup.plan.bucket_schedule,
            backend_requested=setup.plan.backend,
            plan=setup.plan.to_dict(), straggler=args.straggler,
            straggler_p=spec.coding.straggler_p, prefetch=args.prefetch,
            rate_aware=run.rate_aware, n_code=setup.n_code,
            flat_pad=setup.flat_pad)
        logger = MetricsLogger(str(mdir / "metrics.jsonl"),
                               run_metadata=meta)
        rec = SpanRecorder()

    # elastic: coding_state is donated — every leaf is echoed through the
    # metrics dict, so XLA aliases the buffers for the next step's state
    jstep = jax.jit(setup.train_step, donate_argnums=(6,)) \
        if args.elastic else jax.jit(setup.train_step)
    # batches arrive device-resident, staged --prefetch steps ahead by the
    # background prefetcher while the mesh runs the current step
    batches = batch_stream(setup, spec, shape, key, start_step=start,
                           smoke=True, prefetch=run.prefetch)
    try:
        for t in range(start, args.steps):
            extra = (state,) if args.elastic else ()
            if rec is None:
                batch = next(batches)
                params, e, opt, m = jstep(params, e, opt, batch,
                                          jnp.int32(t), key, *extra)
            else:
                with rec.span("train/batch_wait", step=t):
                    batch = next(batches)
                if hasattr(batches, "stats"):
                    rec.counter("prefetch_depth", batches.stats.max_depth)
                with rec.span("train/step_dispatch", step=t):
                    params, e, opt, m = jstep(params, e, opt, batch,
                                              jnp.int32(t), key, *extra)
                with rec.span("train/result_fetch", step=t):
                    tel = frame_to_host(jax.device_get(m["telemetry"]))
                    loss = float(m["loss"])
                span_s = {s["name"]: s["t1"] - s["t0"]
                          for s in rec.spans[-3:]}
                logger.log_step(t, tel, loss=loss, spans=span_s)
                masks.append(tel["participation"])
            if args.elastic:
                # feed the plane: the mask the step just used is pure in
                # (key, t), so the host can observe it without telemetry
                obs = tel["participation"] if rec is not None else (
                    np.asarray(proc.mask(key, t)) if proc is not None
                    else np.ones((setup.n_code,)))
                estimator.update(obs)
                state, info = elastic_coding_state(setup, estimator.rates)
                if logger is not None:
                    logger.log_replan(t, info)
                if info["reallocated"]:
                    print(f"  replan @ step {t}: drift={info['drift']:.3f}"
                          f" -> allocation epoch {info['epoch']}")
            if t % 10 == 0 or t == args.steps - 1:
                print(f"step {t:4d} loss={float(m['loss']):.4f}")
            if (t + 1) % args.ckpt_every == 0:
                p = save_checkpoint(args.ckpt_dir, t + 1,
                                    {"params": params, "e": e})
                print(f"  checkpointed -> {p.name}")
    finally:
        if rec is not None and hasattr(batches, "stats"):
            logger.log_prefetch(batches.stats.snapshot())
        batches.close()     # stop + join the prefetch worker before exit

    if rec is not None:
        # Chrome trace: measured host spans (pid 0) + the StepTimer
        # PREDICTION for the same observed masks (pid 1) — open both in
        # chrome://tracing and compare lane by lane
        from repro.obs import span_events, steptimer_timeline, \
            write_chrome_trace
        from repro.sim import StepTimer
        # priced from setup.plan — the exact PlanSpec the step was built on
        wire = setup.plan.wire(setup.flat_pad // setup.plan.num_buckets, 1)
        timer = StepTimer(wire=wire, n=setup.flat_pad,
                          num_buckets=setup.plan.num_buckets,
                          overlap=setup.plan.overlap)
        sim_ev, sim_t = steptimer_timeline(
            timer, np.asarray(masks, np.float64), pid=1)
        events = span_events(rec.spans, pid=0, counters=rec.counters) \
            + sim_ev
        tpath = str(Path(args.metrics_dir) / "trace.json")
        write_chrome_trace(tpath, events, metadata=meta)
        logger.close()
        ew = logger.rates
        print(f"telemetry -> {logger.path} ({logger.steps_logged} steps); "
              f"trace -> {tpath}")
        print(f"EWMA participation rates: "
              f"{[round(float(x), 3) for x in ew]}")
        print(f"StepTimer-predicted mean step: {sim_t.mean()*1e3:.2f} ms "
              f"(simulated link; measured host spans in the trace)")


if __name__ == "__main__":
    main()
