"""Elastic scaling demo on the PRODUCTION path: train the transformer LM
through the mesh `cocoef_update` step on 4 coding ranks, checkpoint, then
resume on a SHRUNK mesh with 2 coding ranks.

Everything goes through the real pipeline — `build_train_setup`, the wire
compressor (`WireFormat`), the two-stage shard_map aggregation — not the
(N, D) reference EF loop.  Across the resize:

  * params restore against the NEW mesh's shardings (global shapes are
    mesh-independent),
  * the per-rank error vectors and optimizer state map through
    `checkpoint.elastic_rescale_ef`: surviving coding ranks keep their
    error, vanished ranks drop, the flat tail truncates/pads to the new
    local size (Theorem 1 is invariant to e_i^0 = 0 re-initialization),
  * the elastic coding plane resizes: `RateEstimator.resize` carries the
    survivors' rate statistics, and the fresh setup's `CodingPlan` plans
    the new fleet's allocation.

  PYTHONPATH=src python examples/elastic_restart.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (elastic_rescale_ef, restore_checkpoint,
                              save_checkpoint)
from repro.compat import make_mesh
from repro.configs import REGISTRY
from repro.configs.common import ShapeCfg
from repro.core.coding_state import RateEstimator
from repro.launch.train import (TrainRun, build_train_setup,
                                elastic_coding_state, make_batch_for_step)

CKPT = "/tmp/repro_elastic_restart"
STEPS_1, STEPS_2 = 10, 10


def build(mesh_shape):
    mesh = make_mesh(mesh_shape, ("pod", "data", "model"))
    shape = ShapeCfg("train", seq_len=64, global_batch=16)
    spec = REGISTRY["olmoe-1b-7b"]
    spec = dataclasses.replace(spec, coding=dataclasses.replace(
        spec.coding, group_size=32, block_size=64, k_per_block=8,
        straggler_p=0.25))
    run = TrainRun(base_lr=5e-3, mode="cocoef", compressor="sign",
                   straggler="hetero", elastic=True)
    return build_train_setup(spec, mesh, shape, run, smoke=True), spec, shape


def train(setup, spec, shape, params, e, opt, estimator, start, steps, key):
    jstep = jax.jit(setup.train_step, donate_argnums=(6,))
    state, _ = elastic_coding_state(setup, estimator.rates
                                    if estimator.steps_seen.any() else None)
    proc = setup.straggler_process
    loss = None
    for t in range(start, start + steps):
        batch = jax.device_put(
            make_batch_for_step(setup, spec, shape, key, t, smoke=True),
            setup.batch_shardings)
        params, e, opt, m = jstep(params, e, opt, batch, jnp.int32(t), key,
                                  state)
        estimator.update(np.asarray(proc.mask(key, t)))
        state, info = elastic_coding_state(setup, estimator.rates)
        loss = float(m["loss"])
        tag = f" (replan -> epoch {info['epoch']})" if info["reallocated"] \
            else ""
        print(f"  step {t:3d} loss={loss:.4f}{tag}")
    return params, e, opt, loss


def main():
    key = jax.random.PRNGKey(0)

    # ---- phase 1: full mesh (2, 2, 2) -> 4 coding ranks -------------------
    setup1, spec, shape = build((2, 2, 2))
    print(f"[phase 1] mesh (2,2,2): n_code={setup1.n_code} "
          f"local flat={setup1.flat_pad}")
    params, e, opt = setup1.init_state(key)
    est = RateEstimator(setup1.n_code)
    params, e, opt, loss1 = train(setup1, spec, shape, params, e, opt, est,
                                  0, STEPS_1, key)
    save_checkpoint(CKPT, STEPS_1, {"params": params, "e": e, "opt": opt})
    print(f"[phase 1] checkpointed at step {STEPS_1}, loss={loss1:.4f}")

    # ---- phase 2: cluster shrinks to (1, 2, 2) -> 2 coding ranks ----------
    setup2, spec, shape = build((1, 2, 2))
    print(f"[phase 2] mesh (1,2,2): n_code={setup2.n_code} "
          f"local flat={setup2.flat_pad}")
    p2, e2, o2 = setup2.init_state(key)          # templates for restore
    start, st = restore_checkpoint(
        CKPT, {"params": p2, "e": e, "opt": opt},
        shardings={"params": setup2.param_shardings})
    params = st["params"]
    # EF + optimizer state ride elastic_rescale_ef: coding ranks present in
    # both grids keep their slices, the rest start from zero
    mesh1, mesh2 = (2, 2, 2), (1, 2, 2)
    e = jax.device_put(
        jnp.asarray(elastic_rescale_ef(np.asarray(st["e"]), mesh1, mesh2,
                                       setup2.flat_pad),
                    e2.dtype), setup2.state_sharding)
    opt = tuple(jax.device_put(
        jnp.asarray(elastic_rescale_ef(np.asarray(o), mesh1, mesh2,
                                       setup2.flat_pad), jnp.float32),
        setup2.state_sharding) for o in st["opt"])
    est.resize(setup2.n_code)                    # survivors keep statistics
    params, e, opt, loss2 = train(setup2, spec, shape, params, e, opt, est,
                                  start, STEPS_2, key)
    print(f"[phase 2] step {start + STEPS_2} loss={loss2:.4f}  "
          f"(training continued through the resize; "
          f"phase-1 final loss was {loss1:.4f})")


if __name__ == "__main__":
    main()
