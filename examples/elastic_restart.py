"""Elastic scaling demo: train on N coding ranks, checkpoint, resume on a
DIFFERENT device count.  The pairwise-balanced allocation is regenerated,
surviving ranks keep their error vectors, new ranks start at e=0
(convergence is preserved — Theorem 1 holds for any e^0 = 0 subset).

  PYTHONPATH=src python examples/elastic_restart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import elastic_rescale_ef
from repro.core import coding, compression as C, error_feedback as EF
from repro.data.tasks import linreg_task

grad_fn, loss_fn, theta0, _ = linreg_task(seed=0)
key = jax.random.PRNGKey(42)

# phase 1: 100 devices
N1 = 100
alloc1 = coding.random_allocation(0, N1, 100, d=5)
W1 = coding.encode_weights(alloc1, p=0.2)
st = EF.EFState.init(theta0, N1)
for t in range(150):
    mask = coding.straggler_mask(key, t, N1, 0.2)
    st = EF.cocoef_step(st, grad_fn, W1, mask, 1e-5, C.GroupedSign(), step=t)
print(f"[N=100] step 150 loss = {float(loss_fn(st.theta)):.1f}")

# cluster shrinks to 60 devices: regenerate allocation, carry EF for the
# surviving ranks (first 60), drop the rest
N2 = 60
alloc2 = coding.random_allocation(1, N2, 100, d=5)
W2 = coding.encode_weights(alloc2, p=0.2)
e2 = np.asarray(elastic_rescale_ef(np.asarray(st.e)[:, None, :],
                                   (N1, 1), (N2, 1), st.e.shape[-1]))[:, 0]
st = EF.EFState(theta=st.theta, e=jnp.asarray(e2))
for t in range(150, 400):
    mask = coding.straggler_mask(key, t, N2, 0.2)
    st = EF.cocoef_step(st, grad_fn, W2, mask, 1e-5, C.GroupedSign(), step=t)
print(f"[N=60 ] step 400 loss = {float(loss_fn(st.theta)):.1f}  "
      f"(training continued through the resize)")
