"""Quickstart: COCO-EF on the paper's linear-regression task (Sec. V.A).

Runs the proposed method next to the 1-bit unbiased baseline [32] at equal
communication overhead and prints the loss trajectory — the Fig. 2 claim
in 30 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import coding, compression as C, error_feedback as EF
from repro.data.tasks import linreg_task

grad_fn, loss_fn, theta0, _ = linreg_task(seed=0)
N = M = 100
alloc = coding.random_allocation(seed=0, num_devices=N, num_subsets=M, d=5)
W = coding.encode_weights(alloc, p=0.2)
key = jax.random.PRNGKey(42)

runs = {
    "COCO-EF (Sign)  [proposed]": (EF.cocoef_step, C.GroupedSign(), 1e-5, False),
    "Unbiased (Sign) [baseline]": (EF.unbiased_step, C.StochasticSign(), 2e-6, True),
}
for name, (step_fn, comp, lr, needs_key) in runs.items():
    st = EF.EFState.init(theta0, N)
    print(f"\n{name}  (1 bit/coordinate on the wire)")
    for t in range(301):
        mask = coding.straggler_mask(key, t, N, p=0.2)   # 20% stragglers
        kk = jax.random.fold_in(jax.random.PRNGKey(7), t) if needs_key else None
        st = step_fn(st, grad_fn, W, mask, lr, comp, step=t, key=kk)
        if t % 60 == 0:
            print(f"  step {t:4d}  F(theta) = {float(loss_fn(st.theta)):12.1f}")
