"""Batched serving: prefill a batch of prompts, then decode new tokens with
TP-sharded KV caches — the inference path the decode/prefill dry-run cells
exercise at production scale.

  PYTHONPATH=src python examples/serve_batched.py [--arch phi3-medium-14b]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY
from repro.configs.common import ShapeCfg
from repro.launch.serve import build_serve_setup


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-medium-14b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--metrics", action="store_true",
                    help="serve-plane telemetry (repro.obs): per-request "
                         "queue wait + prefill/decode p50/p99 histograms, "
                         "JSONL records and a Chrome trace")
    ap.add_argument("--requests", type=int, default=4,
                    help="--metrics: simulated request arrivals served "
                         "sequentially (queue wait = service start - "
                         "arrival)")
    ap.add_argument("--metrics-dir", default="/tmp/repro_serve_metrics",
                    help="where --metrics writes serve.jsonl + trace.json")
    args = ap.parse_args()

    from repro.compat import make_mesh
    mesh = make_mesh((4, 2), ("data", "model"))
    # cache sized for prompt + generation
    total = args.prompt_len + args.new_tokens
    shape = ShapeCfg("decode", seq_len=total, global_batch=args.batch)
    spec = REGISTRY[args.arch]
    setup = build_serve_setup(spec, mesh, shape, smoke=True)
    cfg = spec.smoke
    model = setup.model

    key = jax.random.PRNGKey(0)
    params = jax.jit(model.init, out_shardings=setup.param_shardings)(key)
    prompts = jax.random.randint(key, (args.batch, total), 0, cfg.vocab_size)

    # prefill by decoding the prompt into the cache (same kernels the
    # decode_32k cell lowers), then sample greedily.
    if args.metrics:
        serve_with_metrics(args, setup, params, prompts, total)
        return

    caches = model.init_caches(args.batch, total)
    caches = jax.device_put(caches, setup.cache_shardings)
    jdecode = jax.jit(setup.decode_step,
                      out_shardings=setup.decode_out_shardings)
    tok = prompts[:, :1]
    generated = []
    for t in range(total - 1):
        logits, caches = jdecode(params, caches, tok, jnp.int32(t))
        if t < args.prompt_len - 1:
            tok = prompts[:, t + 1:t + 2]          # teacher-forced prompt
        else:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            generated.append(tok)
    gen = jnp.concatenate(generated, 1)
    print(f"arch={args.arch} batch={args.batch} "
          f"prompt={args.prompt_len} generated={gen.shape[1]} tokens")
    print("sampled token ids:\n", gen)


def serve_with_metrics(args, setup, params, prompts, total):
    """Serve --requests sequential requests through the instrumented
    steps: each request decodes its prompt batch end to end; requests
    queue behind the one in service (queue wait = service start -
    arrival), the request-level view the serve_summary histograms and
    the Chrome trace report."""
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]
                           / "benchmarks"))
    from _repro_common import run_metadata
    from repro.launch.serve import instrument_steps
    from repro.obs import (MetricsLogger, ServeTelemetry, span_events,
                           write_chrome_trace)

    tel = ServeTelemetry()
    rec = tel.recorder
    _, decode = instrument_steps(setup, tel)
    model = setup.model

    arrival = rec.now()    # all requests arrive up front (a burst): the
    #                        k-th request's queue wait is the service time
    #                        of the k-1 ahead of it
    for rid in range(args.requests):
        start = rec.now()
        with rec.span("serve/request", tid="requests", request_id=rid):
            caches = jax.device_put(model.init_caches(args.batch, total),
                                    setup.cache_shardings)
            n_pref = len(tel.prefill_s)
            n_dec = len(tel.decode_token_s)
            tok = prompts[:, :1]
            gen_tokens = 0
            for t in range(total - 1):
                logits, caches = decode(params, caches, tok, jnp.int32(t))
                if t < args.prompt_len - 1:
                    tok = prompts[:, t + 1:t + 2]
                else:
                    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
                    gen_tokens += args.batch
        # the teacher-forced prompt pass is this request's "prefill",
        # the sampled steps its decode
        pref = sum(tel.decode_token_s[n_dec:n_dec + args.prompt_len - 1])
        dec = sum(tel.decode_token_s[n_dec + args.prompt_len - 1:])
        tel.add_prefill(pref)
        tel.add_request(rid, queue_wait_s=start - arrival,
                        prefill_s=pref, decode_s=dec, tokens=gen_tokens)

    mdir = Path(args.metrics_dir)
    meta = run_metadata(arch=args.arch, batch=args.batch,
                        prompt_len=args.prompt_len,
                        new_tokens=args.new_tokens,
                        requests=args.requests, path="serve")
    with MetricsLogger(str(mdir / "serve.jsonl"),
                       run_metadata=meta) as logger:
        tel.log_to(logger)
    tpath = str(mdir / "trace.json")
    write_chrome_trace(tpath, span_events(rec.spans, pid=0), metadata=meta)
    print(tel.format_summary())
    print(f"telemetry -> {mdir / 'serve.jsonl'}; trace -> {tpath}")


if __name__ == "__main__":
    main()
