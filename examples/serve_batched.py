"""Batched serving: prefill a batch of prompts, then decode new tokens with
TP-sharded KV caches — the inference path the decode/prefill dry-run cells
exercise at production scale.

  PYTHONPATH=src python examples/serve_batched.py [--arch phi3-medium-14b]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY
from repro.configs.common import ShapeCfg
from repro.launch.serve import build_serve_setup


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-medium-14b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    from repro.compat import make_mesh
    mesh = make_mesh((4, 2), ("data", "model"))
    # cache sized for prompt + generation
    total = args.prompt_len + args.new_tokens
    shape = ShapeCfg("decode", seq_len=total, global_batch=args.batch)
    spec = REGISTRY[args.arch]
    setup = build_serve_setup(spec, mesh, shape, smoke=True)
    cfg = spec.smoke
    model = setup.model

    key = jax.random.PRNGKey(0)
    params = jax.jit(model.init, out_shardings=setup.param_shardings)(key)
    prompts = jax.random.randint(key, (args.batch, total), 0, cfg.vocab_size)

    # prefill by decoding the prompt into the cache (same kernels the
    # decode_32k cell lowers), then sample greedily.
    caches = model.init_caches(args.batch, total)
    caches = jax.device_put(caches, setup.cache_shardings)
    jdecode = jax.jit(setup.decode_step,
                      out_shardings=setup.decode_out_shardings)
    tok = prompts[:, :1]
    generated = []
    for t in range(total - 1):
        logits, caches = jdecode(params, caches, tok, jnp.int32(t))
        if t < args.prompt_len - 1:
            tok = prompts[:, t + 1:t + 2]          # teacher-forced prompt
        else:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            generated.append(tok)
    gen = jnp.concatenate(generated, 1)
    print(f"arch={args.arch} batch={args.batch} "
          f"prompt={args.prompt_len} generated={gen.shape[1]} tokens")
    print("sampled token ids:\n", gen)


if __name__ == "__main__":
    main()
