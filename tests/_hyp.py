"""Optional-`hypothesis` shim for the property-based tests.

When hypothesis is installed, this re-exports the real `given`, `settings`
and `strategies` and the property tests run at full strength.  On a clean
CPU box without it, a deterministic fallback keeps the same tests
collectable and meaningful: each strategy degrades to a small fixed example
list and `@given` becomes a `pytest.mark.parametrize` over cycled
combinations — a handful of deterministic cases instead of randomized
search, never a skip.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False
    _FALLBACK_COMBOS = 6  # deterministic cases per property test

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            mid = (min_value + max_value) // 2
            vals = [min_value, mid, max_value, min_value + 1 if
                    min_value + 1 <= max_value else max_value]
            return list(dict.fromkeys(vals))

        @staticmethod
        def sampled_from(elements):
            return list(elements)

    st = _Strategies()

    def settings(**_kwargs):  # noqa: D103 — hypothesis-API stand-in
        return lambda fn: fn

    def given(**strategies):  # noqa: D103 — hypothesis-API stand-in
        names = list(strategies)
        pools = [list(strategies[n]) for n in names]
        count = max(max(len(p) for p in pools), _FALLBACK_COMBOS)
        combos = [tuple(pool[i % len(pool)] for pool in pools)
                  for i in range(count)]
        combos = list(dict.fromkeys(combos))
        return pytest.mark.parametrize(",".join(names), combos)
