"""Reference-vs-production Algorithm-1 parity (the fig10 parity gate).

The (N, D) reference EF loop (error_feedback.cocoef_step — what figs. 2-9
train) and the production mesh step (cocoef_update inside shard_map — what
launch.train runs) are two implementations of the paper's Algorithm 1.
These tests train BOTH on the same linreg task / allocation / masks / wire
and demand BIT-FOR-BIT identical theta and error-vector trajectories for a
whole trained run: any drift between the implementations becomes a test
failure instead of a silently wrong figure.

Multi-device (mesh side), so everything runs through the run_sub
subprocess harness of test_distributed."""
import pytest

from test_distributed import run_sub


def test_reference_vs_mesh_parity_sign_quick():
    """Fast tier-1 signal: the sign wire (the paper's compressor) stays
    bit-for-bit over a short trained run."""
    run_sub("""
    from repro.launch.parity import assert_parity, run_parity
    rep = run_parity("sign", T=10)
    assert_parity(rep)
    assert rep["loss_ref"] < rep["loss_start"], rep
    """, timeout=600)


def test_dynamic_coding_state_parity_sign_quick():
    """Tier-1 elastic-plane gate: with the rate estimate pinned to the
    oracle rates, the dynamic CodingState trajectory (W recomputed by
    maybe_replan every step, fed as a jit argument) is bit-for-bit the
    static trajectory."""
    run_sub("""
    from repro.launch.parity import assert_parity, run_parity
    rep = run_parity("sign", T=10, dynamic_state=True)
    assert_parity(rep)
    assert rep["dynamic_state"], rep
    """, timeout=600)


@pytest.mark.slow
def test_dynamic_coding_state_parity_all_wires_schedules():
    """The elastic acceptance criterion in full: every parity wire x
    backend x bucket schedule stays bit-for-bit with the dynamic
    CodingState path."""
    run_sub("""
    from repro.launch.parity import (PARITY_COMPRESSORS, assert_parity,
                                     run_parity)
    for comp in PARITY_COMPRESSORS:
        rep = run_parity(comp, T=15, dynamic_state=True)
        assert_parity(rep)
    for comp in ("sign", "block_topk"):
        rep = run_parity(comp, T=8, backend="pallas", dynamic_state=True)
        assert_parity(rep)
        for sched in ("serial", "pipelined"):
            rep = run_parity(comp, T=8, num_buckets=2,
                             bucket_schedule=sched, dynamic_state=True)
            assert_parity(rep)
    """, timeout=900)


@pytest.mark.slow
def test_reference_vs_mesh_parity_all_wires_trained_run():
    """The full gate: sign / block_topk / dense (identity) wires, 25-step
    trained run, theta AND error vectors bit-for-bit at every step, with
    the loss actually decreasing (a trained run, not a fixed point)."""
    run_sub("""
    from repro.launch.parity import (PARITY_COMPRESSORS, assert_parity,
                                     run_parity)
    for comp in PARITY_COMPRESSORS:
        rep = run_parity(comp, T=25)
        assert_parity(rep)
        assert rep["loss_ref"] < rep["loss_start"], (comp, rep)
        assert rep["loss_mesh"] == rep["loss_ref"], (comp, rep)
    """, timeout=900)


@pytest.mark.slow
def test_parity_holds_on_pallas_backend():
    """The gate also holds with the mesh side running the Pallas kernels
    (interpret mode on CPU) — reference == jnp == pallas, one Algorithm 1
    across every execution backend."""
    run_sub("""
    from repro.launch.parity import assert_parity, run_parity
    for comp in ("sign", "block_topk"):
        rep = run_parity(comp, T=10, backend="pallas")
        assert_parity(rep)
    """, timeout=900)


@pytest.mark.slow
def test_parity_holds_through_bucketed_schedules():
    """The gate also holds with the mesh side splitting the flat vector
    into buckets, under BOTH issue orders: the (unbucketed) reference EF
    loop == the bucketed mesh step, serial or pipelined — overlap is a
    pure reordering, never a numerics change."""
    run_sub("""
    from repro.launch.parity import assert_parity, run_parity
    for comp in ("sign", "block_topk"):
        for sched in ("serial", "pipelined"):
            rep = run_parity(comp, T=10, num_buckets=2,
                             bucket_schedule=sched)
            assert_parity(rep)
            assert rep["loss_ref"] < rep["loss_start"], (comp, sched, rep)
    """, timeout=900)
