"""PlanSpec configuration plane + the auto-tuning planner.

Covers the PR-10 contract: PlanSpec round-trips every wire (including
per-rank budget tuples) and validates at construction; the deprecated
TrainRun alias fields resolve to the IDENTICAL PlanSpec an explicit plan
would carry (and mixing the two is rejected); the analytic pruning stage
never drops the brute-force StepTimer optimum; plan_search is
deterministic under a fixed seed; the plan-derived StepTimer charges
exactly the plan's own byte ledger; and TraceReplay's CSV trace format is
bit-compatible with the JSON path.
"""
import numpy as np
import pytest

import jax

from repro.configs import REGISTRY
from repro.core.plan import PLAN_SCHEMA, PlanSpec
from repro.launch.train import TrainRun
from repro.sim import (DEFAULT_COMPUTE, HeterogeneousRates, LinkProfile,
                       TraceReplay, elastic_replan_hook,
                       enumerate_candidates, plan_search, plan_timer,
                       prune_candidates)
from repro.sim.planner import convergence_penalty, expected_step_s


# ---------------------------------------------------------------------------
# PlanSpec: serialization + construction-time validation
# ---------------------------------------------------------------------------

ROUNDTRIP_PLANS = [
    PlanSpec(),                                            # defaults
    PlanSpec(d=3, compressor="sign", group_size=128,
             value_dtype="bfloat16", num_buckets=4,
             bucket_schedule="serial", backend="jnp"),
    PlanSpec(d=1, compressor="identity", allocation="rate_aware"),
    PlanSpec(compressor="block_topk", k_per_block=4, block_size=128),
    PlanSpec(compressor="topk", topk_k=96, allocation="exact_load"),
    PlanSpec(compressor="block_topk", k_per_block=(2, 4, 8, 16),
             block_size=256, num_ranks=4),                 # per-rank budgets
    PlanSpec(d=2, compressor="sign", num_ranks=8),
]


@pytest.mark.parametrize("plan", ROUNDTRIP_PLANS,
                         ids=lambda p: f"{p.compressor}-{p.allocation}")
def test_planspec_json_roundtrip_every_field(plan):
    again = PlanSpec.from_json(plan.to_json())
    assert again == plan
    assert again.to_dict() == plan.to_dict()
    assert plan.to_dict()["schema"] == PLAN_SCHEMA


def test_planspec_rejects_unknown_fields_and_schema():
    with pytest.raises(ValueError, match="unknown PlanSpec fields"):
        PlanSpec.from_dict({"schema": PLAN_SCHEMA, "dd": 2})
    with pytest.raises(ValueError, match="schema"):
        PlanSpec.from_dict({"schema": "repro.plan/v999", "d": 2})


def test_planspec_validates_at_construction():
    with pytest.raises(ValueError):
        PlanSpec(d=0)
    with pytest.raises(ValueError):
        PlanSpec(allocation="psychic")
    with pytest.raises(ValueError):
        PlanSpec(compressor="gzip")
    with pytest.raises(ValueError):
        PlanSpec(d=5, num_ranks=4)                 # more replicas than ranks
    with pytest.raises(ValueError):                # tuples need block_topk
        PlanSpec(compressor="sign", k_per_block=(4, 4))


def test_planspec_k_budget_length_validated_against_num_ranks():
    # the PR-10 bugfix: a wrong-length budget tuple fails loudly at
    # construction, not as a shape error deep inside jit
    with pytest.raises(ValueError, match="one k per rank"):
        PlanSpec(compressor="block_topk", k_per_block=(8, 8, 8),
                 num_ranks=4)
    ok = PlanSpec(compressor="block_topk", k_per_block=(8, 8, 8, 8),
                  num_ranks=4)
    assert ok.k_per_block == (8, 8, 8, 8)


def test_plan_timer_charges_the_plan_ledger():
    # "the config priced is the config run": StepTimer per-rank uplink
    # bytes == the plan's own rank_wire_bytes, including per-rank budgets
    plan = PlanSpec(compressor="block_topk", k_per_block=(2, 4, 8, 8),
                    block_size=256, num_ranks=4)
    n = 1 << 12
    timer = plan_timer(plan, n)
    np.testing.assert_array_equal(timer.bytes_up_ranks(4),
                                  plan.rank_wire_bytes(n))


# ---------------------------------------------------------------------------
# TrainRun: deprecated aliases == explicit plan, conflicts rejected
# ---------------------------------------------------------------------------

def test_deprecated_aliases_build_identical_planspec():
    cfg = REGISTRY["olmoe-1b-7b"].coding
    n_code = 4
    legacy = TrainRun(mode="cocoef", compressor="block_topk",
                      k_budgets=(2, 4, 8, 8), num_buckets=2,
                      bucket_schedule="serial", backend="jnp")
    explicit = TrainRun(mode="cocoef", plan=PlanSpec(
        d=min(cfg.redundancy, n_code), allocation="uniform",
        compressor="block_topk", group_size=cfg.group_size,
        k_per_block=(2, 4, 8, 8), block_size=cfg.block_size,
        topk_k=cfg.topk_k, value_dtype=cfg.wire_dtype, num_buckets=2,
        bucket_schedule="serial", backend="jnp", num_ranks=n_code))
    assert legacy.resolve_plan(cfg, n_code) == \
        explicit.resolve_plan(cfg, n_code)


def test_default_aliases_resolve_to_default_plan():
    cfg = REGISTRY["olmoe-1b-7b"].coding
    plan = TrainRun(mode="cocoef").resolve_plan(cfg, 4)
    assert plan.compressor == cfg.compressor
    assert plan.d == min(cfg.redundancy, 4)
    assert plan.num_ranks == 4
    assert plan.allocation == "uniform"


def test_plan_and_alias_conflict_rejected():
    with pytest.raises(ValueError, match="deprecated alias"):
        TrainRun(mode="cocoef", plan=PlanSpec(), compressor="sign")
    with pytest.raises(ValueError, match="deprecated alias"):
        TrainRun(mode="cocoef", plan=PlanSpec(), num_buckets=2)


def test_legacy_k_budgets_length_validated():
    cfg = REGISTRY["olmoe-1b-7b"].coding
    run = TrainRun(mode="cocoef", compressor="block_topk",
                   k_budgets=(8, 8, 8))
    with pytest.raises(ValueError, match="coding ranks"):
        run.resolve_plan(cfg, 4)
    with pytest.raises(ValueError, match="block_topk"):
        TrainRun(mode="cocoef", compressor="sign",
                 k_budgets=(8, 8, 8, 8)).resolve_plan(cfg, 4)


def test_explicit_plan_num_ranks_must_match_mesh():
    cfg = REGISTRY["olmoe-1b-7b"].coding
    run = TrainRun(mode="cocoef", plan=PlanSpec(num_ranks=8))
    with pytest.raises(ValueError, match="mesh has 4"):
        run.resolve_plan(cfg, 4)
    # unbound plans bind to the mesh
    bound = TrainRun(mode="cocoef", plan=PlanSpec()).resolve_plan(cfg, 4)
    assert bound.num_ranks == 4


# ---------------------------------------------------------------------------
# planner: pruning vs brute force, determinism
# ---------------------------------------------------------------------------

def test_bruteforce_top1_survives_analytic_pruning():
    # ground truth: sampled-trace StepTimer expectation x the same
    # convergence penalty, over the full grid; the analytic stage may
    # reorder the tail but must keep the brute-force optimum in the
    # confirmation set
    N, n = 12, 1 << 20
    link = LinkProfile(bandwidth_gbps=1.0)
    proc = HeterogeneousRates.two_class(N, p_slow=0.7, p_fast=0.05,
                                        slow_fraction=0.25)
    q = np.asarray(proc.rates())
    cands = enumerate_candidates(N, link=link, n=n)
    key = jax.random.PRNGKey(0)
    brute = min(
        ((expected_step_s(p, n, link, DEFAULT_COMPUTE, proc, key, T=128)
          * convergence_penalty(p, q, n), p.to_json()) for p in cands))
    kept = prune_candidates(cands, q, n, link, DEFAULT_COMPUTE, top_k=4)
    assert brute[1] in {c.plan.to_json() for c in kept}


def test_plan_search_deterministic_under_fixed_seed():
    proc = HeterogeneousRates.two_class(8, p_slow=0.6, p_fast=0.05,
                                        slow_fraction=0.25)
    kw = dict(process=proc, top_k=3, confirm_steps=40, trials=1,
              seed=3, dim=32, gamma=1e-4, record_every=10)
    r1 = plan_search(1 << 16, **kw)
    r2 = plan_search(1 << 16, **kw)
    assert r1.to_json() == r2.to_json()
    assert r1.best.confirmed
    assert r1.num_enumerated >= r1.pruned_to == 3


def test_replan_hook_surfaces_planner_ranking():
    from repro.core.coding_state import CodingPlan
    hook = elastic_replan_hook(1 << 14)
    cp = CodingPlan.create(np.full(6, 0.8), 6, 2, drift_threshold=0.05,
                           replan_hook=hook)
    _, info = cp.maybe_replan(np.array([0.2] * 3 + [0.9] * 3))
    assert info["reallocated"]
    ranking = info["plan_ranking"]
    assert ranking and ranking[0]["plan"]["schema"] == PLAN_SCHEMA
    assert ranking[0]["score"] <= ranking[-1]["score"]


# ---------------------------------------------------------------------------
# TraceReplay: CSV format bit-compatible with JSON
# ---------------------------------------------------------------------------

def test_tracereplay_csv_bitcompatible_with_json(tmp_path):
    rows = np.array([[1, 0, 1], [0, 1, 1], [1, 1, 1], [0, 0, 0]],
                    np.float64)
    jpath = TraceReplay.from_array(rows).to_json(tmp_path / "t.json")
    cpath = tmp_path / "t.csv"
    cpath.write_text("rank0,rank1,rank2\n" + "\n".join(
        ",".join(str(x) for x in r) for r in rows) + "\n")
    a = TraceReplay.from_file(jpath)
    b = TraceReplay.from_file(cpath)
    key = jax.random.PRNGKey(0)
    for t in range(2 * len(rows)):            # wraps past the end too
        np.testing.assert_array_equal(np.asarray(a.mask(key, t)),
                                      np.asarray(b.mask(key, t)))


def test_tracereplay_csv_rejects_ragged_rows(tmp_path):
    p = tmp_path / "bad.csv"
    p.write_text("1,0,1\n0,1\n")
    with pytest.raises(ValueError, match="one per rank"):
        TraceReplay.from_csv(p)
