"""Telemetry plane (repro.obs): schema, EWMA, traces, and the two hard
guarantees of the in-graph metrics path:

  1. DISABLED metrics cost nothing: `cocoef_update(want_metrics=False)`
     lowers to byte-identical HLO vs the pre-telemetry body, per wire
     format x backend (subprocess, 8 fake devices).
  2. ENABLED metrics add no collectives, and the per-rank wire-byte
     counters they report equal `WireFormat.rank_wire_bytes` == the
     `sim.StepTimer` uplink ledger == the packed payload
     (`benchmarks/comm_volume.audit_wire_bytes`) exactly.

Host-only pieces (logger / serve / trace export / timeline) run in the
main single-device process; everything needing >1 device runs in a
subprocess with xla_force_host_platform_device_count=8 (see conftest).
"""
import json
import os
import subprocess
import sys
import textwrap
import warnings
from pathlib import Path

import numpy as np
import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")
BENCH = str(Path(__file__).resolve().parents[1] / "benchmarks")


def run_sub(body: str, devices: int = 8, timeout: int = 600):
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import make_mesh, shard_map
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
        print("SUBTEST-PASS")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    assert "SUBTEST-PASS" in r.stdout


# ==========================================================================
# JSONL schema + logger
# ==========================================================================

def _train_step_telemetry(n=4, b=2):
    return {"participation": [1.0, 0.0, 1.0, 1.0][:n],
            "participants": 3.0,
            "wire_bytes_rank": [100.0] * n, "bytes_up_total": 300.0,
            "bucket_wire_bytes_rank": [[50.0] * b] * n,
            "bytes_down": 4096.0,
            "grad_norm_rank": [1.0] * n, "ef_norm_rank": [0.1] * n,
            "compress_cosine_rank": [0.9] * n,
            "compress_contraction_rank": [0.2] * n,
            "ghat_norm": 1.0, "update_norm": 0.01, "param_norm": 10.0}


def test_validate_record_rejects_malformed():
    from repro.obs import SCHEMA, validate_record
    ok = {"schema": SCHEMA, "kind": "run_meta", "meta": {"x": 1}}
    validate_record(ok)
    with pytest.raises(ValueError, match="schema"):
        validate_record({"schema": "repro.obs/v0", "kind": "run_meta",
                         "meta": {}})
    with pytest.raises(ValueError, match="kind"):
        validate_record({"schema": SCHEMA, "kind": "mystery"})
    with pytest.raises(ValueError, match="missing field"):
        validate_record({"schema": SCHEMA, "kind": "prefetch"})
    with pytest.raises(ValueError, match="must be dict"):
        validate_record({"schema": SCHEMA, "kind": "prefetch",
                         "stats": [1, 2]})
    # train_step per-rank lists must agree with participation's length
    rec = {"schema": SCHEMA, "kind": "train_step", "step": 0,
           "t_wall_s": 0.0, "ewma_participation": [1.0, 1.0, 1.0, 1.0],
           **_train_step_telemetry()}
    validate_record(rec)
    bad = dict(rec, wire_bytes_rank=[1.0, 2.0])
    with pytest.raises(ValueError, match="wire_bytes_rank"):
        validate_record(bad)
    # serve_summary histograms need p50/p99/mean/count
    with pytest.raises(ValueError, match="histogram keys"):
        validate_record({"schema": SCHEMA, "kind": "serve_summary",
                         "requests": 1, "queue_wait_ms": {"p50": 1.0},
                         "prefill_ms": {"p50": 0, "p99": 0, "mean": 0,
                                        "count": 0},
                         "decode_token_ms": {"p50": 0, "p99": 0, "mean": 0,
                                             "count": 0}})


def test_metrics_logger_jsonl_and_ewma(tmp_path):
    from repro.obs import MetricsLogger, read_jsonl, validate_record
    path = str(tmp_path / "m.jsonl")
    masks = [np.array([1.0, 0.0, 1.0, 1.0]), np.array([0.0, 1.0, 1.0, 1.0]),
             np.array([1.0, 1.0, 1.0, 0.0])]
    with MetricsLogger(path, run_metadata={"arch": "t"},
                       ewma_alpha=0.5) as lg:
        assert lg.rates is None
        for t, m in enumerate(masks):
            tel = _train_step_telemetry()
            tel["participation"] = m.tolist()
            lg.log_step(t, tel, loss=1.0 - 0.1 * t,
                        spans={"train/step_dispatch": 0.01})
        ew = lg.rates
        lg.log_prefetch({"size": 2, "put_count": 3, "get_count": 3,
                         "producer_wait_s": 0.0, "consumer_wait_s": 0.1,
                         "device_put_s": 0.01, "max_depth": 2,
                         "depth_sum": 4})
        assert lg.steps_logged == 3
    # bias-corrected EWMA: zero-init s_t = (1-a) s + a m, reported
    # s_t / (1 - (1-a)^t) — an exact weighted average of the masks seen
    # (for a=0.5, T=3: (m0 + 2 m1 + 4 m2) / 7)
    s = np.zeros_like(masks[0])
    for m in masks:
        s = 0.5 * s + 0.5 * m
    expect = s / (1.0 - 0.5 ** len(masks))
    np.testing.assert_allclose(expect, (masks[0] + 2 * masks[1]
                                        + 4 * masks[2]) / 7.0)
    np.testing.assert_allclose(ew, expect)
    recs = read_jsonl(path)
    assert [r["kind"] for r in recs] == \
        ["run_meta", "train_step", "train_step", "train_step", "prefetch"]
    for r in recs:
        validate_record(r)     # every emitted line passes the schema gate
    np.testing.assert_allclose(recs[3]["ewma_participation"], expect)
    # at t=1 the correction makes the estimate exactly the first mask
    np.testing.assert_allclose(recs[1]["ewma_participation"], masks[0])
    assert recs[1]["loss"] == pytest.approx(1.0)
    # a malformed record never reaches the file, and closed loggers refuse
    with pytest.raises(ValueError):
        MetricsLogger(str(tmp_path / "x.jsonl")).write({"kind": "nope"})
    lg2 = MetricsLogger(str(tmp_path / "y.jsonl"))
    lg2.close()
    with pytest.raises(ValueError, match="closed"):
        lg2.log_prefetch({"size": 1})


def test_ewma_bias_correction_5step_regression(tmp_path):
    """Satellite regression pin: under a known-rate Bernoulli process the
    bias-corrected estimate after 5 steps is an exact weighted average of
    the observed masks, so its error against the empirical mean is bounded
    by the (small) geometric reweighting — NOT by step-0 noise, which
    dominated the first ~1/alpha steps under the old first-mask seeding."""
    from repro.obs import MetricsLogger
    rng = np.random.default_rng(7)
    q = np.array([0.9, 0.6, 0.3, 0.8])
    masks = (rng.uniform(size=(5, 4)) < q).astype(np.float64)
    a = 0.1
    with MetricsLogger(str(tmp_path / "m.jsonl"), ewma_alpha=a) as lg:
        for t, m in enumerate(masks):
            tel = _train_step_telemetry()
            tel["participation"] = m.tolist()
            lg.log_step(t, tel)
        est = lg.rates
    # closed form: weights (1-a)^(T-1-t) * a, normalized by 1-(1-a)^T
    w = a * (1.0 - a) ** np.arange(len(masks) - 1, -1, -1)
    expect = (w[:, None] * masks).sum(0) / (1.0 - (1.0 - a) ** len(masks))
    np.testing.assert_allclose(est, expect, rtol=1e-12)
    # with alpha=0.1 the corrected weights are within 34% of uniform over
    # 5 steps, so the estimate stays near the empirical mean...
    emp = masks.mean(0)
    assert np.max(np.abs(est - emp)) < 0.25
    # ...while the OLD seeded estimate is pinned to the first mask:
    # weight of m_0 is (1-a)^4 ~ 0.66, so a first-step outage drags a
    # q=0.9 rank's estimate below 0.7 for ~1/a steps
    seeded = masks[0].copy()
    for m in masks[1:]:
        seeded = (1.0 - a) * seeded + a * m
    assert np.max(np.abs(seeded - emp)) > np.max(np.abs(est - emp))


def test_logger_ewma_matches_rate_estimator():
    """The logger's inline bias correction and the standalone
    `core.coding_state.RateEstimator` are twin implementations (the
    logger cannot import core); they must agree bit-for-bit."""
    from repro.core.coding_state import RateEstimator
    from repro.obs import MetricsLogger
    import tempfile
    rng = np.random.default_rng(3)
    masks = (rng.uniform(size=(12, 4)) < 0.7).astype(np.float64)
    est = RateEstimator(4, alpha=0.2)
    with tempfile.TemporaryDirectory() as d:
        with MetricsLogger(d + "/m.jsonl", ewma_alpha=0.2) as lg:
            for t, m in enumerate(masks):
                tel = _train_step_telemetry()
                tel["participation"] = m.tolist()
                lg.log_step(t, tel)
                est.update(m)
                assert (lg.rates == est.rates).all()


def test_replan_record_schema(tmp_path):
    from repro.obs import MetricsLogger, read_jsonl, validate_record
    path = str(tmp_path / "m.jsonl")
    with MetricsLogger(path) as lg:
        rec = lg.log_replan(3, {"epoch": 1, "drift": 0.17,
                                "reallocated": True,
                                "rates_estimate": [0.9, 0.5]})
        validate_record(rec)
    recs = read_jsonl(path)
    assert recs[-1]["kind"] == "replan"
    assert recs[-1]["reallocated"] is True
    assert recs[-1]["epoch"] == 1
    with pytest.raises(ValueError, match="missing field"):
        validate_record({"schema": "repro.obs/v1", "kind": "replan",
                         "step": 1})


def test_serve_telemetry_percentiles_and_records(tmp_path):
    from repro.obs import MetricsLogger, ServeTelemetry, read_jsonl, \
        validate_record
    from repro.obs.logger import percentiles_ms
    assert percentiles_ms([]) == {"p50": 0.0, "p99": 0.0, "mean": 0.0,
                                  "count": 0}
    tel = ServeTelemetry()
    decode_s = [0.001 * (i + 1) for i in range(100)]   # 1..100 ms
    for s in decode_s:
        tel.add_decode_token(s)
    tel.add_prefill(0.050)
    for rid in range(4):
        tel.add_request(rid, queue_wait_s=0.010 * rid, prefill_s=0.05,
                        decode_s=0.2, tokens=8)
    s = tel.summary()
    assert s["requests"] == 4
    assert s["decode_token_ms"]["count"] == 100
    assert s["decode_token_ms"]["p50"] == pytest.approx(
        np.percentile(np.asarray(decode_s) * 1e3, 50))
    assert s["decode_token_ms"]["p99"] == pytest.approx(
        np.percentile(np.asarray(decode_s) * 1e3, 99))
    assert s["queue_wait_ms"]["p50"] == pytest.approx(15.0)
    with MetricsLogger(str(tmp_path / "s.jsonl"),
                       run_metadata={"path": "serve"}) as lg:
        tel.log_to(lg)
    recs = read_jsonl(str(tmp_path / "s.jsonl"))
    assert [r["kind"] for r in recs] == \
        ["run_meta"] + ["serve_request"] * 4 + ["serve_summary"]
    for r in recs:
        validate_record(r)
    assert "p50" in tel.format_summary()


# ==========================================================================
# span recorder + Chrome-trace export
# ==========================================================================

def test_span_recorder_and_chrome_trace_roundtrip(tmp_path):
    import time

    from repro.obs import SpanRecorder, span_events, validate_chrome_trace, \
        write_chrome_trace
    rec = SpanRecorder()
    with rec.span("phase/a", step=0):
        time.sleep(0.01)
    with rec.span("phase/b", tid="serve"):
        pass
    rec.counter("queue_depth", 2)
    assert rec.durations("phase/a")[0] >= 0.01
    assert set(rec.summary_s()) == {"phase/a", "phase/b"}
    path = str(tmp_path / "trace.json")
    obj = write_chrome_trace(path, span_events(rec.spans, pid=0,
                                               counters=rec.counters),
                             metadata={"arch": "t"})
    validate_chrome_trace(obj)
    loaded = json.load(open(path))
    assert loaded["otherData"]["schema"] == "repro.obs.trace/v1"
    kinds = [e["ph"] for e in loaded["traceEvents"]]
    assert kinds.count("X") == 2 and kinds.count("C") == 1
    ex = [e for e in loaded["traceEvents"] if e["ph"] == "X"][0]
    assert ex["tid"] == "host" and ex["args"]["step"] == 0


def test_validate_chrome_trace_rejects_malformed():
    from repro.obs import chrome_trace, validate_chrome_trace
    with pytest.raises(ValueError, match="schema"):
        validate_chrome_trace({"traceEvents": []})
    ok = lambda: chrome_trace([{"name": "x", "ph": "X", "ts": 0.0,
                                "dur": 1.0, "pid": 0, "tid": "t"}])
    validate_chrome_trace(ok())
    bad = ok()
    bad["traceEvents"][0]["ph"] = "Z"
    with pytest.raises(ValueError, match="ph"):
        validate_chrome_trace(bad)
    bad = ok()
    bad["traceEvents"][0]["ts"] = float("nan")
    with pytest.raises(ValueError, match="finite"):
        validate_chrome_trace(bad)
    bad = ok()
    del bad["traceEvents"][0]["tid"]
    with pytest.raises(ValueError, match="tid"):
        validate_chrome_trace(bad)
    bad = ok()
    bad["traceEvents"][0]["dur"] = -1.0
    with pytest.raises(ValueError, match="dur"):
        validate_chrome_trace(bad)


# ==========================================================================
# simulated StepTimer timeline == the cost model's closed form
# ==========================================================================

def _timeline_cases():
    from repro.core.collectives import DenseWire, SignWire, SparseWire
    return [
        ("sign serial B=1", SignWire(group_size=512), {}),
        ("sign serial B=4", SignWire(group_size=512),
         {"num_buckets": 4}),
        ("sign pipelined B=4", SignWire(group_size=512),
         {"num_buckets": 4, "overlap": True}),
        ("topk pipelined B=4 pack", SparseWire(k_per_block=8,
                                               block_size=512),
         {"num_buckets": 4, "overlap": True, "pack_s": 1e-3}),
        ("topk per-rank budgets", SparseWire(k_per_block=(2, 4, 8, 16),
                                             block_size=512), {}),
        ("dense serial B=2 pack", DenseWire(), {"num_buckets": 2,
                                                "pack_s": 5e-4}),
    ]


def test_steptimer_timeline_matches_cost_model():
    """The laid-out span extent of every simulated step equals
    `StepTimer.steps()` exactly — serial and pipelined bucket schedules,
    per-rank budgets, and the all-straggler timeout window included."""
    from repro.obs import chrome_trace, steptimer_timeline, \
        validate_chrome_trace
    from repro.sim import StepTimer
    trace = np.array([[1, 1, 1, 1],
                      [1, 0, 1, 1],
                      [0, 0, 0, 0],      # all-straggler: timeout window
                      [0, 1, 0, 0],
                      [1, 1, 0, 1]], np.float64)
    for name, wire, kw in _timeline_cases():
        timer = StepTimer(wire=wire, n=4096, **kw)
        events, ts = steptimer_timeline(timer, trace, pid=1)
        expect, _, _ = timer.steps(trace)
        np.testing.assert_allclose(ts, expect, rtol=1e-9, atol=1e-15,
                                   err_msg=name)
        obj = chrome_trace(events, {"case": name})
        validate_chrome_trace(obj)
        steps = [e for e in events if e["name"] == "step"]
        assert len(steps) == trace.shape[0], name
        # steps tile the timeline back to back, and the all-straggler row
        # renders a timeout (no uplink), participating rows compute lanes
        for t in range(1, len(steps)):
            assert steps[t]["ts"] == pytest.approx(
                steps[t - 1]["ts"] + steps[t - 1]["dur"]), name
        names_t2 = {e["name"] for e in events
                    if e["args"].get("step") == 2}
        assert "compute_timeout" in names_t2 and "uplink" not in names_t2
        assert "compute" not in names_t2, name
    with pytest.raises(ValueError, match=r"\(T, N\)"):
        steptimer_timeline(StepTimer(wire=_timeline_cases()[0][1], n=4096),
                           np.ones((4,)))


# ==========================================================================
# single source of truth: declared == packed == cost model (+ provenance)
# ==========================================================================

def test_wire_audit_and_run_metadata():
    sys.path.insert(0, BENCH)
    try:
        import _repro_common as R
        import comm_volume
    finally:
        sys.path.remove(BENCH)
    audited = comm_volume.audit_wire_bytes(n=4096)
    assert len(audited) == len(comm_volume.WIRE_TABLE) + 1   # + per-rank
    meta = R.run_metadata(trials=3, T=100)
    for k in ("git_sha", "jax_version", "python", "platform",
              "jax_backend", "device_count", "timestamp"):
        assert k in meta, k
    assert meta["trials"] == 3 and meta["T"] == 100
    json.dumps(meta)          # must be embeddable in results JSON


def test_rank_wire_bytes_linear_over_buckets():
    """Per-bucket accounting sums to the whole-vector accounting — the
    identity the in-graph per-bucket byte counters rely on."""
    from repro.core.collectives import DenseWire, SignWire, SparseWire
    n, N, B = 8192, 4, 4
    for wire in (SignWire(group_size=512),
                 SparseWire(k_per_block=8, block_size=512),
                 SparseWire(k_per_block=(2, 4, 8, 16), block_size=512),
                 DenseWire(value_dtype="bfloat16")):
        per_bucket = wire.rank_wire_bytes(n // B, N)
        np.testing.assert_array_equal(per_bucket * B,
                                      wire.rank_wire_bytes(n, N))


# ==========================================================================
# host-side grid reduction (pure-array semantics)
# ==========================================================================

def test_reduce_frame_grid_semantics():
    """Synthetic (2, 3) grid, coding over "data" (size 2), 3 model shards:
    corners dedupe replicated leaves, rank sums fold the model axis, byte
    counters scale by the shard count, and zero-acc ranks report cosine 0
    (no NaNs)."""
    import jax.numpy as jnp

    from repro.obs import MetricsFrame, frame_to_host, reduce_frame_grid
    grid = (2, 3)
    N, B = 2, 2
    rep = lambda v: jnp.broadcast_to(jnp.asarray(v, jnp.float32),
                                     grid + np.shape(v))
    dev = jnp.arange(6, dtype=jnp.float32).reshape(grid)   # distinct/device
    frame = MetricsFrame(
        participation=rep([1.0, 0.0]),
        wire_bytes_rank=rep([100.0, 0.0]),
        bucket_wire_bytes=rep([30.0, 20.0]),
        bytes_down=rep(7.0),
        grad_norm_sq=dev, ef_norm_sq=dev * 2,
        acc_norm_sq=jnp.stack([dev[0] * 0 + 4.0, dev[1] * 0.0]),
        c_norm_sq=jnp.stack([dev[0] * 0 + 1.0, dev[1] * 0.0]),
        acc_dot_c=jnp.stack([dev[0] * 0 + 2.0, dev[1] * 0.0]),
        ghat_norm_sq=rep(3.0), update_norm_sq=rep(5.0),
        param_norm_sq=rep(9.0))
    tel = frame_to_host(reduce_frame_grid(frame, ("data", "model"),
                                          ("data",)))
    assert tel["participation"] == [1.0, 0.0]
    assert tel["participants"] == 1.0
    # byte counters: per-device constants x 3 model shards
    assert tel["wire_bytes_rank"] == [300.0, 0.0]
    assert tel["bytes_up_total"] == 300.0
    assert tel["bytes_down"] == 21.0
    assert tel["bucket_wire_bytes_rank"] == [[90.0, 60.0], [90.0, 60.0]]
    # rank sums fold the model axis: rank 0 sees devices 0+1+2, rank 1 3+4+5
    np.testing.assert_allclose(tel["grad_norm_rank"],
                               [np.sqrt(0 + 1 + 2), np.sqrt(3 + 4 + 5)])
    np.testing.assert_allclose(tel["ef_norm_rank"],
                               [np.sqrt(6.0), np.sqrt(24.0)])
    # cosine/contraction per rank; the all-zero rank 1 reports 0, not NaN
    # rank 0: acc_sq=12, c_sq=3, dot=6 -> cos=1, contraction=(12+3-12)/12
    np.testing.assert_allclose(tel["compress_cosine_rank"], [1.0, 0.0])
    np.testing.assert_allclose(tel["compress_contraction_rank"],
                               [0.25, 0.0])
    # replicated-after-collective scalars: sum model, mean coding
    assert tel["ghat_norm"] == pytest.approx(np.sqrt(9.0))
    assert tel["update_norm"] == pytest.approx(np.sqrt(15.0))
    assert tel["param_norm"] == pytest.approx(np.sqrt(27.0))


# ==========================================================================
# resolve_use_pallas fallback warning: once per (op, shape, dtype)
# ==========================================================================

def test_resolve_use_pallas_rewarns_per_op_and_dtype():
    from repro.kernels import ops
    ops._fallback_warned.clear()
    try:
        with pytest.warns(RuntimeWarning, match="falling back"):
            assert ops.resolve_use_pallas(True, 100, 64, op="ef_sign_fused",
                                          dtype="float32") is False
        with warnings.catch_warnings():       # same key: silent
            warnings.simplefilter("error")
            ops.resolve_use_pallas(True, 100, 64, op="ef_sign_fused",
                                   dtype="float32")
        # the PR 8 bugfix: the same shape through a DIFFERENT op or value
        # dtype used to be swallowed by the shape-only key
        with pytest.warns(RuntimeWarning, match="ef_topk_fused"):
            ops.resolve_use_pallas(True, 100, 64, op="ef_topk_fused",
                                   dtype="float32")
        with pytest.warns(RuntimeWarning):
            ops.resolve_use_pallas(True, 100, 64, op="ef_sign_fused",
                                   dtype="bfloat16")
        with warnings.catch_warnings():       # no explicit request / fits
            warnings.simplefilter("error")
            assert ops.resolve_use_pallas(False, 100, 64, op="x") is False
            assert ops.resolve_use_pallas(True, 128, 64, op="x") is True
    finally:
        ops._fallback_warned.clear()


# ==========================================================================
# prefetch stats reach the JSONL plane
# ==========================================================================

def test_prefetch_stats_log_record(tmp_path):
    from repro.data import pipeline
    from repro.obs import MetricsLogger, read_jsonl, validate_record
    it = pipeline.prefetch_to_device(
        iter([np.zeros((2,), np.float32)] * 3), size=2)
    out = list(it)
    assert len(out) == 3
    with MetricsLogger(str(tmp_path / "p.jsonl")) as lg:
        rec = lg.log_prefetch(it.stats.snapshot())
    validate_record(rec)
    saved = read_jsonl(str(tmp_path / "p.jsonl"))[0]["stats"]
    assert saved["get_count"] == 3 and saved["put_count"] == 3
    assert saved["size"] == 2 and saved["max_depth"] <= 2


# ==========================================================================
# multi-device: HLO identity (disabled) + no extra collectives (enabled)
# ==========================================================================

def test_metrics_disabled_hlo_identical_per_wire_and_backend():
    """`cocoef_update` (metrics off) must lower to byte-identical text vs
    the pre-telemetry `_cocoef_update_impl` for every compressor x backend
    x mode, and the metrics-ON lowering must contain exactly the same
    collective ops (telemetry is device-local by construction)."""
    run_sub("""
    from jax.sharding import PartitionSpec as P
    from repro.core.cocoef import (CocoEFConfig, cocoef_update,
                                   _cocoef_update_impl)
    from repro.obs.metrics import MetricsFrame, frame_out_specs

    mesh = make_mesh((4, 2), ("data", "model"))
    axis = {"data", "model"}
    mask = jnp.array([1., 0., 1., 1.])
    n = 2048
    spec = P(("data", "model"))
    gs = jax.ShapeDtypeStruct((8 * n,), jnp.float32)

    COLLECTIVES = ("all_to_all", "all_gather", "all_reduce",
                   "collective_permute", "reduce_scatter",
                   "collective_broadcast")

    def counts(txt):
        return {c: txt.count(c) for c in COLLECTIVES}

    cases = []
    for backend in ("jnp", "pallas"):
        for comp in ("sign", "block_topk", "topk", "identity"):
            cases.append(dict(compressor=comp, backend=backend))
    cases.append(dict(mode="coco"))
    cases.append(dict(mode="dense"))
    cases.append(dict(compressor="block_topk", num_buckets=4,
                      bucket_schedule="pipelined"))
    cases.append(dict(compressor="block_topk",
                      k_per_block=(1, 2, 4, 8)))

    for over in cases:
        cfg = CocoEFConfig(coding_axes=("data",), group_size=32,
                           block_size=64, k_per_block=over.pop(
                               "k_per_block", 4), **over)

        def lower2(fn):
            f = shard_map(lambda g, e: fn(g, e, mask, 0.05, cfg), mesh,
                          in_specs=(spec,) * 2, out_specs=(spec,) * 2,
                          axis_names=axis, check=False)
            return jax.jit(f).lower(gs, gs).as_text()

        off = lower2(cocoef_update)          # default want_metrics=False
        impl = lower2(_cocoef_update_impl)   # the pre-telemetry body
        assert off == impl, f"HLO drift with metrics disabled: {cfg}"

        def body_on(g, e):
            ghat, e_new, frame = cocoef_update(g, e, mask, 0.05, cfg,
                                               want_metrics=True)
            frame = jax.tree.map(lambda l: l.reshape((1, 1) + l.shape),
                                 frame)
            return ghat, e_new, frame
        fa = MetricsFrame.abstract(4, cfg.num_buckets)
        f_on = shard_map(body_on, mesh, in_specs=(spec,) * 2,
                         out_specs=(spec, spec,
                                    frame_out_specs(fa, mesh.axis_names)),
                         axis_names=axis, check=False)
        on = jax.jit(f_on).lower(gs, gs).as_text()
        assert counts(on) == counts(off), \\
            f"metrics added collectives: {cfg}: " \\
            f"{counts(on)} vs {counts(off)}"
    """)


def test_shard_map_per_rank_metrics_match_ledger():
    """Enabled metrics through the real mesh: per-rank wire bytes equal
    mask x `wire.rank_wire_bytes` x TP shards == the `sim.StepTimer`
    uplink ledger; norms/cosine/contraction match a host-side oracle of
    Algorithm 1's compression per coding rank."""
    run_sub("""
    from jax.sharding import PartitionSpec as P
    from repro.core.cocoef import CocoEFConfig, cocoef_update
    from repro.core.collectives import DenseWire
    from repro.obs.metrics import (MetricsFrame, frame_out_specs,
                                   frame_to_host, reduce_frame_grid)
    from repro.sim import StepTimer

    mesh = make_mesh((4, 2), ("data", "model"))
    axis = {"data", "model"}
    N, TP, n = 4, 2, 2048
    gamma = 0.05
    mask = jnp.array([1., 0., 1., 1.])
    spec = P(("data", "model"))
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (8 * n,), jnp.float32)
    e0 = jax.random.normal(jax.random.PRNGKey(1), (8 * n,),
                           jnp.float32) * 0.1

    cases = [
        ("cocoef sign", CocoEFConfig(coding_axes=("data",), group_size=32)),
        ("cocoef topk B=4 pipelined",
         CocoEFConfig(coding_axes=("data",), group_size=32,
                      compressor="block_topk", block_size=64, k_per_block=4,
                      num_buckets=4)),
        ("cocoef topk per-rank budgets",
         CocoEFConfig(coding_axes=("data",), group_size=32,
                      compressor="block_topk", block_size=64,
                      k_per_block=(1, 2, 4, 8))),
        ("coco sign", CocoEFConfig(coding_axes=("data",), group_size=32,
                                   mode="coco")),
        ("dense", CocoEFConfig(coding_axes=("data",), group_size=32,
                               mode="dense")),
    ]
    for name, cfg in cases:
        B = cfg.num_buckets
        e = e0 * (0.0 if cfg.mode in ("coco", "dense") else 1.0)

        def body(g_, e_):
            ghat, e_new, frame = cocoef_update(g_, e_, mask, gamma, cfg,
                                               want_metrics=True)
            frame = jax.tree.map(lambda l: l.reshape((1, 1) + l.shape),
                                 frame)
            return ghat, e_new, frame
        fa = MetricsFrame.abstract(N, B)
        f = jax.jit(shard_map(
            body, mesh, in_specs=(spec,) * 2,
            out_specs=(spec, spec, frame_out_specs(fa, mesh.axis_names)),
            axis_names=axis, check=False))
        ghat, e_new, grid = f(g, e)
        tel = frame_to_host(jax.device_get(reduce_frame_grid(
            grid, mesh.axis_names, cfg.coding_axes)))

        assert tel["participation"] == [1., 0., 1., 1.], name
        assert tel["participants"] == 3.0, name

        # --- byte ledger: metrics == wire declaration == StepTimer ------
        wire = (DenseWire(value_dtype="float32") if cfg.mode == "dense"
                else cfg.wire_format(n // B, N))
        timer = StepTimer(wire=wire, n=n // B, num_buckets=B)
        per_rank = timer.bytes_up_ranks(N).astype(np.float64) * B
        expect_rank = np.asarray(mask) * per_rank * TP
        np.testing.assert_allclose(tel["wire_bytes_rank"], expect_rank,
                                   err_msg=name)
        assert tel["bytes_up_total"] == expect_rank.sum(), name
        # the StepTimer trace ledger prices the same step identically
        _, bytes_up, _ = StepTimer(wire=wire, n=n // B).steps(
            np.asarray(mask)[None, :] )
        assert tel["bytes_up_total"] == bytes_up[0] * B * TP, name
        bb = np.asarray(tel["bucket_wire_bytes_rank"])
        assert bb.shape == (N, B), name
        np.testing.assert_allclose(bb.sum(axis=1), expect_rank,
                                   err_msg=name)
        assert tel["bytes_down"] == n * 4 * TP, name

        # --- norms / compression quality vs a host oracle ---------------
        gr = np.asarray(g).reshape(N, TP * n)
        er = np.asarray(e).reshape(N, TP * n)
        np.testing.assert_allclose(tel["grad_norm_rank"],
                                   np.linalg.norm(gr, axis=1), rtol=1e-5,
                                   err_msg=name)
        enr = np.asarray(e_new).reshape(N, TP * n)
        np.testing.assert_allclose(tel["ef_norm_rank"],
                                   np.linalg.norm(enr, axis=1), rtol=1e-5,
                                   err_msg=name)
        acc_sq = np.zeros(N); c_sq = np.zeros(N); dot = np.zeros(N)
        for i in range(N):
            for j in range(TP):
                dev = slice((i * TP + j) * n, (i * TP + j + 1) * n)
                for acc_b in (gamma * np.asarray(g)[dev] +
                              np.asarray(e)[dev]).reshape(B, -1):
                    acc_b = jnp.asarray(acc_b, jnp.float32)
                    if cfg.mode == "dense":
                        c_b = acc_b
                    else:
                        w = cfg.wire_format(n // B, N)
                        c_b = w.unpack(w.apply_rank_budget(
                            w.fused_pack(acc_b, use_pallas=False), i))
                    c_b = np.asarray(c_b)
                    acc_b = np.asarray(acc_b)
                    acc_sq[i] += (acc_b * acc_b).sum()
                    c_sq[i] += (c_b * c_b).sum()
                    dot[i] += (acc_b * c_b).sum()
        cos = dot / np.maximum(np.sqrt(acc_sq) * np.sqrt(c_sq), 1e-30)
        contraction = (acc_sq + c_sq - 2 * dot) / np.maximum(acc_sq, 1e-30)
        np.testing.assert_allclose(tel["compress_cosine_rank"], cos,
                                   rtol=1e-4, err_msg=name)
        np.testing.assert_allclose(tel["compress_contraction_rank"],
                                   contraction, rtol=1e-3, atol=1e-6,
                                   err_msg=name)
        # ghat identical across coding ranks; its norm is the global one
        gh = np.asarray(ghat).reshape(N, TP * n)
        np.testing.assert_allclose(tel["ghat_norm"],
                                   np.linalg.norm(gh[0]), rtol=1e-5,
                                   err_msg=name)
        print(name, "OK")
    """)
