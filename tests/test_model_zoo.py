"""fig10 model-zoo sweep internals: the REAL mesh train step per cell,
with per-model compute profiles derived from the compiled step's HLO
(ComputeProfile.from_compiled_hlo — the acceptance criterion that phase-1
compute seconds differ across architectures instead of the cost model's
fixed 5 ms default)."""
import pytest

from test_distributed import run_sub


@pytest.mark.slow
def test_model_zoo_cell_compute_differs_across_archs():
    run_sub("""
    from repro.configs import SMOKE_TRAIN
    from benchmarks import fig10_model_zoo as F
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    cells = {}
    for arch in ("gemma2-2b", "olmoe-1b-7b"):
        cells[arch] = F.run_cell(arch, "sign", "iid", mesh, SMOKE_TRAIN,
                                 T=6, trials=1)
    g = {a: c["grad_s"] for a, c in cells.items()}
    # per-model compute from the compiled HLO: positive, NOT the 5 ms
    # default, and architecture-dependent
    for a, v in g.items():
        assert v > 0, (a, v)
        assert abs(v - 5e-3) > 1e-6, (a, v)
    assert g["gemma2-2b"] != g["olmoe-1b-7b"], g
    for a, c in cells.items():
        curve = c["curve"]
        assert len(curve["loss"]) == 6
        assert curve["time_s"][-1] > 0
        assert curve["bytes_up_cum"][-1] > 0
        assert c["bytes_up_per_rank"] > 0
        assert c["n_code"] == 4
    """, timeout=900)


@pytest.mark.slow
def test_model_zoo_wire_changes_bytes_not_flops():
    """Same arch, different wire: the compute profile (flops) is the
    model's, the wire bytes are the wire's."""
    run_sub("""
    from repro.configs import SMOKE_TRAIN
    from benchmarks import fig10_model_zoo as F
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    sign = F.run_cell("xlstm-1.3b", "sign", "iid", mesh, SMOKE_TRAIN,
                      T=4, trials=1)
    dense = F.run_cell("xlstm-1.3b", "dense", "iid", mesh, SMOKE_TRAIN,
                       T=4, trials=1)
    assert sign["grad_s"] == dense["grad_s"], (sign["grad_s"],
                                               dense["grad_s"])
    assert dense["bytes_up_per_rank"] > 4 * sign["bytes_up_per_rank"]
    """, timeout=900)
