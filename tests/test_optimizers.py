"""Server-optimizer semantics: decoupled weight decay + schedule validation.

Regression suite for two PR-5 bugfixes:
  * `apply_update` used to fold `weight_decay * gamma * params` into ghat
    BEFORE Adam divided by gamma and fed the moments — L2-through-moments
    (and through the momentum buffer), not AdamW.  Decay is now decoupled:
    it must not change the moment estimates at all.
  * `lr_schedule("cosine", total=None)` used to die on a bare `assert`
    inside jit tracing; schedule knobs now validate at construction.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (OptimizerConfig, SCHEDULES, apply_update,
                         init_opt_state, lr_schedule)

N = 64
GAMMA = 0.1
WD = 0.01


def _inputs(seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(k1, (N,)), jax.random.normal(k2, (N,)) * 0.1)


def _run(kind, wd, steps=5):
    cfg = OptimizerConfig(kind=kind, weight_decay=wd)
    params, _ = _inputs()
    state = init_opt_state(cfg, N)
    states, trajectory = [], []
    for t in range(steps):
        _, ghat = _inputs(seed=100 + t)
        params, state = apply_update(cfg, params, GAMMA * ghat, state,
                                     jnp.int32(t), GAMMA)
        states.append(state)
        trajectory.append(params)
    return trajectory, states


@pytest.mark.parametrize("kind", ["momentum", "adam"])
def test_weight_decay_never_touches_moments(kind):
    """THE regression: with decoupled decay the optimizer state (momentum
    buffer / Adam m, v) is BIT-FOR-BIT identical with and without decay."""
    _, states0 = _run(kind, wd=0.0)
    _, statesw = _run(kind, wd=WD)
    for s0, sw in zip(states0, statesw):
        for a, b in zip(s0, sw):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("kind", ["sgd", "momentum", "adam"])
def test_weight_decay_is_decoupled_at_the_update(kind):
    """One step from the same state: params(wd) == params(0) - wd*gamma*p
    exactly (the decay enters nowhere else)."""
    params, ghat = _inputs()
    state = init_opt_state(OptimizerConfig(kind=kind), N)
    p0, _ = apply_update(OptimizerConfig(kind=kind), params, ghat, state,
                         jnp.int32(0), GAMMA)
    pw, _ = apply_update(OptimizerConfig(kind=kind, weight_decay=WD),
                         params, ghat, state, jnp.int32(0), GAMMA)
    np.testing.assert_array_equal(np.asarray(pw),
                                  np.asarray(p0 - WD * GAMMA * params))


def test_adam_decay_shrinks_params_without_biasing_direction():
    """Sanity: with zero gradient, Adam + decay is pure shrinkage."""
    cfg = OptimizerConfig(kind="adam", weight_decay=WD)
    params, _ = _inputs()
    state = init_opt_state(cfg, N)
    p1, (m, v) = apply_update(cfg, params, jnp.zeros((N,)), state,
                              jnp.int32(0), GAMMA)
    np.testing.assert_allclose(np.asarray(p1),
                               np.asarray(params * (1 - WD * GAMMA)),
                               rtol=1e-6)
    assert float(jnp.abs(m).max()) == 0.0
    assert float(jnp.abs(v).max()) == 0.0


# ---------------------------------------------------------------------------
# schedule validation + step-0 behavior
# ---------------------------------------------------------------------------

def test_cosine_without_total_raises_at_construction():
    with pytest.raises(ValueError, match="cosine"):
        lr_schedule("cosine", 1e-3)
    with pytest.raises(ValueError, match="cosine"):
        lr_schedule("cosine", 1e-3, total=0)


def test_unknown_schedule_and_bad_warmup_raise():
    with pytest.raises(ValueError, match="unknown lr schedule"):
        lr_schedule("linear", 1e-3)
    with pytest.raises(ValueError, match="warmup"):
        lr_schedule("constant", 1e-3, warmup=-1)


@pytest.mark.parametrize("kind", SCHEDULES)
@pytest.mark.parametrize("warmup", [0, 1, 10])
def test_every_schedule_finite_at_step0(kind, warmup):
    """All three schedules x warmup at step 0: finite, positive, no
    0-division, warmup factor clipped to 1 (jitted — the setting the old
    bare assert died in)."""
    base = 1e-3
    f = lr_schedule(kind, base, warmup=warmup, total=100)
    g0 = float(jax.jit(f)(jnp.int32(0)))
    assert np.isfinite(g0) and g0 > 0.0
    expect = base * (min(1.0, 1.0 / warmup) if warmup > 0 else 1.0)
    if kind == "constant":
        np.testing.assert_allclose(g0, expect, rtol=1e-6)
    else:
        assert g0 <= expect * (1 + 1e-6)
    # far past warmup + decay horizon: still finite, warmup factor == 1
    g_late = float(jax.jit(f)(jnp.int32(1000)))
    assert np.isfinite(g_late) and g_late >= 0.0
    if kind == "constant" and warmup:
        np.testing.assert_allclose(g_late, base, rtol=1e-6)


def test_trainrun_validates_schedule_at_construction():
    from repro.launch.train import TrainRun
    with pytest.raises(ValueError, match="cosine"):
        TrainRun(schedule="cosine")
    with pytest.raises(ValueError, match="unknown lr schedule"):
        TrainRun(schedule="nope")
    run = TrainRun(schedule="cosine", schedule_total=1000, warmup=10)
    assert run.schedule_total == 1000
