"""Block-select top-k: the sort-free threshold search must BE `lax.top_k`.

`kernels.topk_block.block_select` (binary search on IEEE bit patterns +
first-occurrence tie cut) is the in-kernel selection primitive of every
sparse-wire Pallas kernel, and `kernels.topk_fast` is the barrier-fixed
jnp hot path the train step runs on CPU.  The reference-vs-mesh parity
gate demands that all three agree with `kernels/ref.py` (plain
`lax.top_k`) BIT-FOR-BIT — indices, tie ORDER, values, scale — so these
tests drive the selection through adversarial inputs: heavy magnitude
ties, all-equal rows, all-zero rows, denormals, and k == block width.

Also covered here: the transmitted-reconstruction conservation for
bfloat16 wire values (Sterbenz), and the warn-once guard on silent
pallas -> jnp tile fallbacks.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st
from jax import lax

from repro.kernels import ops, ref
from repro.kernels import topk_fast as tf
from repro.kernels.topk_block import block_select, block_select_mask
from repro.kernels.topk_pack import ef_topk_fused, topk_pack

KINDS = ("normal", "ties", "equal", "denormal", "zeros")


def _rows(kind: str, seed: int, R: int, B: int) -> jnp.ndarray:
    """(R, B) f32 rows engineered at the selection's corner cases."""
    x = jax.random.normal(jax.random.PRNGKey(seed * 7919 + B), (R, B))
    if kind == "ties":          # few distinct magnitudes -> threshold ties
        x = jnp.round(x * 3.0) / 3.0
    elif kind == "equal":       # every |x| identical -> pure tie-rank cut
        x = jnp.where(x >= 0, 1.0, -1.0)
    elif kind == "denormal":    # f32 subnormals (bit-pattern search floor)
        x = x * 1e-40
    elif kind == "zeros":       # zero rows + zero-riddled rows
        x = x.at[:, ::2].set(0.0).at[0].set(0.0)
    return x.astype(jnp.float32)


@settings(max_examples=20, deadline=None)
@given(kind=st.sampled_from(KINDS), seed=st.integers(0, 100),
       k=st.sampled_from([1, 4, 16, 64]))
def test_block_select_is_lax_top_k(kind, seed, k):
    """Indices (incl. tie order), signed values, and scale all bitwise
    equal to the lax.top_k selection on |x| — for every adversarial row
    family, up to k == block width."""
    B = 64
    x = _rows(kind, seed, 8, B)
    idx, sval, scale = jax.jit(block_select, static_argnums=1)(x, k)
    topv, tidx = lax.top_k(jnp.abs(x), k)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(tidx), kind)
    np.testing.assert_array_equal(
        np.asarray(sval), np.asarray(jnp.take_along_axis(x, tidx, -1)), kind)
    np.testing.assert_array_equal(
        np.asarray(scale[:, 0]),
        np.asarray(jnp.max(jnp.abs(x), axis=-1)), kind)


@settings(max_examples=20, deadline=None)
@given(kind=st.sampled_from(KINDS), seed=st.integers(0, 100),
       k=st.sampled_from([1, 7, 32, 128]))
def test_block_select_mask_is_exact_topk_set(kind, seed, k):
    """The keep-mask has exactly k survivors per row and is the SET
    lax.top_k selects (first occurrence winning ties)."""
    B = 128
    x = _rows(kind, seed, 8, B)
    keep = np.asarray(jax.jit(block_select_mask, static_argnums=1)(x, k))
    assert (keep.sum(-1) == k).all()
    _, tidx = lax.top_k(jnp.abs(x), k)
    expect = np.zeros_like(keep)
    np.put_along_axis(expect, np.asarray(tidx), True, axis=-1)
    np.testing.assert_array_equal(keep, expect, kind)


def test_block_select_rejects_bad_k():
    x = jnp.ones((2, 16))
    for bad in (0, -1, 17):
        with pytest.raises(ValueError):
            block_select_mask(x, bad)


# ---------------------------------------------------------------------------
# the fast (barrier) jnp path and the Pallas kernels vs the ref oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("value_dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("mask", [0.0, 1.0])
def test_fast_fused_step_bitwise_equals_ref(value_dtype, mask):
    """topk_fast.ef_topk_fused_fast (the CPU hot path with the fusion
    barrier) is bit-for-bit ref.ef_topk_fused_ref under jit."""
    n, k, block = 8 * 128 * 2, 8, 128
    g = jax.random.normal(jax.random.PRNGKey(10), (n,))
    e = jax.random.normal(jax.random.PRNGKey(11), (n,)) * 0.1
    fast = jax.jit(lambda a, b: tf.ef_topk_fused_fast(
        a, b, 0.01, mask, k, block, value_dtype=value_dtype))(g, e)
    orac = jax.jit(lambda a, b: ref.ef_topk_fused_ref(
        a, b, 0.01, mask, k, block, value_dtype=value_dtype))(g, e)
    for a, b in zip(fast, orac):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fast_pack_bitwise_equals_ref():
    n, k, block = 8 * 256, 8, 256
    x = _rows("ties", 3, n // block, block).reshape(-1)
    fast = jax.jit(lambda a: tf.topk_pack_fast(a, k, block))(x)
    orac = jax.jit(lambda a: ref.topk_pack_ref(a, k, block))(x)
    for a, b in zip(fast, orac):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("value_dtype", ["float32", "bfloat16"])
def test_pallas_fused_step_bitwise_equals_ref(value_dtype):
    """The Pallas kernel (block_select inside the kernel body, interpret
    mode on CPU) matches the jitted ref oracle bitwise, both wire dtypes,
    including on tie-heavy input."""
    n, k, block = 8 * 128, 8, 128
    g = _rows("ties", 5, n // 128, 128).reshape(-1)
    e = jax.random.normal(jax.random.PRNGKey(12), (n,)) * 0.1
    outs_k = ef_topk_fused(g, e, 0.01, 1.0, k, block,
                           value_dtype=value_dtype, interpret=True)
    outs_r = jax.jit(lambda a, b: ref.ef_topk_fused_ref(
        a, b, 0.01, 1.0, k, block, value_dtype=value_dtype))(g, e)
    for a, b in zip(outs_k, outs_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pallas_pack_bitwise_equals_ref_on_ties():
    n, k, block = 8 * 64, 4, 64
    x = _rows("equal", 9, n // block, block).reshape(-1)
    outs_k = topk_pack(x, k, block, interpret=True)
    outs_r = ref.topk_pack_ref(x, k, block)
    for a, b in zip(outs_k, outs_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bf16_wire_conservation_sterbenz():
    """With bfloat16 wire values, c is the value_dtype-ROUNDED transmitted
    reconstruction, yet c + e_new still equals acc bit-for-bit: at kept
    coordinates c lands within a factor of two of acc, so the `acc - c`
    subtraction is exact (Sterbenz), and elsewhere c is zero."""
    n, k, block = 8 * 128, 8, 128
    gv = jax.random.normal(jax.random.PRNGKey(13), (n,))
    e = jax.random.normal(jax.random.PRNGKey(14), (n,)) * 0.1
    gamma = 0.05

    @jax.jit
    def step(a, b):
        acc = ref.mul_add(gamma, a, b)
        _, _, _, c, e_new = tf.ef_topk_fused_fast(
            a, b, gamma, 1.0, k, block, value_dtype="bfloat16")
        return acc, c, e_new

    acc, c, e_new = step(gv, e)
    np.testing.assert_array_equal(np.asarray(c) + np.asarray(e_new),
                                  np.asarray(acc))


def test_want_c_false_matches_want_c_true():
    """want_c=False must change nothing but drop c (the DCE path the wire
    uses when only the payload ships)."""
    n, k, block = 8 * 128, 4, 128
    g = jax.random.normal(jax.random.PRNGKey(15), (n,))
    e = jax.random.normal(jax.random.PRNGKey(16), (n,)) * 0.1
    for fn in (tf.ef_topk_fused_fast,
               lambda *a, **kw: ef_topk_fused(*a, interpret=True, **kw)):
        with_c = jax.jit(lambda a, b: fn(a, b, 0.01, 1.0, k, block,
                                         want_c=True))(g, e)
        no_c = jax.jit(lambda a, b: fn(a, b, 0.01, 1.0, k, block,
                                       want_c=False))(g, e)
        assert no_c[3] is None
        for i in (0, 1, 2, 4):
            np.testing.assert_array_equal(np.asarray(with_c[i]),
                                          np.asarray(no_c[i]))


# ---------------------------------------------------------------------------
# dispatch honesty: explicit-pallas tile fallback warns exactly once
# ---------------------------------------------------------------------------

def test_pallas_tile_fallback_warns_once_per_shape():
    n, tile = 4097, 4096            # unique (n, tile): the warn-set is
    #   process-global, so this pair must not be used by any other test
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert ops.resolve_use_pallas(True, n, tile) is False
        assert ops.resolve_use_pallas(True, n, tile) is False
    runtime = [x for x in w if issubclass(x.category, RuntimeWarning)]
    assert len(runtime) == 1
    assert "falling back" in str(runtime[0].message)
    # auto (None) and explicit jnp fallbacks stay silent — only a broken
    # EXPLICIT pallas request is worth a warning
    with warnings.catch_warnings(record=True) as w2:
        warnings.simplefilter("always")
        assert ops.resolve_use_pallas(None, 4099, tile) in (False,)
        assert ops.resolve_use_pallas(False, 4099, tile) is False
    assert not [x for x in w2 if issubclass(x.category, RuntimeWarning)]
    # fitting shapes never warn and honor the request
    with warnings.catch_warnings(record=True) as w3:
        warnings.simplefilter("always")
        assert ops.resolve_use_pallas(True, 2 * tile, tile) is True
    assert not [x for x in w3 if issubclass(x.category, RuntimeWarning)]
