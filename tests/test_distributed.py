"""Multi-device tests (collectives + end-to-end distributed training).

These need >1 device, so each runs in a SUBPROCESS with
xla_force_host_platform_device_count=8 — the main pytest process keeps the
default single device (see conftest)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_sub(body: str, devices: int = 8, timeout: int = 600):
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import jax, jax.numpy as jnp, numpy as np
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
        print("SUBTEST-PASS")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    assert "SUBTEST-PASS" in r.stdout


def test_two_phase_equals_dense():
    run_sub("""
    from jax.sharding import PartitionSpec as P
    from repro.core.collectives import (two_phase_sign_allreduce,
                                        dense_allreduce,
                                        CodingCollectiveConfig)
    from repro.core.compression import GroupedSign
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,)*3)
    cfg = CodingCollectiveConfig(coding_axes=("pod", "data"), group_size=32)
    mask = jnp.array([1., 0., 1., 1.])

    def body(c):
        return (two_phase_sign_allreduce(c, cfg, mask),
                dense_allreduce(c, cfg, mask))

    n = 256
    f = jax.shard_map(body, mesh=mesh, in_specs=P(("pod","data","model")),
                      out_specs=(P(("pod","data","model")),)*2,
                      axis_names={"pod","data","model"})
    raw = jax.random.normal(jax.random.PRNGKey(1), (8*n,))
    q = jax.vmap(lambda v: GroupedSign(group_size=32).apply(v)
                 )(raw.reshape(8, n)).reshape(-1)
    g1, g2 = jax.jit(f)(q)
    assert np.allclose(np.asarray(g1), np.asarray(g2), atol=1e-5), \
        float(np.abs(np.asarray(g1)-np.asarray(g2)).max())
    """)


def test_phase2_sign_is_contraction():
    """Beyond-paper compressed broadcast: output is the sign-quantization of
    the dense aggregate (per chunk), i.e. still a valid contraction."""
    run_sub("""
    from jax.sharding import PartitionSpec as P
    from repro.core.collectives import (two_phase_sign_allreduce,
                                        dense_allreduce,
                                        CodingCollectiveConfig)
    from repro.core.compression import GroupedSign
    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,)*2)
    cfg = CodingCollectiveConfig(coding_axes=("data",), group_size=32,
                                 phase2_sign=True)
    cfg0 = CodingCollectiveConfig(coding_axes=("data",), group_size=32)
    mask = jnp.ones((4,))

    def body(c):
        return (two_phase_sign_allreduce(c, cfg, mask),
                two_phase_sign_allreduce(c, cfg0, mask))

    n = 256
    f = jax.shard_map(body, mesh=mesh, in_specs=P(("data","model")),
                      out_specs=(P(("data","model")),)*2,
                      axis_names={"data","model"})
    raw = jax.random.normal(jax.random.PRNGKey(1), (8*n,))
    q = jax.vmap(lambda v: GroupedSign(group_size=32).apply(v)
                 )(raw.reshape(8, n)).reshape(-1)
    gq, gd = jax.jit(f)(q)
    gq, gd = np.asarray(gq), np.asarray(gd)
    # contraction vs the dense aggregate, and exact sign-quant of it
    delta = 1 - 1/32
    assert ((gq - gd)**2).sum() <= delta * (gd**2).sum() * 1.001
    expect = jax.vmap(lambda v: GroupedSign(group_size=32).apply(v))(
        jnp.asarray(gd).reshape(-1, 32)[None])[0].reshape(-1)
    assert np.allclose(gq, np.asarray(expect), atol=1e-5)
    """)


def test_distributed_train_loss_decreases():
    run_sub("""
    import dataclasses
    from repro.configs import REGISTRY
    from repro.configs.common import ShapeCfg
    from repro.launch.train import TrainRun, build_train_setup, \
        make_batch_for_step
    mesh = jax.make_mesh((2,2,2), ("pod","data","model"),
                         axis_types=(jax.sharding.AxisType.Auto,)*3)
    shape = ShapeCfg("train", 32, 8)
    spec = REGISTRY["olmoe-1b-7b"]
    spec = dataclasses.replace(
        spec, coding=dataclasses.replace(spec.coding, group_size=32))
    setup = build_train_setup(spec, mesh, shape, TrainRun(base_lr=1e-2),
                              smoke=True)
    key = jax.random.PRNGKey(0)
    params, e, opt = setup.init_state(key)
    batch = make_batch_for_step(setup, spec, shape, key, 0, smoke=True)
    batch = jax.device_put(batch, setup.batch_shardings)
    jstep = jax.jit(setup.train_step)
    losses = []
    for t in range(10):
        params, e, opt, m = jstep(params, e, opt, batch, jnp.int32(t), key)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.05, losses
    assert float(jnp.abs(e).max()) > 0
    """, timeout=900)


def test_distributed_dense_matches_direct_sgd():
    """mode=dense, p=0: the aggregated update must equal a directly-computed
    full-batch weighted gradient step (validates stage-1 coding + stage-2
    plumbing end to end)."""
    run_sub("""
    import dataclasses
    from repro.configs import REGISTRY
    from repro.configs.common import ShapeCfg
    from repro.launch.train import TrainRun, build_train_setup, \
        make_batch_for_step
    from repro.nn import Model
    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,)*2)
    shape = ShapeCfg("train", 32, 8)
    spec = REGISTRY["phi3-medium-14b"]
    spec = dataclasses.replace(
        spec, coding=dataclasses.replace(spec.coding, group_size=32,
                                         straggler_p=0.0, redundancy=1))
    run = TrainRun(base_lr=1e-2, mode="dense")
    setup = build_train_setup(spec, mesh, shape, run, smoke=True)
    key = jax.random.PRNGKey(0)
    params, e, opt = setup.init_state(key)
    batch = make_batch_for_step(setup, spec, shape, key, 0, smoke=True)
    params2, _, _, m = jax.jit(setup.train_step)(params, e, opt, batch,
                                                 jnp.int32(0), key)
    # direct: gradient of sum_i w_i-weighted loss over the SAME batch
    model = Model(spec.smoke)
    flatb = {"inputs": batch["inputs"].reshape(-1, 33),
             "weights": batch["weights"].reshape(-1)}
    g = jax.grad(lambda p: model.loss(p, flatb)[0])(params)
    for (path, pn), (_, po), (_, gg) in zip(
            jax.tree_util.tree_leaves_with_path(params2),
            jax.tree_util.tree_leaves_with_path(params),
            jax.tree_util.tree_leaves_with_path(g)):
        expect = np.asarray(po) - 1e-2 * np.asarray(gg)
        got = np.asarray(pn)
        assert np.allclose(got, expect, rtol=2e-4, atol=2e-5), \
            (path, np.abs(got-expect).max())
    """, timeout=900)


def test_distributed_cocoef_matches_reference_sim():
    """Distributed COCO-EF (p=0, all ranks participate) == the (N, D)
    reference simulator on identical coded gradients: same theta update,
    same error vectors (up to f32 reorder)."""
    run_sub("""
    import dataclasses
    from repro.configs import REGISTRY
    from repro.configs.common import ShapeCfg
    from repro.launch.train import TrainRun, build_train_setup, \
        make_batch_for_step
    from repro.core import compression as C
    from repro.nn import Model
    from jax.flatten_util import ravel_pytree
    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,)*2)
    shape = ShapeCfg("train", 32, 8)
    spec = REGISTRY["phi3-medium-14b"]
    spec = dataclasses.replace(
        spec, coding=dataclasses.replace(spec.coding, group_size=32,
                                         straggler_p=0.0, redundancy=2))
    run = TrainRun(base_lr=1e-2, mode="cocoef")
    setup = build_train_setup(spec, mesh, shape, run, smoke=True)
    key = jax.random.PRNGKey(0)
    params, e, opt = setup.init_state(key)
    batch = make_batch_for_step(setup, spec, shape, key, 0, smoke=True)
    params2, e2, _, m = jax.jit(setup.train_step)(params, e, opt, batch,
                                                  jnp.int32(0), key)
    # reference: per-rank coded grads computed directly, grouped-sign + EF.
    # NOTE: distributed compression operates on each device's LOCAL flat
    # slice; with model=2 shards the group boundaries differ from a global
    # flatten, so compare through the same local-flat view: here we check
    # the aggregate update direction & EF conservation instead of bitwise.
    model = Model(spec.smoke)
    g_ranks = []
    for i in range(4):
        b = {"inputs": batch["inputs"][i], "weights": batch["weights"][i]}
        g = jax.grad(lambda p: model.loss(p, b)[0])(params)
        g_ranks.append(ravel_pytree(g)[0])
    flat_p0 = ravel_pytree(params)[0]
    flat_p2 = ravel_pytree(params2)[0]
    upd = flat_p0 - flat_p2
    dense = 1e-2 * sum(g_ranks)
    # compressed update approximates the dense coded update (delta < 1)
    num = float(jnp.sum((upd - dense)**2))
    den = float(jnp.sum(dense**2))
    assert num < den, (num, den)
    # EF conservation at the aggregate level: sum_i e_i = sum_i acc_i - ghat
    # check via norms: e2 nonzero and bounded by sum |acc|
    assert float(jnp.abs(e2).max()) > 0
    """, timeout=900)
