"""Multi-device tests (collectives + end-to-end distributed training).

These need >1 device, so each runs in a SUBPROCESS with
xla_force_host_platform_device_count=8 — the main pytest process keeps the
default single device (see conftest)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_sub(body: str, devices: int = 8, timeout: int = 600):
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import make_mesh, shard_map
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
        print("SUBTEST-PASS")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    assert "SUBTEST-PASS" in r.stdout


def test_two_phase_equals_dense():
    run_sub("""
    from jax.sharding import PartitionSpec as P
    from repro.core.collectives import (two_phase_sign_allreduce,
                                        dense_allreduce,
                                        CodingCollectiveConfig)
    from repro.core.compression import GroupedSign
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = CodingCollectiveConfig(coding_axes=("pod", "data"), group_size=32)
    mask = jnp.array([1., 0., 1., 1.])

    def body(c):
        return (two_phase_sign_allreduce(c, cfg, mask),
                dense_allreduce(c, cfg, mask))

    n = 256
    f = shard_map(body, mesh, in_specs=P(("pod","data","model")),
                  out_specs=(P(("pod","data","model")),)*2,
                  axis_names={"pod","data","model"})
    raw = jax.random.normal(jax.random.PRNGKey(1), (8*n,))
    q = jax.vmap(lambda v: GroupedSign(group_size=32).apply(v)
                 )(raw.reshape(8, n)).reshape(-1)
    g1, g2 = jax.jit(f)(q)
    assert np.allclose(np.asarray(g1), np.asarray(g2), atol=1e-5), \
        float(np.abs(np.asarray(g1)-np.asarray(g2)).max())
    """)


def test_phase2_sign_is_contraction():
    """Beyond-paper compressed broadcast: output is the sign-quantization of
    the dense aggregate (per chunk), i.e. still a valid contraction."""
    run_sub("""
    from jax.sharding import PartitionSpec as P
    from repro.core.collectives import (two_phase_sign_allreduce,
                                        dense_allreduce,
                                        CodingCollectiveConfig)
    from repro.core.compression import GroupedSign
    mesh = make_mesh((4, 2), ("data", "model"))
    cfg = CodingCollectiveConfig(coding_axes=("data",), group_size=32,
                                 phase2_sign=True)
    cfg0 = CodingCollectiveConfig(coding_axes=("data",), group_size=32)
    mask = jnp.ones((4,))

    def body(c):
        return (two_phase_sign_allreduce(c, cfg, mask),
                two_phase_sign_allreduce(c, cfg0, mask))

    n = 256
    f = shard_map(body, mesh, in_specs=P(("data","model")),
                  out_specs=(P(("data","model")),)*2,
                  axis_names={"data","model"})
    raw = jax.random.normal(jax.random.PRNGKey(1), (8*n,))
    q = jax.vmap(lambda v: GroupedSign(group_size=32).apply(v)
                 )(raw.reshape(8, n)).reshape(-1)
    gq, gd = jax.jit(f)(q)
    gq, gd = np.asarray(gq), np.asarray(gd)
    # contraction vs the dense aggregate, and exact sign-quant of it
    delta = 1 - 1/32
    assert ((gq - gd)**2).sum() <= delta * (gd**2).sum() * 1.001
    expect = jax.vmap(lambda v: GroupedSign(group_size=32).apply(v))(
        jnp.asarray(gd).reshape(-1, 32)[None])[0].reshape(-1)
    assert np.allclose(gq, np.asarray(expect), atol=1e-5)
    """)


def test_coded_allreduce_matches_dense_oracle_sweep():
    """`two_phase_coded_allreduce` == dense masked psum for every wire
    format x straggler mask x num_buckets in {1, 4}, and `cocoef_update`
    matches a host-side Algorithm-1 oracle for every compressor mode
    (acceptance: TopK/BlockTopK end-to-end on the coded train path)."""
    run_sub("""
    from jax.sharding import PartitionSpec as P
    from repro.core.collectives import (two_phase_coded_allreduce,
                                        dense_allreduce,
                                        CodingCollectiveConfig,
                                        SignWire, SparseWire, DenseWire)
    from repro.core.cocoef import CocoEFConfig, cocoef_update
    mesh = make_mesh((4, 2), ("data", "model"))
    cfg = CodingCollectiveConfig(coding_axes=("data",), group_size=32)
    masks = [jnp.ones((4,)), jnp.array([1., 0., 1., 1.]),
             jnp.array([0., 0., 1., 0.])]
    wires = [SignWire(group_size=32), SparseWire(k_per_block=4, block_size=64),
             SparseWire(k_per_block=4, block_size=64, value_dtype="bfloat16"),
             DenseWire()]
    n = 2048   # per-device flat size: multiple of 4 chunks * 64 block * 4 bkts
    raw = jax.random.normal(jax.random.PRNGKey(1), (8 * n,))
    for wire in wires:
        assert wire.wire_bytes(n) <= 4 * n   # never worse than dense f32
        for num_buckets in (1, 4):
            nb = n // num_buckets
            def body(c, mask):
                outs = []
                for c_b in c.reshape(num_buckets, -1):
                    outs.append((two_phase_coded_allreduce(c_b, wire, cfg,
                                                           mask),
                                 dense_allreduce(c_b, cfg, mask)))
                return (jnp.concatenate([o[0] for o in outs]),
                        jnp.concatenate([o[1] for o in outs]))
            f = shard_map(body, mesh,
                          in_specs=(P(("data", "model")), P()),
                          out_specs=(P(("data", "model")),) * 2,
                          axis_names={"data", "model"})
            q = jax.vmap(wire.roundtrip)(
                raw.reshape(8 * num_buckets, nb)).reshape(-1)
            jf = jax.jit(f)
            for mask in masks:
                g1, g2 = jf(q, mask)
                err = float(np.abs(np.asarray(g1) - np.asarray(g2)).max())
                assert err <= 1e-5, (type(wire).__name__, num_buckets, err)

    # cocoef_update end-to-end vs host oracle, all compressor modes
    gamma = 0.1
    g = jax.random.normal(jax.random.PRNGKey(2), (8 * n,))
    e = jax.random.normal(jax.random.PRNGKey(3), (8 * n,)) * 0.1
    mask = jnp.array([1., 0., 1., 1.])
    for comp in ("sign", "block_topk", "topk", "identity"):
        for num_buckets in (1, 4):
            ccfg = CocoEFConfig(coding_axes=("data",), group_size=32,
                                compressor=comp, block_size=64, k_per_block=4,
                                topk_k=64, num_buckets=num_buckets)
            f = shard_map(lambda gg, ee: cocoef_update(gg, ee, mask, gamma,
                                                       ccfg),
                          mesh, in_specs=(P(("data", "model")),) * 2,
                          out_specs=(P(("data", "model")),) * 2,
                          axis_names={"data", "model"})
            ghat, e_new = jax.jit(f)(g, e)
            # host oracle: per-device roundtrip of acc, masked sum over the
            # coding (data) axis, EF update where the sender participated
            acc = (gamma * g + e).reshape(4, 2, n)
            def rt(v):
                w = ccfg.wire_format(n // num_buckets, 4)
                return jnp.concatenate([w.roundtrip(b) for b in
                                        v.reshape(num_buckets, -1)])
            c = jax.vmap(jax.vmap(rt))(acc)
            want_ghat = (mask[:, None, None] * c).sum(0)      # (2, n)
            want_e = jnp.where(mask[:, None, None] > 0, acc - c,
                               e.reshape(4, 2, n))
            err_g = float(jnp.abs(ghat.reshape(4, 2, n)
                                  - want_ghat[None]).max())
            err_e = float(jnp.abs(e_new.reshape(4, 2, n) - want_e).max())
            assert err_g <= 1e-5 and err_e <= 1e-5, (comp, num_buckets,
                                                     err_g, err_e)
    """, timeout=900)


@pytest.mark.slow
def test_distributed_train_loss_decreases():
    run_sub("""
    import dataclasses
    from repro.configs import REGISTRY
    from repro.configs.common import ShapeCfg
    from repro.launch.train import TrainRun, build_train_setup, \
        make_batch_for_step
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    shape = ShapeCfg("train", 32, 8)
    spec = REGISTRY["olmoe-1b-7b"]
    spec = dataclasses.replace(
        spec, coding=dataclasses.replace(spec.coding, group_size=32))
    setup = build_train_setup(spec, mesh, shape, TrainRun(base_lr=1e-2),
                              smoke=True)
    key = jax.random.PRNGKey(0)
    params, e, opt = setup.init_state(key)
    batch = make_batch_for_step(setup, spec, shape, key, 0, smoke=True)
    batch = jax.device_put(batch, setup.batch_shardings)
    jstep = jax.jit(setup.train_step)
    losses = []
    for t in range(10):
        params, e, opt, m = jstep(params, e, opt, batch, jnp.int32(t), key)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.05, losses
    assert float(jnp.abs(e).max()) > 0
    """, timeout=900)


@pytest.mark.slow
def test_distributed_dense_matches_direct_sgd():
    """mode=dense, p=0: the aggregated update must equal a directly-computed
    full-batch weighted gradient step (validates stage-1 coding + stage-2
    plumbing end to end)."""
    run_sub("""
    import dataclasses
    from repro.configs import REGISTRY
    from repro.configs.common import ShapeCfg
    from repro.launch.train import TrainRun, build_train_setup, \
        make_batch_for_step
    from repro.nn import Model
    mesh = make_mesh((4, 2), ("data", "model"))
    shape = ShapeCfg("train", 32, 8)
    spec = REGISTRY["phi3-medium-14b"]
    spec = dataclasses.replace(
        spec, coding=dataclasses.replace(spec.coding, group_size=32,
                                         straggler_p=0.0, redundancy=1))
    run = TrainRun(base_lr=1e-2, mode="dense")
    setup = build_train_setup(spec, mesh, shape, run, smoke=True)
    key = jax.random.PRNGKey(0)
    params, e, opt = setup.init_state(key)
    batch = make_batch_for_step(setup, spec, shape, key, 0, smoke=True)
    params2, _, _, m = jax.jit(setup.train_step)(params, e, opt, batch,
                                                 jnp.int32(0), key)
    # direct: gradient of sum_i w_i-weighted loss over the SAME batch
    model = Model(spec.smoke)
    flatb = {"inputs": batch["inputs"].reshape(-1, 33),
             "weights": batch["weights"].reshape(-1)}
    g = jax.grad(lambda p: model.loss(p, flatb)[0])(params)
    for (path, pn), (_, po), (_, gg) in zip(
            jax.tree_util.tree_leaves_with_path(params2),
            jax.tree_util.tree_leaves_with_path(params),
            jax.tree_util.tree_leaves_with_path(g)):
        expect = np.asarray(po) - 1e-2 * np.asarray(gg)
        got = np.asarray(pn)
        assert np.allclose(got, expect, rtol=2e-4, atol=2e-5), \
            (path, np.abs(got-expect).max())
    """, timeout=900)


@pytest.mark.slow
def test_distributed_cocoef_matches_reference_sim():
    """Distributed COCO-EF (p=0, all ranks participate) == the (N, D)
    reference simulator on identical coded gradients: same theta update,
    same error vectors (up to f32 reorder)."""
    run_sub("""
    import dataclasses
    from repro.configs import REGISTRY
    from repro.configs.common import ShapeCfg
    from repro.launch.train import TrainRun, build_train_setup, \
        make_batch_for_step
    from repro.core import compression as C
    from repro.nn import Model
    from jax.flatten_util import ravel_pytree
    mesh = make_mesh((4, 2), ("data", "model"))
    shape = ShapeCfg("train", 32, 8)
    spec = REGISTRY["phi3-medium-14b"]
    spec = dataclasses.replace(
        spec, coding=dataclasses.replace(spec.coding, group_size=32,
                                         straggler_p=0.0, redundancy=2))
    run = TrainRun(base_lr=1e-2, mode="cocoef")
    setup = build_train_setup(spec, mesh, shape, run, smoke=True)
    key = jax.random.PRNGKey(0)
    params, e, opt = setup.init_state(key)
    batch = make_batch_for_step(setup, spec, shape, key, 0, smoke=True)
    params2, e2, _, m = jax.jit(setup.train_step)(params, e, opt, batch,
                                                  jnp.int32(0), key)
    # reference: per-rank coded grads computed directly, grouped-sign + EF.
    # NOTE: distributed compression operates on each device's LOCAL flat
    # slice; with model=2 shards the group boundaries differ from a global
    # flatten, so compare through the same local-flat view: here we check
    # the aggregate update direction & EF conservation instead of bitwise.
    model = Model(spec.smoke)
    g_ranks = []
    for i in range(4):
        b = {"inputs": batch["inputs"][i], "weights": batch["weights"][i]}
        g = jax.grad(lambda p: model.loss(p, b)[0])(params)
        g_ranks.append(ravel_pytree(g)[0])
    flat_p0 = ravel_pytree(params)[0]
    flat_p2 = ravel_pytree(params2)[0]
    upd = flat_p0 - flat_p2
    dense = 1e-2 * sum(g_ranks)
    # compressed update approximates the dense coded update (delta < 1)
    num = float(jnp.sum((upd - dense)**2))
    den = float(jnp.sum(dense**2))
    assert num < den, (num, den)
    # EF conservation at the aggregate level: sum_i e_i = sum_i acc_i - ghat
    # check via norms: e2 nonzero and bounded by sum |acc|
    assert float(jnp.abs(e2).max()) > 0
    """, timeout=900)
