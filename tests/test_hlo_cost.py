"""The while-aware HLO cost model (repro.launch.hlo_cost) must agree with
exact flop counts where XLA's own cost_analysis does, and fix the known
while-body undercount (scan == unroll)."""
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def test_scan_equals_unroll_and_exact():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from repro.launch import hlo_cost
        M, L = 128, 6
        def body(x, w):
            return jnp.tanh(x @ w), None
        def scanned(x, ws):
            return jax.lax.scan(body, x, ws)[0]
        def unrolled(x, ws):
            for i in range(L):
                x, _ = body(x, ws[i])
            return x
        x = jax.ShapeDtypeStruct((M, M), jnp.float32)
        w = jax.ShapeDtypeStruct((L, M, M), jnp.float32)
        exact = 2 * M**3 * L
        cs = hlo_cost.analyze(jax.jit(scanned).lower(x, w).compile().as_text(), 4)
        cu = hlo_cost.analyze(jax.jit(unrolled).lower(x, w).compile().as_text(), 4)
        assert abs(cs.flops - exact) / exact < 1e-6, (cs.flops, exact)
        assert abs(cu.flops - exact) / exact < 1e-6, (cu.flops, exact)
        assert cs.n_while == 1 and cs.unknown_trip == 0
        # XLA's own cost_analysis undercounts the scan (the bug we fix):
        ca = jax.jit(scanned).lower(x, w).compile().cost_analysis()
        if isinstance(ca, (list, tuple)):   # older jax: one dict per device
            ca = ca[0]
        assert ca["flops"] < exact / 2
        # collective accounting on a sharded matmul
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import make_mesh
        mesh = make_mesh((2, 2), ("data", "model"))
        def mm(a, b):
            return a @ b
        a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        comp = jax.jit(mm,
            in_shardings=(NamedSharding(mesh, P("data", "model")),
                          NamedSharding(mesh, P("model", None))),
            out_shardings=NamedSharding(mesh, P("data", None))
            ).lower(a, a).compile()
        c = hlo_cost.analyze(comp.as_text(), 4)
        assert abs(c.flops - 2 * 256**3 / 4) / (2 * 256**3 / 4) < 1e-6
        assert c.wire_bytes > 0 and "all-reduce" in c.coll_by_op
        print("PASS")
    """)
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "PASS" in r.stdout
