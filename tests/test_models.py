"""Per-architecture smoke tests (assignment requirement): reduced config,
one forward/train step on CPU, output shapes + no NaNs; decode-vs-parallel
consistency for the cache/state paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.nn import Model, layers as L

ARCHS = sorted(REGISTRY)


def _batch(cfg, key, B=2, S=16):
    if cfg.input_mode == "tokens":
        return {"inputs": jax.random.randint(key, (B, S + 1), 0,
                                             cfg.vocab_size),
                "weights": jnp.ones((B,)) / B}
    return {"inputs": jax.random.normal(key, (B, S, cfg.d_model),
                                        jnp.bfloat16),
            "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
            "weights": jnp.ones((B,)) / B}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    spec = REGISTRY[arch]
    cfg = spec.smoke
    m = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    batch = _batch(cfg, key)
    g, loss, per_ex = jax.jit(m.grad_fn())(params, batch)
    assert np.isfinite(float(loss))
    assert per_ex.shape == (2,)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()
    # one SGD step changes the loss
    params2 = jax.tree.map(lambda p, gg: p - 0.1 * gg.astype(p.dtype),
                           params, g)
    loss2, _ = m.loss(params2, batch)
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_shapes(arch):
    spec = REGISTRY[arch]
    cfg = spec.smoke
    m = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    B = 2
    caches = m.init_caches(B, 32)
    tok = (jnp.zeros((B, 1), jnp.int32) if cfg.input_mode == "tokens"
           else jnp.zeros((B, 1, cfg.d_model), jnp.bfloat16))
    logits, caches2 = jax.jit(m.decode_step)(params, caches, tok,
                                             jnp.int32(3))
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


@pytest.mark.parametrize("arch", ["gemma2-2b", "zamba2-2.7b", "xlstm-1.3b",
                                  "deepseek-v2-lite-16b", "olmoe-1b-7b",
                                  "musicgen-large"])
def test_decode_matches_parallel(arch):
    """Step-by-step decode logits == full parallel forward logits."""
    spec = REGISTRY[arch]
    cfg = spec.smoke
    m = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    B, S = 2, 12
    if cfg.input_mode == "tokens":
        inp = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        step_in = lambda t: inp[:, t:t + 1]
    else:
        inp = jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16)
        step_in = lambda t: inp[:, t:t + 1]
    x, _ = m.forward(params, inp)
    full = L.logits_from(params["embed"], x, cfg)
    caches = m.init_caches(B, S)
    outs = []
    for t in range(S):
        lg, caches = m.decode_step(params, caches, step_in(t), jnp.int32(t))
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["phi3-medium-14b", "zamba2-2.7b",
                                  "xlstm-1.3b"])
def test_prefill_matches_decode_continuation(arch):
    """prefill(prompt) then one decode step == decoding the whole sequence
    token by token."""
    spec = REGISTRY[arch]
    cfg = spec.smoke
    m = Model(cfg)
    key = jax.random.PRNGKey(1)
    params = m.init(key)
    B, S = 2, 8
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    # path A: prefill on S tokens, decode token S
    logits_pre, caches = m.prefill(params, toks[:, :S])
    # caches from prefill have length S; extend by appending a slot
    # (ring wraps: decode at pos S writes slot S % S = 0) — instead compare
    # the prefill last-token logits with the sequential decode at step S-1.
    caches2 = m.init_caches(B, S)
    lg = None
    for t in range(S):
        lg, caches2 = m.decode_step(params, caches2, toks[:, t:t + 1],
                                    jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits_pre, np.float32),
                               np.asarray(lg, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_gemma2_sliding_window_masks():
    """A token further than the window back must not influence local-layer
    attention: degenerate 1-layer-local config."""
    spec = REGISTRY["gemma2-2b"]
    cfg = spec.smoke.scaled(num_layers=2, sliding_window=4,
                            local_global_period=1)  # all local
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 1, 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    x1, _ = m.forward(params, toks)
    # perturb a token far outside every later position's window
    toks2 = toks.at[:, 0].set((toks[:, 0] + 1) % cfg.vocab_size)
    x2, _ = m.forward(params, toks2)
    # receptive field of 2 local layers = 2*(window-1) = 6: positions >= 7
    # are unaffected by token 0
    np.testing.assert_allclose(np.asarray(x1[:, 7:], np.float32),
                               np.asarray(x2[:, 7:], np.float32),
                               rtol=1e-4, atol=1e-4)
    # ...and position 3 (inside the window) IS affected
    assert not np.allclose(np.asarray(x1[:, 3], np.float32),
                           np.asarray(x2[:, 3], np.float32), atol=1e-4)


def test_num_params_full_configs():
    """Full configs match their nameplate sizes (sanity, no allocation)."""
    expect = {
        "gemma2-2b": (2.0e9, 3.5e9),
        "phi3-medium-14b": (12e9, 16e9),
        "qwen1.5-110b": (95e9, 120e9),
        "nemotron-4-15b": (12e9, 18e9),
        "olmoe-1b-7b": (5e9, 8e9),
        "deepseek-v2-lite-16b": (12e9, 18e9),
        "musicgen-large": (1.5e9, 2.8e9),
        "llava-next-34b": (28e9, 38e9),
        "zamba2-2.7b": (2.0e9, 3.4e9),
        "xlstm-1.3b": (1.2e9, 2.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = Model(REGISTRY[arch].config).num_params()
        assert lo <= n <= hi, f"{arch}: {n:,} outside [{lo:,}, {hi:,}]"
