"""End-to-end system tests: train -> checkpoint -> crash -> restore ->
identical continuation (fault tolerance), on a single device."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core import coding, compression as C, error_feedback as EF
from repro.data.tasks import linreg_task


def _mk():
    grad_fn, loss_fn, theta0, _ = linreg_task(seed=0)
    alloc = coding.random_allocation(0, 100, 100, 5)
    W = coding.encode_weights(alloc, 0.2)
    return grad_fn, loss_fn, theta0, W


def _run_steps(st, grad_fn, W, start, n, key):
    for t in range(start, start + n):
        mask = coding.straggler_mask(key, t, 100, 0.2)
        st = EF.cocoef_step(st, grad_fn, W, mask, 1e-5, C.GroupedSign(),
                            step=t)
    return st


def test_checkpoint_restart_bitexact(tmp_path):
    """Training 20 steps straight == training 10, checkpointing, restoring
    in a fresh state, training 10 more.  EF state must be carried."""
    grad_fn, loss_fn, theta0, W = _mk()
    key = jax.random.PRNGKey(42)

    st_full = _run_steps(EF.EFState.init(theta0, 100), grad_fn, W, 0, 20, key)

    st_a = _run_steps(EF.EFState.init(theta0, 100), grad_fn, W, 0, 10, key)
    save_checkpoint(tmp_path, 10, {"theta": st_a.theta, "e": st_a.e})
    step, out = restore_checkpoint(
        tmp_path, {"theta": st_a.theta, "e": st_a.e})
    assert step == 10
    st_b = EF.EFState(theta=out["theta"], e=out["e"])
    st_b = _run_steps(st_b, grad_fn, W, 10, 10, key)

    np.testing.assert_array_equal(np.asarray(st_full.theta),
                                  np.asarray(st_b.theta))
    np.testing.assert_array_equal(np.asarray(st_full.e),
                                  np.asarray(st_b.e))


def test_restore_without_ef_degrades_gracefully(tmp_path):
    """Elastic scenario: EF state dropped (new ranks) -> training still
    converges (Theorem 1 allows e^0 = 0)."""
    grad_fn, loss_fn, theta0, W = _mk()
    key = jax.random.PRNGKey(42)
    st = _run_steps(EF.EFState.init(theta0, 100), grad_fn, W, 0, 30, key)
    st_reset = EF.EFState(theta=st.theta, e=jnp.zeros_like(st.e))
    st2 = _run_steps(st_reset, grad_fn, W, 30, 120, key)
    assert float(loss_fn(st2.theta)) < float(loss_fn(st.theta))


def test_full_straggler_iteration_is_noop():
    """If every device straggles in an iteration (mask all-zero), theta and
    all error vectors are unchanged — the system tolerates total loss of a
    step (extreme fault tolerance case)."""
    grad_fn, loss_fn, theta0, W = _mk()
    st = EF.EFState.init(theta0, 100)
    st = EF.cocoef_step(st, grad_fn, W, jnp.ones((100,)), 1e-5,
                        C.GroupedSign())
    st2 = EF.cocoef_step(st, grad_fn, W, jnp.zeros((100,)), 1e-5,
                         C.GroupedSign())
    np.testing.assert_array_equal(np.asarray(st.theta), np.asarray(st2.theta))
    np.testing.assert_array_equal(np.asarray(st.e), np.asarray(st2.e))
