"""Serving-path coverage: launch/serve.py + launch/dryrun.py smokes.

Neither module had any test before PR 5.  The serve smoke runs a real
prefill + decode on a CPU mesh (subprocess, forced multi-device) and
asserts decode is deterministic and the decode caches keep exactly the
shapes/dtypes `input_specs` advertises; the dryrun smoke lowers+compiles
one full-size (arch, shape) cell on the 256-device production mesh."""
import pytest

from test_distributed import run_sub


def test_serve_prefill_decode_smoke():
    run_sub("""
    from repro.configs import REGISTRY, SMOKE_DECODE
    from repro.launch.serve import build_serve_setup
    mesh = make_mesh((2, 2), ("data", "model"))
    spec = REGISTRY["gemma2-2b"]
    cfg = spec.smoke
    setup = build_serve_setup(spec, mesh, SMOKE_DECODE, smoke=True)
    B, S = setup.batch, setup.seq_len
    key = jax.random.PRNGKey(0)
    params = jax.jit(setup.model.init,
                     out_shardings=setup.param_shardings)(key)

    # prefill: real tokens through the sharded prefill step
    toks = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    jpre = jax.jit(setup.prefill_step,
                   out_shardings=setup.prefill_out_shardings)
    logits_p, caches_p = jpre(params, toks)
    assert logits_p.shape[0] == B
    assert bool(jnp.isfinite(logits_p.astype(jnp.float32)).all())

    # decode: deterministic (same inputs -> bitwise same logits) and the
    # cache pytree matches input_specs exactly (shape AND dtype)
    ispec = setup.input_specs("decode")
    caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                          ispec["caches"])
    tok = jnp.ones((B, 1), jnp.int32)
    jdec = jax.jit(setup.decode_step,
                   out_shardings=setup.decode_out_shardings)
    l1, c1 = jdec(params, caches, tok, jnp.int32(3))
    l2, c2 = jdec(params, caches, tok, jnp.int32(3))
    assert np.array_equal(np.asarray(l1), np.asarray(l2)), \
        "decode must be deterministic"
    got = jax.tree.leaves(c1)
    want = jax.tree.leaves(ispec["caches"])
    assert len(got) == len(want)
    for a, b in zip(got, want):
        assert a.shape == b.shape and a.dtype == b.dtype, (a.shape, b.shape,
                                                           a.dtype, b.dtype)
    # the decode wrote something into the caches
    assert any(float(jnp.abs(x.astype(jnp.float32)).max()) > 0
               for x in got)
    """, devices=4, timeout=900)


@pytest.mark.slow
def test_dryrun_cell_smoke():
    """One full-size dry-run cell (gemma2-2b @ train_4k, single pod):
    lower + compile on 256 virtual devices must succeed and produce the
    cost/roofline record the §Roofline table is built from."""
    run_sub("""
    from repro.launch import dryrun
    rec = dryrun.run_cell("gemma2-2b", "train_4k", multi_pod=False)
    assert rec["status"] == "ok", rec.get("error", rec)
    assert rec["cost"]["flops"] > 0
    assert rec["cost"]["bytes accessed"] > 0
    assert rec["memory"]["peak_estimate_bytes"] > 0
    assert rec["collectives"]["wire_bytes_per_device"] > 0
    assert rec["roofline"]["dominant"] in ("compute", "memory",
                                           "collective")
    assert rec["effective_mode"] == "cocoef"
    """, devices=512, timeout=900)
