"""Substrate tests: checkpointing, data pipeline, optimizers, sharding
rules, cocoef flatten/unflatten."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (elastic_rescale_ef, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.core.cocoef import FlatMeta, flatten_local, padded_size, \
    unflatten_local
from repro.data.pipeline import SyntheticLMConfig, subset_batch_for_rank, \
    synthetic_lm_batch
from repro.optim import OptimizerConfig, apply_update, init_opt_state, \
    lr_schedule


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    state = {
        "params": {"w": jnp.arange(12.0).reshape(3, 4),
                   "b": jnp.ones((4,), jnp.bfloat16)},
        "e": jnp.full((2, 8), 0.5),
        "opt": (jnp.zeros((8,)),),
    }
    save_checkpoint(tmp_path, 7, state, extra={"note": "x"})
    save_checkpoint(tmp_path, 12, state)
    assert latest_step(tmp_path) == 12
    step, out = restore_checkpoint(tmp_path, state, step=7)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_checkpoint_resume_latest(tmp_path):
    s1 = {"x": jnp.ones((4,))}
    save_checkpoint(tmp_path, 1, s1)
    save_checkpoint(tmp_path, 2, {"x": 2 * jnp.ones((4,))})
    step, out = restore_checkpoint(tmp_path, s1)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(out["x"]), 2 * np.ones(4))


def test_elastic_rescale_ef():
    old = np.arange(2 * 4 * 6, dtype=np.float32).reshape(2, 4, 6)
    new = elastic_rescale_ef(old, (2, 4), (3, 4), 8)
    assert new.shape == (3, 4, 8)
    np.testing.assert_array_equal(new[:2, :, :6], old)     # carried
    assert (new[2] == 0).all()                             # new ranks zero
    # shrink
    new2 = elastic_rescale_ef(old, (2, 4), (1, 4), 4)
    np.testing.assert_array_equal(new2[0, :, :4], old[0, :, :4])


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic():
    key = jax.random.PRNGKey(0)
    a = synthetic_lm_batch(key, 5, 4, 16, 1000)
    b = synthetic_lm_batch(key, 5, 4, 16, 1000)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = synthetic_lm_batch(key, 6, 4, 16, 1000)
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    assert a.shape == (4, 17)
    assert int(a.max()) < 1000 and int(a.min()) >= 0


def test_subset_batch_weights():
    key = jax.random.PRNGKey(0)
    toks, w = subset_batch_for_rank(key, 3, np.array([0, 2]),
                                    np.array([0.5, 0.25]), 4, 16, 100)
    assert toks.shape == (8, 17)
    np.testing.assert_allclose(np.asarray(w),
                               [0.5] * 4 + [0.25] * 4)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def test_sgd_and_momentum():
    p = jnp.ones((8,))
    ghat = 0.1 * jnp.ones((8,))
    cfg = OptimizerConfig(kind="sgd")
    p2, _ = apply_update(cfg, p, ghat, (), jnp.int32(0), 0.1)
    np.testing.assert_allclose(np.asarray(p2), 0.9 * np.ones(8), rtol=1e-6)

    cfg = OptimizerConfig(kind="momentum", momentum=0.5)
    st = init_opt_state(cfg, 8)
    p2, st = apply_update(cfg, p, ghat, st, jnp.int32(0), 0.1)
    p3, st = apply_update(cfg, p2, ghat, st, jnp.int32(1), 0.1)
    # second step: m = 0.5*0.1+0.1 = 0.15
    np.testing.assert_allclose(np.asarray(p3), np.asarray(p2) - 0.15,
                               rtol=1e-6)


def test_adam_direction():
    cfg = OptimizerConfig(kind="adam")
    st = init_opt_state(cfg, 4)
    p = jnp.zeros((4,))
    ghat = 0.01 * jnp.asarray([1.0, -1.0, 2.0, 0.0])
    p2, st = apply_update(cfg, p, ghat, st, jnp.int32(0), 0.01)
    assert float(p2[0]) < 0 and float(p2[1]) > 0 and float(p2[3]) == 0


def test_lr_schedules():
    f = lr_schedule("constant", 1e-3)
    assert float(f(0)) == pytest.approx(1e-3)
    assert float(f(100)) == pytest.approx(1e-3)
    f = lr_schedule("rsqrt", 2e-5)
    assert float(f(0)) == pytest.approx(2e-5)
    assert float(f(3)) == pytest.approx(1e-5)
    f = lr_schedule("constant", 1e-3, warmup=10)
    assert float(f(0)) == pytest.approx(1e-4)


# ---------------------------------------------------------------------------
# flatten/unflatten + padding
# ---------------------------------------------------------------------------

def test_flatten_roundtrip():
    leaves = [jnp.arange(6.0).reshape(2, 3),
              jnp.ones((5,), jnp.bfloat16),
              jnp.zeros((1, 2, 2), jnp.float32)]
    flat, meta = flatten_local(leaves, chunk_ranks=4, group_size=32)
    assert flat.shape[0] == padded_size(15, 4, 32)
    out = unflatten_local(flat, meta)
    for a, b in zip(leaves, out):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_padded_size():
    assert padded_size(1, 4, 32) == 128
    assert padded_size(128, 4, 32) == 128
    assert padded_size(129, 4, 32) == 256
    assert padded_size(100, 2, 32, num_buckets=2) == 128


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_rules_fallback_placement():
    """phi3: 40 heads don't divide model=16 -> head_dim gets the axis."""
    import os
    from jax.sharding import PartitionSpec as P
    from repro.configs import REGISTRY
    from repro.nn import Model
    from repro.sharding import rules
    if len(jax.devices()) != 1:
        pytest.skip("single-device rule check")
    from repro.compat import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    # fake axis sizes by monkeypatching through a larger abstract mesh is
    # overkill; check the pure functions instead:
    sizes = {"data": 16, "model": 16}
    spec = rules._check_divisible((None, "model", None), (5120, 40, 128),
                                  sizes)
    assert spec == (None, None, "model")
    spec = rules._check_divisible((None, "model", None), (5120, 48, 128),
                                  sizes)
    assert spec == (None, "model", None)
    spec = rules._check_divisible(("model",), (41,), sizes)
    assert spec == (None,)
