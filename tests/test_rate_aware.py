"""Rate-aware gradient coding (ISSUE 4): the unbiasedness contract of the
per-rank encode weights, the greedy heterogeneity-aware allocator, per-rank
adaptive wire budgets (SparseWire + cost-model solver + per-rank
accounting), construction-time knob validation, and the single definition
of the all-straggler step semantics."""
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import coding, compression as C, error_feedback as EF
from repro.core.collectives import SignWire, SparseWire
from repro.sim import (ComputeProfile, HeterogeneousRates, IIDBernoulli,
                       LinkProfile, MarkovBursty, StepTimer, TraceReplay,
                       get_straggler_process, solve_k_budgets)
from test_distributed import run_sub

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # benchmarks/


# ---------------------------------------------------------------------------
# encode weights: the unbiasedness contract
# ---------------------------------------------------------------------------

def test_encode_weights_uniform_rates_bit_for_bit():
    """rates == (1-p) * ones must reproduce eq. 3 BIT FOR BIT (the iid
    regression guarantee of the rate-aware generalization)."""
    alloc = coding.random_allocation(0, 24, 24, 3)
    for p in (0.0, 0.2, 0.37, 0.7):
        W_eq3 = np.asarray(coding.encode_weights(alloc, p))
        W_rate = np.asarray(coding.encode_weights(
            alloc, rates=np.full(24, 1.0 - p)))
        np.testing.assert_array_equal(W_eq3, W_rate)


@pytest.mark.parametrize("rates", [
    pytest.param(HeterogeneousRates.two_class(
        16, p_slow=0.8, p_fast=0.02, slow_fraction=0.3).rates(),
        id="two_class"),
    pytest.param(HeterogeneousRates.linear(16, 0.3, spread=0.9).rates(),
                 id="linear"),
    pytest.param(np.linspace(0.35, 1.0, 16), id="arbitrary"),
])
def test_rate_aware_weights_unbiased_closed_form(rates):
    """sum_i q_i W[i, k] == 1 for every subset k — the exact condition for
    E[sum_i I_i g_i] = grad F under independent per-rank participation."""
    alloc = coding.random_allocation(1, 16, 16, 3)
    W = np.asarray(coding.encode_weights(alloc, rates=rates), np.float64)
    coeff = np.asarray(rates, np.float64) @ W
    np.testing.assert_allclose(coeff, 1.0, rtol=1e-5)


def test_mean_rate_weights_provably_biased_under_two_class():
    """Eq. 3 with the scalar mean rate is NOT unbiased under a two-class
    fleet: some subset's expectation coefficient deviates from 1 by a
    closed-form margin (the PR-motivating bug)."""
    proc = HeterogeneousRates.two_class(16, p_slow=0.8, p_fast=0.02,
                                        slow_fraction=0.3)
    q = proc.rates()
    alloc = coding.random_allocation(1, 16, 16, 3)
    p_bar = float(1.0 - q.mean())
    W = np.asarray(coding.encode_weights(alloc, p_bar), np.float64)
    coeff = q @ W
    assert np.max(np.abs(coeff - 1.0)) > 0.1


@pytest.mark.parametrize("make,T,atol", [
    pytest.param(lambda: IIDBernoulli(num_devices=16, p=0.3), 1200, 0.5,
                 id="iid"),
    pytest.param(lambda: HeterogeneousRates.two_class(
        16, p_slow=0.8, p_fast=0.02, slow_fraction=0.3), 1200, 0.5,
        id="hetero_two_class"),
    pytest.param(lambda: HeterogeneousRates.linear(16, 0.3, spread=0.9),
                 1200, 0.5, id="hetero_linear"),
    # bursts correlate consecutive masks -> ~mean_burst x fewer effective
    # samples, hence the looser tolerance
    pytest.param(lambda: MarkovBursty(num_devices=16, p=0.3, mean_burst=4.0),
                 2400, 1.0, id="markov"),
])
def test_rate_aware_ghat_empirically_unbiased(make, T, atol, rng_key):
    """Property test of the whole aggregation: the mean over >= 1k sampled
    masks of ghat = sum_i I_i g_i matches the dense gradient under the
    rate-aware weights for EVERY straggler process — and provably does not
    under mean-rate eq. 3 for the two-class fleet."""
    proc = make()
    N, D = 16, 8
    alloc = coding.random_allocation(2, N, N, 3)
    rng = np.random.default_rng(3)
    grads = rng.normal(size=(N, D))               # per-subset gradients
    dense = grads.sum(0)                          # grad F
    tr = np.asarray(proc.sample_trace(rng_key, T), np.float64)  # (T, N)
    assert tr.shape[0] >= 1000

    W = np.asarray(coding.encode_weights(
        alloc, rates=np.asarray(proc.rates())), np.float64)
    ghat_mean = (tr @ (W @ grads)) .mean(axis=0)
    scale = np.abs(dense).max()
    np.testing.assert_allclose(ghat_mean, dense, atol=atol * scale / 10)

    if isinstance(proc, HeterogeneousRates) and len(set(proc.p_ranks)) > 1:
        p_bar = float(1.0 - proc.rates().mean())
        W_mean = np.asarray(coding.encode_weights(alloc, p_bar), np.float64)
        bias = np.abs((tr @ (W_mean @ grads)).mean(axis=0) - dense).max()
        assert bias > 2 * atol * scale / 10       # clearly outside tolerance


def test_trace_replay_rate_aware_exactly_unbiased_over_one_cycle(rng_key):
    """TraceReplay.rates() is the trace's empirical marginal, so averaging
    ghat over exactly one replay cycle recovers the dense gradient to f32
    weight precision — the strongest form of the contract."""
    rows = np.array(HeterogeneousRates.two_class(
        8, p_slow=0.7, p_fast=0.1).sample_trace(rng_key, 32))
    rows[0] = 1.0                                 # every rank covered
    proc = TraceReplay.from_array(rows)
    alloc = coding.random_allocation(4, 8, 8, 3)
    W = np.asarray(coding.encode_weights(
        alloc, rates=np.asarray(proc.rates())), np.float64)
    grads = np.random.default_rng(5).normal(size=(8, 5))
    tr = np.asarray(proc.sample_trace(rng_key, proc.length), np.float64)
    ghat_mean = (tr @ (W @ grads)).mean(axis=0)
    np.testing.assert_allclose(ghat_mean, grads.sum(0), rtol=1e-5)


def test_encode_weights_validation():
    alloc = coding.random_allocation(0, 8, 8, 2)
    with pytest.raises(ValueError):               # neither given
        coding.encode_weights(alloc)
    with pytest.raises(ValueError):               # both given
        coding.encode_weights(alloc, 0.1, rates=np.ones(8))
    with pytest.raises(ValueError):               # wrong length
        coding.encode_weights(alloc, rates=np.ones(5))
    with pytest.raises(ValueError):               # out of range
        coding.encode_weights(alloc, rates=np.full(8, 1.5))
    # a subset whose every holder has rate 0 has no unbiased weighting
    dead = np.ones(8)
    dead[np.nonzero(alloc.S[:, 0])[0]] = 0.0
    with pytest.raises(ValueError):
        coding.encode_weights(alloc, rates=dead)


# ---------------------------------------------------------------------------
# rate-aware allocator: greedy expected-coverage maximization
# ---------------------------------------------------------------------------

def test_rate_aware_allocation_budget_and_coverage():
    q = HeterogeneousRates.two_class(16, p_slow=0.8, p_fast=0.02,
                                     slow_fraction=0.3).rates()
    d = 3
    alloc = coding.rate_aware_allocation(q, 16, d)
    assert alloc.S.shape == (16, 16)
    assert int(alloc.S.sum()) == d * 16           # same replica budget
    assert (alloc.d >= 1).all()
    cov = coding.expected_coverage(alloc, q)
    cov_cyc = coding.expected_coverage(coding.cyclic_allocation(16, 16, d), q)
    assert cov.mean() > cov_cyc.mean()            # strictly better placement
    assert cov.min() >= cov_cyc.min()


def test_rate_aware_allocation_extra_redundancy_on_unreliable_ranks():
    """The redundancy concentrates where the fleet is weak: every subset
    homed on an unreliable rank acquires a reliable holder (cyclic leaves
    some covered only by slow ranks), subsets homed on slow ranks carry at
    least as many replicas as fast-homed ones, and the worst-subset
    coverage is lifted far above cyclic's."""
    N, d = 16, 3
    n_slow = 5
    q = np.array([0.2] * n_slow + [0.98] * (N - n_slow))
    alloc = coding.rate_aware_allocation(q, N, d)
    d_k = alloc.d
    assert d_k[:n_slow].mean() >= d_k[n_slow:].mean()
    assert d_k.max() > d_k.min()                  # non-uniform redundancy
    for k in range(n_slow):                       # slow-homed subsets get
        assert alloc.S[n_slow:, k].sum() >= 1     # a reliable holder
    cov = coding.expected_coverage(alloc, q)
    cov_cyc = coding.expected_coverage(coding.cyclic_allocation(N, N, d), q)
    assert cov.min() > 0.99 > cov_cyc.min()


def test_rate_aware_allocation_validation_and_determinism():
    with pytest.raises(ValueError):
        coding.rate_aware_allocation(np.array([0.5, 1.5]), 4, 2)
    with pytest.raises(ValueError):
        coding.rate_aware_allocation(np.array([]), 4, 2)
    q = np.linspace(0.3, 1.0, 8)
    a1 = coding.rate_aware_allocation(q, 8, 3)
    a2 = coding.rate_aware_allocation(q, 8, 3)
    np.testing.assert_array_equal(a1.S, a2.S)     # deterministic
    # uniform rates degrade gracefully to a valid balanced allocation
    u = coding.rate_aware_allocation(np.full(8, 0.7), 8, 3)
    assert int(u.S.sum()) == 24 and (u.d >= 1).all()


# ---------------------------------------------------------------------------
# per-rank adaptive wire budgets
# ---------------------------------------------------------------------------

def test_sparse_wire_per_rank_budget_semantics(rng_key):
    wire = SparseWire(k_per_block=(2, 8), block_size=64)
    assert wire.has_rank_budgets() and wire.k_max == 8
    assert not SparseWire(k_per_block=8).has_rank_budgets()
    x = jax.random.normal(rng_key, (256,))
    payload = wire.pack(x)
    assert payload[1].shape == (4, 8)             # k_max payload shape
    p0 = wire.apply_rank_budget(payload, 0)
    assert np.all(np.asarray(p0[1])[:, 2:] == 0)  # beyond budget zeroed
    np.testing.assert_array_equal(np.asarray(p0[1])[:, :2],
                                  np.asarray(payload[1])[:, :2])
    # the truncated payload decodes to exactly the scalar-k wire's roundtrip
    np.testing.assert_array_equal(np.asarray(wire.unpack(p0)),
                                  np.asarray(wire.for_rank(0).roundtrip(x)))
    # rank 1 keeps the full budget
    p1 = wire.apply_rank_budget(payload, 1)
    np.testing.assert_array_equal(np.asarray(p1[1]),
                                  np.asarray(payload[1]))
    # traced rank index (the shard_map path)
    p0j = jax.jit(lambda r: wire.apply_rank_budget(payload, r))(jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(p0j[1]), np.asarray(p0[1]))


def test_sparse_wire_per_rank_bytes_accounting():
    wire = SparseWire(k_per_block=(2, 4, 8, 16), block_size=512)
    n = 4096
    per = wire.rank_wire_bytes(n, 4)
    for i, k in enumerate((2, 4, 8, 16)):
        assert per[i] == SparseWire(k_per_block=k,
                                    block_size=512).wire_bytes(n)
    assert wire.wire_bytes(n) == per.max()        # shipped payload shape
    assert np.all(np.diff(per) > 0)               # monotone in budget
    with pytest.raises(ValueError):
        wire.rank_wire_bytes(n, 5)                # rank-count mismatch
    with pytest.raises(ValueError):
        SparseWire(k_per_block=(4, 0), block_size=64)   # bad budget
    with pytest.raises(ValueError):
        SparseWire(k_per_block=(), block_size=64)       # empty


def test_solve_k_budgets_slow_uplinks_send_less():
    link = LinkProfile(rank_bandwidth_gbps=(10.0, 5.0, 2.5, 20.0))
    n = 1 << 16
    ks = solve_k_budgets(n, 4, link, block_size=512, k_ref=8)
    assert ks == (8, 3, 1, 16)
    # equal-time property: every rank's uplink fits the reference deadline
    wire = SparseWire(k_per_block=ks, block_size=512)
    deadline = link.up_s(SparseWire(k_per_block=8,
                                    block_size=512).wire_bytes(n))
    up = link.up_s_ranks(wire.rank_wire_bytes(n, 4))
    assert np.all(up <= deadline + 1e-12)
    # uniform link reproduces the reference budget on every rank
    assert solve_k_budgets(n, 4, LinkProfile(), block_size=512,
                           k_ref=8) == (8,) * 4
    with pytest.raises(ValueError):
        solve_k_budgets(n + 1, 4, link, block_size=512)
    with pytest.raises(ValueError):
        solve_k_budgets(n, 4, link, deadline_s=0.0)


def test_link_profile_per_rank_validation():
    with pytest.raises(ValueError):
        LinkProfile(rank_bandwidth_gbps=(10.0, -1.0))
    with pytest.raises(ValueError):
        LinkProfile(bandwidth_gbps=0.0)
    link = LinkProfile(rank_bandwidth_gbps=(10.0, 5.0))
    with pytest.raises(ValueError):
        link.up_bandwidths(3)


def test_step_timer_per_rank_wire_and_link_accounting():
    """Phase-1 time = the slowest PARTICIPATING uplink (per-rank bytes over
    per-rank bandwidth); the bytes ledger charges each participant its own
    budgeted bytes."""
    wire = SparseWire(k_per_block=(2, 4, 8, 16), block_size=512)
    n = 4096
    link = LinkProfile(rank_bandwidth_gbps=(1.0, 2.0, 4.0, 8.0),
                       down_bandwidth_gbps=100.0, latency_s=1e-3)
    comp = ComputeProfile(grad_s=2e-3)
    timer = StepTimer(wire=wire, n=n, link=link, compute=comp)
    per = timer.bytes_up_ranks(4)
    up = link.up_s_ranks(per)
    down = link.down_s(timer.bytes_down())
    t_full = timer.step_time([1, 1, 1, 1])
    assert t_full == pytest.approx(2e-3 + up.max() + down)
    # masking out the slowest uplink removes it from the critical path
    slowest = int(np.argmax(up))
    m = np.ones(4)
    m[slowest] = 0.0
    rest = np.delete(up, slowest)
    assert timer.step_time(m) == pytest.approx(2e-3 + rest.max() + down)
    # ledger: each participant charges its own per-rank bytes
    tr = np.array([[1.0, 0.0, 1.0, 1.0]])
    _, b_up, _ = timer.steps(tr)
    assert b_up[0] == per[0] + per[2] + per[3]


def test_cocoef_update_per_rank_budgets_match_oracle():
    """cocoef_update with a per-rank k_per_block tuple must equal the
    manual oracle on a real mesh: each rank packs at k_max, zeroes values
    beyond ITS budget, and the truncation feeds its error vector."""
    run_sub("""
    from jax.sharding import PartitionSpec as P
    from repro.core.cocoef import CocoEFConfig, cocoef_update
    from repro.core.collectives import SparseWire
    n, nd = 512, 4
    ks = (2, 4, 8, 16)
    cfg = CocoEFConfig(coding_axes=("data",), group_size=32,
                       compressor="block_topk", k_per_block=ks,
                       block_size=64, backend="jnp", mode="cocoef")
    mesh = make_mesh((4, 2), ("data", "model"))
    mask = jnp.array([1., 0., 1., 1.])
    g = jax.random.normal(jax.random.PRNGKey(1), (8 * n,))
    e = jax.random.normal(jax.random.PRNGKey(2), (8 * n,)) * 0.1
    gamma = 0.1

    f = shard_map(lambda gg, ee: cocoef_update(gg, ee, mask, gamma, cfg),
                  mesh, in_specs=(P(("data", "model")),) * 2,
                  out_specs=(P(("data", "model")),) * 2,
                  axis_names={"data", "model"}, check=False)
    ghat, e_new = jax.jit(f)(g, e)

    # oracle: per coding rank, budget-k roundtrip + EF; ghat = masked sum
    wire = SparseWire(k_per_block=ks, block_size=64)
    acc = (gamma * g + e).reshape(nd, 2 * n)      # (rank, local on 2 shards)
    cs, e_ref = [], []
    for i in range(nd):
        c_i = wire.for_rank(i).roundtrip(acc[i])
        cs.append(c_i)
        e_ref.append(jnp.where(mask[i] > 0, acc[i] - c_i,
                               e.reshape(nd, 2 * n)[i]))
    ghat_ref = sum(m * c for m, c in zip(mask, cs))
    ghat2 = np.asarray(ghat).reshape(nd, 2 * n)
    for i in range(nd):
        assert np.allclose(ghat2[i], np.asarray(ghat_ref), atol=1e-5), i
    assert np.allclose(np.asarray(e_new).reshape(nd, 2 * n),
                       np.asarray(jnp.stack(e_ref)), atol=1e-6)

    # a budget tuple shorter than the coding-rank count must raise (jnp's
    # clamped indexing would otherwise silently reuse the last budget)
    bad = CocoEFConfig(coding_axes=("data",), group_size=32,
                       compressor="block_topk", k_per_block=(2, 4),
                       block_size=64, backend="jnp", mode="cocoef")
    fb = shard_map(lambda gg, ee: cocoef_update(gg, ee, mask, gamma, bad),
                   mesh, in_specs=(P(("data", "model")),) * 2,
                   out_specs=(P(("data", "model")),) * 2,
                   axis_names={"data", "model"}, check=False)
    try:
        jax.jit(fb)(g, e)
        raise AssertionError("short per-rank budget tuple not caught")
    except ValueError as err:
        assert "per-rank budgets" in str(err)
    """, timeout=600)


# ---------------------------------------------------------------------------
# construction-time knob validation (TrainRun / registry / processes)
# ---------------------------------------------------------------------------

def test_train_run_validates_at_construction():
    from repro.launch.train import TrainRun
    TrainRun()                                        # defaults are valid
    with pytest.raises(ValueError):
        TrainRun(mode="nope")
    with pytest.raises(ValueError):
        TrainRun(straggler="bogus")
    with pytest.raises(ValueError):
        TrainRun(straggler_burst=0.5)
    with pytest.raises(ValueError):
        TrainRun(straggler_spread=-0.1)
    with pytest.raises(ValueError):
        TrainRun(backend="tpu")
    with pytest.raises(ValueError):
        TrainRun(num_buckets=0)
    with pytest.raises(ValueError):
        TrainRun(k_budgets=(4, 0, 2))


def test_straggler_knob_validation():
    with pytest.raises(ValueError):
        get_straggler_process("iid", 4, p=1.2)
    with pytest.raises(ValueError):
        IIDBernoulli(num_devices=4, p=-0.1)
    # spread that pushes a p_i out of [0, 1) fails loudly (used to be
    # silently clipped, surfacing later as biased marginals)
    with pytest.raises(ValueError):
        HeterogeneousRates.linear(8, 0.5, spread=1.5)
    with pytest.raises(ValueError):
        HeterogeneousRates.linear(8, 0.5, spread=-0.2)
    with pytest.raises(ValueError):
        get_straggler_process("hetero", 8, 0.6, spread=0.8)  # hi = 1.08
    # still-valid edges keep working
    assert HeterogeneousRates.linear(8, 0.4, spread=1.0).p_ranks[0] == 0.0


# ---------------------------------------------------------------------------
# all-straggler step: ONE semantics, end to end
# ---------------------------------------------------------------------------

def test_all_straggler_step_semantics(rng_key):
    """An all-zero mask row means: the server waits out the slowest
    compute window (timeout), zero uplink seconds AND bytes, the broadcast
    still goes out, the model update is ghat = 0, and every error vector
    is untouched — one definition across timer, trace, and dynamics."""
    rows = np.ones((6, 4))
    rows[2] = 0.0                                 # recorded total outage
    proc = TraceReplay.from_array(rows)
    comp = ComputeProfile(grad_s=3e-3, speed_factors=(1.0, 2.0, 1.0, 4.0))
    timer = StepTimer(wire=SignWire(group_size=32), n=1 << 10, compute=comp)
    tr = proc.sample_trace(rng_key, 6)
    times, b_up, b_down = timer.steps(tr)
    down = timer.link.down_s(timer.bytes_down())
    up = timer.link.up_s(timer.bytes_up())
    assert times[2] == pytest.approx(3e-3 * 4.0 + down)      # timeout+bcast
    assert times[0] == pytest.approx(3e-3 * 4.0 + up + down)
    assert b_up[2] == 0.0                                    # nothing sent
    assert b_down[2] == 4 * timer.bytes_down()               # still bcast

    # dynamics: reference COCO-EF step with the outage mask is a no-op on
    # theta AND on every error vector
    grad_fn_mat = np.random.default_rng(0).normal(size=(4, 6)).astype(
        np.float32)
    grad_fn = lambda th: jnp.asarray(grad_fn_mat) * (1.0 + 0.0 * th.sum())
    alloc = coding.cyclic_allocation(4, 4, 2)
    W = coding.encode_weights(alloc, rates=np.asarray(proc.rates()))
    st = EF.EFState.init(jnp.ones((6,)), 4)
    st = EF.cocoef_step(st, grad_fn, W, jnp.asarray(rows[0]), 0.1,
                        C.GroupedSign(group_size=2), step=0)
    st2 = EF.cocoef_step(st, grad_fn, W, jnp.asarray(rows[2]), 0.1,
                         C.GroupedSign(group_size=2), step=2)
    np.testing.assert_array_equal(np.asarray(st2.theta), np.asarray(st.theta))
    np.testing.assert_array_equal(np.asarray(st2.e), np.asarray(st.e))


def test_all_straggler_step_through_cocoef_update():
    """The production aggregation under an all-zero mask: ghat == 0 and the
    error state bit-for-bit unchanged, on a real mesh."""
    run_sub("""
    from jax.sharding import PartitionSpec as P
    from repro.core.cocoef import CocoEFConfig, cocoef_update
    mesh = make_mesh((4, 2), ("data", "model"))
    n = 1024
    cfg = CocoEFConfig(coding_axes=("data",), group_size=32,
                       compressor="sign", backend="jnp")
    g = jax.random.normal(jax.random.PRNGKey(4), (8 * n,))
    e = jax.random.normal(jax.random.PRNGKey(5), (8 * n,)) * 0.1
    zero = jnp.zeros((4,))
    f = shard_map(lambda gg, ee: cocoef_update(gg, ee, zero, 0.1, cfg),
                  mesh, in_specs=(P(("data", "model")),) * 2,
                  out_specs=(P(("data", "model")),) * 2,
                  axis_names={"data", "model"}, check=False)
    ghat, e_new = jax.jit(f)(g, e)
    assert np.all(np.asarray(ghat) == 0.0)
    assert np.array_equal(np.asarray(e_new), np.asarray(e))
    """, timeout=600)


# ---------------------------------------------------------------------------
# rate threading through the production setup
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fig9_smoke_rate_aware_no_later(tmp_path):
    """The fig9 acceptance contract: rate-aware COCO-EF reaches the target
    loss NO LATER than mean-rate under every non-iid process, and under
    markov (uniform rates) the two are bit-for-bit the same trajectory."""
    from benchmarks import fig9_hetero_sweep as f9
    res = f9.run(smoke=True, out_dir=tmp_path)
    assert (tmp_path / "fig9.json").exists()
    assert set(res["curves"]) == {"hetero", "markov", "trace"}
    for pname, s in res["summary"].items():
        t = s["time_to_target_s"]
        assert t["rate_aware"] is not None
        assert t["mean_rate"] is None or \
            t["rate_aware"] <= t["mean_rate"] + 1e-9, pname
        # the closed-form weight bias: zero for rate-aware, nonzero for
        # mean-rate exactly when the process is genuinely heterogeneous
        assert s["weight_bias_max"]["rate_aware"] < 1e-5
        if pname != "markov":
            assert s["weight_bias_max"]["mean_rate"] > 0.05
    m = res["curves"]["markov"]
    assert m["rate_aware"]["loss"] == m["mean_rate"]["loss"]


@pytest.mark.slow
def test_build_train_setup_threads_rates():
    """build_train_setup under a hetero process carries the process's
    per-rank rates into CocoEFConfig (rate_aware=True default) and drops
    them with rate_aware=False; k_budgets overrides k_per_block."""
    run_sub("""
    import dataclasses
    from repro.configs import REGISTRY
    from repro.configs.common import ShapeCfg
    from repro.launch.train import TrainRun, build_train_setup
    spec = REGISTRY["olmoe-1b-7b"]
    spec = dataclasses.replace(spec, coding=dataclasses.replace(
        spec.coding, group_size=32, block_size=64, k_per_block=8,
        straggler_p=0.2))
    shape = ShapeCfg("train", seq_len=64, global_batch=16)
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))

    setup = build_train_setup(spec, mesh, shape,
                              TrainRun(straggler="hetero",
                                       straggler_spread=0.5), smoke=True)
    proc = setup.straggler_process
    rates = setup.cocoef_cfg.straggler_rates
    assert rates is not None and len(rates) == setup.n_code
    np.testing.assert_allclose(rates, proc.rates())
    assert len(set(rates)) > 1            # genuinely per-rank

    off = build_train_setup(spec, mesh, shape,
                            TrainRun(straggler="hetero",
                                     rate_aware=False), smoke=True)
    assert off.cocoef_cfg.straggler_rates is None

    kb = build_train_setup(spec, mesh, shape,
                           TrainRun(compressor="block_topk",
                                    k_budgets=(2, 4, 8, 16)), smoke=True)
    assert kb.cocoef_cfg.k_per_block == (2, 4, 8, 16)
    try:
        build_train_setup(spec, mesh, shape,
                          TrainRun(compressor="block_topk",
                                   k_budgets=(2, 4)), smoke=True)
        raise AssertionError("k_budgets length mismatch not caught")
    except ValueError:
        pass
    try:
        build_train_setup(spec, mesh, shape,
                          TrainRun(k_budgets=(2, 4, 8, 16)), smoke=True)
        raise AssertionError("k_budgets on a non-sparse wire not caught")
    except ValueError:
        pass
    """, timeout=600)
