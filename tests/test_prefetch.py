"""Host->device batch prefetcher + coded batch stream.

`pipeline.prefetch_to_device` stages device_put one step ahead on a
background thread; consuming it must be INDISTINGUISHABLE from mapping
device_put over the source iterator — same order, same values, exceptions
re-raised at the consumer — and abandoning it early must not leak a
blocked worker thread.  `pipeline.coded_batch_stream` is the generator
half: deterministic in (key, step), so prefetch depth can never change
what any step trains on.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import coding
from repro.data import pipeline


def _no_prefetch_threads(timeout_s: float = 3.0) -> bool:
    """Wait for every repro-prefetch worker to wind down."""
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if not [t for t in threading.enumerate()
                if t.name == "repro-prefetch" and t.is_alive()]:
            return True
        time.sleep(0.05)
    return False


def test_prefetch_preserves_order_and_values():
    items = [np.full((4,), i, np.float32) for i in range(10)]
    out = list(pipeline.prefetch_to_device(iter(items), size=2))
    assert len(out) == 10
    for i, o in enumerate(out):
        assert isinstance(o, jax.Array)        # device-resident
        np.testing.assert_array_equal(np.asarray(o), items[i])
    assert _no_prefetch_threads()


def test_prefetch_matches_direct_device_put_on_pytrees():
    def gen():
        for i in range(6):
            yield {"toks": np.arange(3, dtype=np.int32) + i,
                   "w": (np.ones(2, np.float32) * i,)}

    direct = [jax.device_put(b) for b in gen()]
    staged = list(pipeline.prefetch_to_device(gen(), size=3))
    assert len(direct) == len(staged)
    for d, p in zip(direct, staged):
        for x, y in zip(jax.tree.leaves(d), jax.tree.leaves(p)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_prefetch_reraises_source_exception():
    def gen():
        yield np.zeros(2, np.float32)
        raise RuntimeError("synthetic pipeline failure")

    it = pipeline.prefetch_to_device(gen(), size=2)
    np.testing.assert_array_equal(np.asarray(next(it)), np.zeros(2))
    with pytest.raises(RuntimeError, match="synthetic pipeline failure"):
        next(it)
    assert _no_prefetch_threads()


def test_prefetch_early_abandonment_stops_worker():
    """Closing the consumer generator mid-stream (the crash-resume path)
    must unblock and terminate the worker even though the source is
    infinite and the queue is full."""
    produced = []

    def gen():
        i = 0
        while True:
            produced.append(i)
            yield np.full((2,), i, np.float32)
            i += 1

    it = pipeline.prefetch_to_device(gen(), size=2)
    next(it)
    next(it)
    it.close()                      # generator finally -> stop event
    assert _no_prefetch_threads()
    n_after_close = len(produced)
    time.sleep(0.2)                 # a leaked worker would keep producing
    assert len(produced) == n_after_close


def test_prefetch_size_validation():
    with pytest.raises(ValueError):
        next(pipeline.prefetch_to_device(iter([]), size=0))


def test_prefetch_stall_counters_name_the_bottleneck():
    """PR 6 regression guard: a stalled prefetch worker used to be
    invisible — steps just ran slower.  The stats now name the bottleneck
    side: a slow PRODUCER accumulates consumer_wait_s (the step blocked on
    an empty queue — the stall prefetch exists to remove), a slow CONSUMER
    accumulates producer_wait_s with the queue at its high-water mark."""
    def slow_gen(n, delay):
        for i in range(n):
            time.sleep(delay)
            yield np.full((2,), i, np.float32)

    # producer-bound: the consumer drains faster than the worker produces
    it = pipeline.prefetch_to_device(slow_gen(5, 0.05), size=2)
    assert len(list(it)) == 5
    s = it.stats.snapshot()
    assert s["put_count"] == 5 and s["get_count"] == 5
    assert s["consumer_wait_s"] >= 0.1          # ~5 x 50ms empty-queue waits
    assert s["device_put_s"] >= 0.0
    assert _no_prefetch_threads()

    # consumer-bound: instant producer, slow consumer -> full queue
    it = pipeline.prefetch_to_device(
        (np.full((2,), i, np.float32) for i in range(6)), size=2)
    time.sleep(0.3)                 # worker fills the queue, then blocks
    got = []
    for x in it:
        got.append(x)
        time.sleep(0.05)
    s = it.stats.snapshot()
    assert len(got) == 6
    assert s["max_depth"] == 2                  # queue ran at capacity
    assert s["producer_wait_s"] >= 0.1          # worker blocked on q.put
    assert s["depth_sum"] >= s["get_count"]     # consumer mostly found depth
    assert _no_prefetch_threads()


def test_coded_batch_stream_matches_per_step_batches():
    """The stream at any start_step yields exactly coded_train_batch(t):
    prefetching is a pure reordering of WHEN batches are built."""
    N, d, p = 4, 2, 0.25
    alloc = coding.cyclic_allocation(N, N, d)
    W = coding.encode_weights(alloc, p)
    key = jax.random.PRNGKey(0)
    stream = pipeline.coded_batch_stream(key, alloc, W, per_subset=2,
                                         seq_len=8, vocab=97, start_step=3)
    for t in range(3, 7):
        toks, wts = next(stream)
        rt, rw = pipeline.coded_train_batch(key, t, alloc, W, 2, 8, 97)
        np.testing.assert_array_equal(np.asarray(toks), np.asarray(rt))
        np.testing.assert_array_equal(np.asarray(wts), np.asarray(rw))


def test_prefetched_coded_stream_end_to_end():
    """prefetch(coded_batch_stream) == the synchronous loop, batch for
    batch — the exact composition launch.train.batch_stream runs."""
    N, d, p = 4, 4, 0.2
    alloc = coding.cyclic_allocation(N, N, d)
    W = coding.encode_weights(alloc, p)
    key = jax.random.PRNGKey(7)
    it = pipeline.prefetch_to_device(
        pipeline.coded_batch_stream(key, alloc, W, 2, 8, 61), size=2)
    for t in range(5):
        toks, wts = next(it)
        rt, rw = pipeline.coded_train_batch(key, t, alloc, W, 2, 8, 61)
        np.testing.assert_array_equal(np.asarray(toks), np.asarray(rt))
        np.testing.assert_array_equal(np.asarray(wts), np.asarray(rw))
    it.close()
    assert _no_prefetch_threads()
