"""Unit + property tests for the compression functions (Sec. III / Asm. 5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import compression as C

jax.config.update("jax_enable_x64", False)


def _rand(n, seed=0, scale=3.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), (n,))


# ---------------------------------------------------------------------------
# contraction property:  ||C(x) - x||^2 <= delta ||x||^2   (Assumption 5)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), group=st.sampled_from([4, 16, 64]),
       logn=st.integers(6, 10))
def test_grouped_sign_contraction(seed, group, logn):
    n = (1 << logn)
    n = (n // group) * group
    x = np.asarray(_rand(n, seed))
    c = np.asarray(C.GroupedSign(group_size=group).apply(jnp.asarray(x)))
    delta = C.GroupedSign(group_size=group).delta(n)
    lhs = np.sum((c - x) ** 2)
    rhs = delta * np.sum(x ** 2)
    assert lhs <= rhs * (1 + 1e-4) + 1e-6


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(1, 32))
def test_topk_contraction(seed, k):
    n = 128
    x = np.asarray(_rand(n, seed))
    comp = C.TopK(k=k)
    c = np.asarray(comp.apply(jnp.asarray(x)))
    assert np.sum((c - x) ** 2) <= comp.delta(n) * np.sum(x ** 2) + 1e-6
    assert (c != 0).sum() <= k


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(1, 16),
       block=st.sampled_from([32, 64, 128]))
def test_block_topk_contraction(seed, k, block):
    n = block * 8
    x = np.asarray(_rand(n, seed))
    comp = C.BlockTopK(k_per_block=k, block_size=block)
    c = np.asarray(comp.apply(jnp.asarray(x)))
    assert np.sum((c - x) ** 2) <= comp.delta(n) * np.sum(x ** 2) + 1e-6
    nnz = (c.reshape(-1, block) != 0).sum(-1)
    assert (nnz <= k).all()


def test_topk_keeps_largest():
    x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05, 1.0])
    c = np.asarray(C.TopK(k=2).apply(x))
    assert set(np.nonzero(c)[0].tolist()) == {1, 3}
    assert c[1] == -5.0 and c[3] == 3.0


def test_grouped_sign_value():
    x = jnp.asarray([1.0, -2.0, 3.0, -4.0])
    c = np.asarray(C.GroupedSign(group_size=4).apply(x))
    np.testing.assert_allclose(c, [2.5, -2.5, 2.5, -2.5], rtol=1e-6)
    # two groups
    c2 = np.asarray(C.GroupedSign(group_size=2).apply(x))
    np.testing.assert_allclose(c2, [1.5, -1.5, 3.5, -3.5], rtol=1e-6)


# ---------------------------------------------------------------------------
# unbiasedness of the baseline compressors (Monte Carlo)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("comp", [C.StochasticSign(), C.RandK(k=16)])
def test_unbiased_mc(comp):
    n, reps = 64, 4000
    x = _rand(n, seed=3, scale=1.0)
    keys = jax.random.split(jax.random.PRNGKey(0), reps)
    samples = jax.vmap(lambda k: comp.apply(x, k))(keys)
    mean = samples.mean(0)
    se = samples.std(0) / np.sqrt(reps)
    err = np.abs(np.asarray(mean - x))
    assert (err <= 6 * np.asarray(se) + 5e-3).mean() > 0.97


# ---------------------------------------------------------------------------
# wire size accounting
# ---------------------------------------------------------------------------

def test_wire_bits():
    assert C.GroupedSign().wire_bits(100) == 100 + 32          # M0 = 1
    assert C.GroupedSign(group_size=50).wire_bits(100) == 100 + 64
    assert C.TopK(k=2).wire_bits(100) == 2 * 64
    assert C.Identity().wire_bits(100) == 3200
    # equal-overhead pairs used in Sec. V
    assert (C.GroupedSign().wire_bits(100)
            == C.StochasticSign().wire_bits(100))


def test_registry():
    assert isinstance(C.get_compressor("sign"), C.GroupedSign)
    assert isinstance(C.get_compressor("topk", k=3), C.TopK)
    with pytest.raises(KeyError):
        C.get_compressor("nope")
