"""WireFormat layer: roundtrip fidelity, wire-bit accounting vs the
compressor contracts, degenerate shapes/values, and Pallas-vs-jnp parity
for the sparse (top-K) wire kernels."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression as C
from repro.core.collectives import (DenseWire, SignWire, SparseWire,
                                    get_wire, wire_for_compressor)
from repro.kernels import ref
from repro.kernels.topk_pack import topk_decode_reduce, topk_pack

WIRES = [
    pytest.param(SignWire(group_size=32), id="sign32"),
    pytest.param(SignWire(group_size=128), id="sign128"),
    pytest.param(SparseWire(k_per_block=4, block_size=64), id="sparse4of64"),
    pytest.param(SparseWire(k_per_block=8, block_size=128,
                            value_dtype="bfloat16"), id="sparse8of128bf16"),
    pytest.param(DenseWire(), id="dense_f32"),
    pytest.param(DenseWire(value_dtype="bfloat16"), id="dense_bf16"),
]


def _rand(n, seed=0, scale=2.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), (n,))


# ---------------------------------------------------------------------------
# roundtrip fidelity: the wire realizes its compressor, and is idempotent
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wire", WIRES)
def test_roundtrip_idempotent(wire):
    n = 1024
    x = _rand(n, seed=1)
    c1 = np.asarray(wire.roundtrip(x))
    c2 = np.asarray(wire.roundtrip(jnp.asarray(c1)))
    np.testing.assert_allclose(c2, c1, rtol=3e-7, atol=1e-7)


def test_sign_wire_equals_grouped_sign():
    n, g = 1024, 32
    x = _rand(n, seed=2)
    rt = np.asarray(SignWire(group_size=g).roundtrip(x))
    comp = np.asarray(C.GroupedSign(group_size=g).apply(x))
    np.testing.assert_allclose(rt, comp, rtol=1e-6)


def test_sparse_wire_equals_block_topk():
    n, k, b = 1024, 4, 64
    x = _rand(n, seed=3)
    rt = np.asarray(SparseWire(k_per_block=k, block_size=b).roundtrip(x))
    comp = np.asarray(C.BlockTopK(k_per_block=k, block_size=b).apply(x))
    # same support (the selected coordinates), values to ~1 ulp of the
    # per-block scale normalization
    np.testing.assert_array_equal(rt != 0, comp != 0)
    np.testing.assert_allclose(rt, comp, rtol=3e-7, atol=1e-7)


def test_dense_wire_f32_is_lossless():
    x = _rand(512, seed=4)
    np.testing.assert_array_equal(np.asarray(DenseWire().roundtrip(x)),
                                  np.asarray(x))


def test_stochastic_sign_rides_sign_wire_lossless():
    """Unbiased stochastic sign outputs are ±m per group -> exactly
    representable on the sign wire (equal-overhead baseline of Sec. V)."""
    n, g = 512, 32
    x = _rand(n, seed=5)
    q = C.StochasticSign(group_size=g).apply(x, key=jax.random.PRNGKey(9))
    wire = wire_for_compressor(C.StochasticSign(group_size=g), n)
    np.testing.assert_allclose(np.asarray(wire.roundtrip(q)), np.asarray(q),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# wire accounting vs Compressor.wire_bits
# ---------------------------------------------------------------------------

def test_sign_wire_bytes_match_compressor_bits():
    for n, g in [(1024, 32), (4096, 512)]:
        assert SignWire(group_size=g).wire_bytes(n) * 8 \
            == C.GroupedSign(group_size=g).wire_bits(n)


def test_sparse_wire_bytes_match_compressor_bits():
    """SparseWire = BlockTopK payload + one f32 scale per block."""
    for n, k, b in [(1024, 4, 64), (4096, 8, 256)]:
        nblocks = n // b
        wire = SparseWire(k_per_block=k, block_size=b)
        assert wire.wire_bytes(n) * 8 \
            == C.BlockTopK(k_per_block=k, block_size=b).wire_bits(n) \
            + 32 * nblocks
        # bf16 values shave 16 bits per kept coordinate
        wire16 = SparseWire(k_per_block=k, block_size=b,
                            value_dtype="bfloat16")
        assert (wire.wire_bytes(n) - wire16.wire_bytes(n)) * 8 \
            == 16 * nblocks * k


def test_dense_wire_bytes_match_identity_bits():
    assert DenseWire().wire_bytes(1000) * 8 == C.Identity().wire_bits(1000)


def test_compressed_wires_beat_dense_f32():
    """Acceptance: measured wire bytes < dense f32 for sign AND top-K."""
    n = 1 << 20
    dense = DenseWire().wire_bytes(n)
    assert SignWire(group_size=512).wire_bytes(n) < dense / 20
    assert SparseWire(k_per_block=8, block_size=512).wire_bytes(n) < dense / 20


def test_sparse_index_dtype_narrows():
    assert SparseWire(block_size=256).index_dtype == jnp.uint16
    assert SparseWire(block_size=1 << 17).index_dtype == jnp.uint32
    idx, _, _ = SparseWire(k_per_block=2, block_size=64).pack(_rand(256))
    assert idx.dtype == jnp.uint16


# ---------------------------------------------------------------------------
# degenerate inputs: invalid sizes, zeros, ±0
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wire", WIRES)
def test_check_rejects_odd_sizes(wire):
    a = wire.alignment()
    if a > 1:
        with pytest.raises(ValueError):
            wire.check(a + 1, 1)            # not a multiple of the alignment
    with pytest.raises(ValueError):
        wire.check(4 * a, 8)                # not a multiple of nd * alignment
    wire.check(8 * a, 8)                    # padded size passes


@pytest.mark.parametrize("wire", WIRES)
def test_zero_vector_roundtrips_to_zero(wire):
    n = 512
    rt = np.asarray(wire.roundtrip(jnp.zeros((n,))))
    np.testing.assert_array_equal(rt, np.zeros((n,)))


def test_sign_convention_negative_zero():
    """sign(±0) := +1 — packing -0.0 and +0.0 yields identical words, so
    the wire is deterministic across platforms' zero signs."""
    g = 32
    base = _rand(64, seed=6)
    plus = jnp.where(jnp.arange(64) % 2 == 0, 0.0, base)
    minus = jnp.where(jnp.arange(64) % 2 == 0, -0.0, base)
    wp, sp_ = SignWire(group_size=g).pack(plus)
    wm, sm = SignWire(group_size=g).pack(minus)
    np.testing.assert_array_equal(np.asarray(wp), np.asarray(wm))
    np.testing.assert_allclose(np.asarray(sp_), np.asarray(sm))


# ---------------------------------------------------------------------------
# registry / compressor mapping
# ---------------------------------------------------------------------------

def test_wire_registry():
    assert isinstance(get_wire("sign", group_size=64), SignWire)
    assert isinstance(get_wire("sparse", k_per_block=2, block_size=64),
                      SparseWire)
    assert isinstance(get_wire("dense"), DenseWire)
    with pytest.raises(KeyError):
        get_wire("nope")


def test_wire_for_compressor_mapping():
    n, nd = 4096, 8
    w = wire_for_compressor(C.GroupedSign(group_size=64), n, nd)
    assert isinstance(w, SignWire) and w.group_size == 64
    w = wire_for_compressor(C.BlockTopK(k_per_block=4, block_size=128), n, nd)
    assert isinstance(w, SparseWire) and w.block_size == 128
    w = wire_for_compressor(C.TopK(k=32), n, nd)
    assert isinstance(w, SparseWire)
    assert w.block_size == n // nd and w.k_per_block == 32 // nd
    assert isinstance(wire_for_compressor(C.Identity(), n, nd), DenseWire)


# ---------------------------------------------------------------------------
# Pallas kernels vs jnp references (sparse wire)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,block", [(4, 128), (8, 256), (16, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_pack_kernel_matches_ref(k, block, dtype):
    n = 8 * block * 2
    x = _rand(n, seed=k + block).astype(dtype)
    i1, v1, s1 = topk_pack(x.astype(jnp.float32), k, block, interpret=True)
    i2, v2, s2 = ref.topk_pack_ref(x.astype(jnp.float32), k, block)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_topk_pack_kernel_matches_sparse_wire():
    """The Pallas pack agrees with SparseWire.pack (modulo the wire's
    narrow dtype casts) so either can feed the coded collective."""
    n, k, b = 8 * 128, 4, 128
    x = _rand(n, seed=11)
    ik, vk, sk = topk_pack(x, k, b, interpret=True)
    iw, vw, sw = SparseWire(k_per_block=k, block_size=b).pack(x)
    np.testing.assert_array_equal(np.asarray(ik),
                                  np.asarray(iw).astype(np.int32))
    np.testing.assert_allclose(np.asarray(vk), np.asarray(vw), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(sw))


@pytest.mark.parametrize("n_senders", [2, 4])
def test_topk_decode_reduce_kernel_matches_ref(n_senders):
    rows, k, b = 16, 8, 128
    packs = [ref.topk_pack_ref(_rand(rows * b, seed=i), k, b)
             for i in range(n_senders)]
    idx = jnp.stack([p[0] for p in packs])
    val = jnp.stack([p[1] for p in packs])
    sc = jnp.stack([p[2] for p in packs])
    mask = (jnp.arange(n_senders) % 2).astype(jnp.float32)
    out_k = topk_decode_reduce(idx, val, sc, mask, b, interpret=True)
    out_r = ref.topk_decode_reduce_ref(idx, val, sc, mask, b)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-6, atol=1e-7)


def test_topk_unpack_ref_roundtrip():
    n, k, b = 1024, 8, 128
    x = _rand(n, seed=12)
    i, v, s = ref.topk_pack_ref(x, k, b)
    rt = ref.topk_unpack_ref(i, v, s, b)
    bt = ref.block_topk_ref(x, k, b)
    np.testing.assert_allclose(np.asarray(rt), np.asarray(bt),
                               rtol=3e-7, atol=1e-7)
