"""Algorithm-1 semantics: EF conservation, straggler freezing, convergence
ordering of methods on the paper's linear-regression task."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import coding, compression as C, error_feedback as EF
from repro.data.tasks import linreg_task


@pytest.fixture(scope="module")
def task():
    grad_fn, loss_fn, theta0, _ = linreg_task(seed=0)
    alloc = coding.random_allocation(0, 100, 100, 5)
    W = coding.encode_weights(alloc, 0.2)
    return grad_fn, loss_fn, theta0, W


def test_ef_conservation(task):
    """theta update + error update conserve the accumulator exactly:
    for non-stragglers,  C(acc) + e' == acc  (floating-point assoc aside)."""
    grad_fn, _, theta0, W = task
    st = EF.EFState.init(theta0, 100)
    comp = C.GroupedSign(group_size=20)
    gamma = 1e-5
    mask = jnp.ones((100,))
    g = W @ grad_fn(st.theta)
    acc = gamma * g + st.e
    st2 = EF.cocoef_step(st, grad_fn, W, mask, gamma, comp)
    c = jax.vmap(comp.apply)(acc)
    np.testing.assert_allclose(np.asarray(c + st2.e), np.asarray(acc),
                               rtol=1e-5, atol=1e-7)
    # server applied exactly sum of compressed messages
    np.testing.assert_allclose(np.asarray(st.theta - st2.theta),
                               np.asarray(c.sum(0)), rtol=1e-5, atol=1e-6)


def test_straggler_freezes_error(task):
    grad_fn, _, theta0, W = task
    st = EF.EFState.init(theta0, 100)
    comp = C.GroupedSign()
    # warm up one full step so e != 0
    st = EF.cocoef_step(st, grad_fn, W, jnp.ones((100,)), 1e-5, comp)
    mask = jnp.zeros((100,)).at[:50].set(1.0)
    st2 = EF.cocoef_step(st, grad_fn, W, mask, 1e-5, comp)
    # stragglers (mask 0) keep e, non-stragglers change it
    np.testing.assert_array_equal(np.asarray(st2.e[50:]),
                                  np.asarray(st.e[50:]))
    assert not np.allclose(np.asarray(st2.e[:50]), np.asarray(st.e[:50]))


def test_coco_keeps_zero_error(task):
    grad_fn, _, theta0, W = task
    st = EF.EFState.init(theta0, 100)
    st2 = EF.coco_step(st, grad_fn, W, jnp.ones((100,)), 1e-5,
                       C.GroupedSign())
    assert float(jnp.abs(st2.e).max()) == 0.0


def _run(method, comp, task, gamma, T=150, needs_key=False, diff=False):
    grad_fn, loss_fn, theta0, W = task
    st = (EF.DiffState if diff else EF.EFState).init(theta0, 100)
    key = jax.random.PRNGKey(42)
    for t in range(T):
        mask = coding.straggler_mask(key, t, 100, 0.2)
        kk = jax.random.fold_in(jax.random.PRNGKey(7), t) if needs_key else None
        if method is EF.uncompressed_step:
            st = method(st, grad_fn, W, mask, gamma, step=t)
        else:
            st = method(st, grad_fn, W, mask, gamma, comp, step=t, key=kk)
    return float(loss_fn(st.theta))


def test_convergence_ordering(task):
    """Paper Fig. 2/5 claims at a coarse level: every method reduces the
    loss; COCO-EF(Sign) ~ uncompressed << Unbiased(Sign); EF > no-EF."""
    _, loss_fn, theta0, _ = task
    l0 = float(loss_fn(theta0))
    l_cocoef = _run(EF.cocoef_step, C.GroupedSign(), task, 1e-5)
    l_coco = _run(EF.coco_step, C.GroupedSign(), task, 1e-5)
    l_unb = _run(EF.unbiased_step, C.StochasticSign(), task, 2e-6,
                 needs_key=True)
    l_unc = _run(EF.uncompressed_step, None, task, 1e-5)
    assert l_cocoef < 0.05 * l0
    assert l_cocoef < l_unb          # biased + EF beats unbiased @ equal bits
    assert l_cocoef < l_coco         # EF helps
    assert l_cocoef < 3.0 * l_unc    # near the uncompressed bound


def test_decaying_lr_worse(task):
    """Fig. 6: decaying lr hurts COCO-EF (stale error dominance)."""
    grad_fn, loss_fn, theta0, W = task
    key = jax.random.PRNGKey(42)

    def run(gamma_fn):
        st = EF.EFState.init(theta0, 100)
        for t in range(150):
            mask = coding.straggler_mask(key, t, 100, 0.5)
            st = EF.cocoef_step(st, grad_fn, W, mask, gamma_fn(t),
                                C.GroupedSign(), step=t)
        return float(loss_fn(st.theta))

    const = run(lambda t: 2e-5)
    decay = run(lambda t: 2e-5 / np.sqrt(t + 1))
    assert const < decay
