"""Test config.  NOTE: no XLA_FLAGS here — single-device tests must see one
device (the multi-device collective/integration tests spawn subprocesses
with their own xla_force_host_platform_device_count)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
