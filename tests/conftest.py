"""Test config: determinism pins + import paths.

NOTE: no XLA_FLAGS here — single-device tests must see one device (the
multi-device collective/integration tests spawn subprocesses with their own
xla_force_host_platform_device_count).  Tier-1 runs deterministically: CPU
platform, x64 off, fixed seeds for every RNG the tests touch.
"""
import os
import random
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")   # before jax import

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))   # for the _hyp shim

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: multi-minute distributed/e2e cases (deselect with "
        "-m 'not slow' for the quick tier-1 loop)")


@pytest.fixture(autouse=True)
def _pin_host_rngs():
    """Host-side RNGs re-seeded per test; jax code must use explicit
    PRNGKeys (the `rng_key` fixture) anyway."""
    random.seed(0)
    np.random.seed(0)
    yield


@pytest.fixture
def rng_key():
    return jax.random.PRNGKey(0)
