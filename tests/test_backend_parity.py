"""Backend-dispatch parity: backend="pallas" (interpret mode on CPU) must
match backend="jnp" bit-for-bit through the fused wire entry points, the
coded collective and cocoef_update, and the fused path must lower fewer
full-vector HBM round-trips than the unfused reference sequence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.collectives import DenseWire, SignWire, SparseWire
from repro.kernels import ref
from repro.launch import hlo_cost
from test_distributed import run_sub

WIRES = [
    pytest.param(SignWire(group_size=32), id="sign32"),
    pytest.param(SignWire(group_size=128), id="sign128"),
    pytest.param(SparseWire(k_per_block=4, block_size=64), id="sparse4of64"),
    pytest.param(SparseWire(k_per_block=8, block_size=128,
                            value_dtype="bfloat16"), id="sparse8of128bf16"),
    pytest.param(DenseWire(), id="dense_f32"),
    pytest.param(DenseWire(value_dtype="bfloat16"), id="dense_bf16"),
]


def _assert_trees_equal(a, b, ctx=""):
    for i, (x, y) in enumerate(zip(jax.tree.leaves(a), jax.tree.leaves(b))):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"{ctx} leaf {i}")


# ---------------------------------------------------------------------------
# single-device: fused_local_step / fused_pack / decode_reduce bit-for-bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wire", WIRES)
@pytest.mark.parametrize("mask_self", [0.0, 1.0])
def test_fused_local_step_backends_agree(wire, mask_self):
    n = 16 * 128 * 2          # large enough to engage the Pallas tiles
    g = jax.random.normal(jax.random.PRNGKey(0), (n,))
    e = jax.random.normal(jax.random.PRNGKey(1), (n,)) * 0.1

    def step(up):
        return jax.jit(lambda gg, ee: wire.fused_local_step(
            gg, ee, 0.05, mask_self, use_pallas=up))(g, e)

    _assert_trees_equal(step(False), step(True), type(wire).__name__)


@pytest.mark.parametrize("wire", WIRES)
def test_fused_pack_and_decode_reduce_backends_agree(wire):
    n, n_senders = 16 * 128, 4
    xs = jax.random.normal(jax.random.PRNGKey(2), (n_senders, n))
    mask = (jnp.arange(n_senders) % 2).astype(jnp.float32)

    def both(up):
        pk = jax.jit(lambda x: wire.fused_pack(x, use_pallas=up))
        payloads = tuple(jnp.stack(ps) for ps in
                         zip(*[tuple(pk(x)) for x in xs]))
        out = jax.jit(lambda *p: wire.decode_reduce(p, mask, use_pallas=up)
                      )(*payloads)
        return payloads + (out,)

    _assert_trees_equal(both(False), both(True), type(wire).__name__)


# ---------------------------------------------------------------------------
# end-to-end: cocoef_update + coded collective, every wire x mask x buckets
# ---------------------------------------------------------------------------

def test_backend_parity_cocoef_update_sweep():
    """backend="pallas" == backend="jnp" bit-for-bit through cocoef_update
    (fused local step + two-phase coded collective) for every compressor
    x straggler mask x num_buckets, on an 8-device mesh."""
    run_sub("""
    import dataclasses
    from jax.sharding import PartitionSpec as P
    from repro.core.cocoef import CocoEFConfig, cocoef_update
    mesh = make_mesh((4, 2), ("data", "model"))
    masks = [jnp.ones((4,)), jnp.array([1., 0., 1., 1.]),
             jnp.array([0., 0., 1., 0.])]
    n = 2048   # per-device flat: multiple of 4 chunks * 64 block * 4 buckets
    gamma = 0.1
    g = jax.random.normal(jax.random.PRNGKey(2), (8 * n,))
    e = jax.random.normal(jax.random.PRNGKey(3), (8 * n,)) * 0.1
    cases = [("sign", "float32"), ("block_topk", "float32"),
             ("block_topk", "bfloat16"), ("topk", "float32"),
             ("identity", "float32")]
    for comp, wdt in cases:
        for num_buckets in (1, 4):
            outs = {}
            for backend in ("jnp", "pallas"):
                ccfg = CocoEFConfig(coding_axes=("data",), group_size=32,
                                    compressor=comp, block_size=64,
                                    k_per_block=4, topk_k=64,
                                    wire_dtype=wdt, num_buckets=num_buckets,
                                    backend=backend)
                f = shard_map(lambda gg, ee, mm: cocoef_update(
                                  gg, ee, mm, gamma, ccfg),
                              mesh, in_specs=(P(("data", "model")),) * 2
                              + (P(),),
                              out_specs=(P(("data", "model")),) * 2,
                              axis_names={"data", "model"}, check=False)
                jf = jax.jit(f)
                outs[backend] = [jf(g, e, mask) for mask in masks]
            for (g1, e1), (g2, e2) in zip(outs["jnp"], outs["pallas"]):
                assert np.array_equal(np.asarray(g1), np.asarray(g2)), \
                    ("ghat", comp, wdt, num_buckets)
                assert np.array_equal(np.asarray(e1), np.asarray(e2)), \
                    ("e_new", comp, wdt, num_buckets)
    """, timeout=900)


def test_backend_parity_coco_mode():
    """coco (no-EF) routes through fused_pack: backends agree bit-for-bit."""
    run_sub("""
    from jax.sharding import PartitionSpec as P
    from repro.core.cocoef import CocoEFConfig, cocoef_update
    mesh = make_mesh((4, 2), ("data", "model"))
    n = 2048
    g = jax.random.normal(jax.random.PRNGKey(4), (8 * n,))
    e = jnp.zeros((8 * n,))
    mask = jnp.array([1., 0., 1., 1.])
    for comp in ("sign", "block_topk"):
        outs = []
        for backend in ("jnp", "pallas"):
            ccfg = CocoEFConfig(coding_axes=("data",), group_size=32,
                                compressor=comp, block_size=64, k_per_block=4,
                                mode="coco", backend=backend)
            f = shard_map(lambda gg, ee: cocoef_update(gg, ee, mask, 0.1,
                                                       ccfg),
                          mesh, in_specs=(P(("data", "model")),) * 2,
                          out_specs=(P(("data", "model")),) * 2,
                          axis_names={"data", "model"}, check=False)
            outs.append(jax.jit(f)(g, e))
        assert np.array_equal(np.asarray(outs[0][0]), np.asarray(outs[1][0])), comp
        assert np.array_equal(np.asarray(outs[0][1]), np.asarray(outs[1][1])), comp
    """, timeout=600)


# ---------------------------------------------------------------------------
# HLO cost: the fused path lowers fewer full-vector HBM round-trips
# ---------------------------------------------------------------------------

def _fullvec_writes(n, fn, *args):
    """Full-vector HBM round-trips of a jitted fn: executed ops in the
    ENTRY computation of the optimized HLO (hlo_cost's execution units)
    whose result materializes an f32 tensor of exactly n elements."""
    import math
    txt = jax.jit(fn).lower(*args).compile().as_text()
    comps = hlo_cost.parse_computations(txt)
    entry = None
    for raw in txt.splitlines():
        if raw.startswith("ENTRY"):
            entry = hlo_cost._COMP_HDR.match(raw.strip()).group(1)
            break
    cnt = 0
    for op in comps[entry].ops:
        if op.kind in hlo_cost._SKIP_KINDS:
            continue
        for dt, dims in hlo_cost._arrays(op.rtype):
            if dt == "f32" and math.prod(dims) == n:
                cnt += 1
    return cnt


def test_fused_local_step_fewer_hbm_roundtrips():
    """The fused local step must materialize fewer full-vector f32 tensors
    than the pre-backend-layer reference trace (accumulate, pack, unpack
    for c, error-update), both at equal jit scope and against the
    separately-jitted stage pipeline (whose jit boundaries each force a
    full-vector HBM round-trip)."""
    n, group = 1 << 22, 512
    gamma, mask_self = 0.01, 1.0
    g = jax.ShapeDtypeStruct((n,), jnp.float32)
    e = jax.ShapeDtypeStruct((n,), jnp.float32)

    fused = _fullvec_writes(
        n, lambda gg, ee: ref.ef_sign_fused_ref(gg, ee, gamma, mask_self,
                                                group), g, e)

    def old_local_step(gg, ee):      # the pre-PR cocoef_update local trace
        acc = gamma * gg + ee
        w, s = ref.sign_pack_ref(acc, group)
        c = ref.sign_unpack_ref(w, s, group)
        return w, s, c, jnp.where(mask_self > 0, acc - c, ee)

    reference = _fullvec_writes(n, old_local_step, g, e)
    assert fused < reference, (fused, reference)

    acc_t = jax.ShapeDtypeStruct((n,), jnp.float32)
    w_t = jax.ShapeDtypeStruct((n // 32,), jnp.uint32)
    s_t = jax.ShapeDtypeStruct((n // group,), jnp.float32)
    staged = (
        _fullvec_writes(n, lambda gg, ee: gamma * gg + ee, g, e)
        + _fullvec_writes(n, lambda a: ref.sign_pack_ref(a, group), acc_t)
        + _fullvec_writes(n, lambda w, s: ref.sign_unpack_ref(w, s, group),
                          w_t, s_t)
        + _fullvec_writes(n, lambda a, c, ee: jnp.where(mask_self > 0, a - c,
                                                        ee), acc_t, acc_t, e))
    assert fused < staged, (fused, staged)


def test_coco_mode_drops_dead_c_concat():
    """mode="coco" never materializes the reconstruction c: its traced
    program has exactly one full-vector concatenate per ghat (the bucket
    join) and no second one for c, and moves fewer bytes than cocoef."""
    run_sub("""
    import re
    from jax.sharding import PartitionSpec as P
    from repro.core.cocoef import CocoEFConfig, cocoef_update
    from repro.launch import hlo_cost
    mesh = make_mesh((4, 2), ("data", "model"))
    n = 2048
    mask = jnp.ones((4,))
    gs = jax.ShapeDtypeStruct((8 * n,), jnp.float32)
    def lowered(mode):
        ccfg = CocoEFConfig(coding_axes=("data",), group_size=32,
                            compressor="sign", mode=mode, num_buckets=4,
                            backend="jnp")
        f = shard_map(lambda gg, ee: cocoef_update(gg, ee, mask, 0.1, ccfg),
                      mesh, in_specs=(P(("data", "model")),) * 2,
                      out_specs=(P(("data", "model")),) * 2,
                      axis_names={"data", "model"})
        return jax.jit(f).lower(gs, gs)
    # trace-level: full-vector (512 = n/4 buckets) f32 concatenates
    def full_concats(low):
        txt = low.as_text()
        return len([l for l in txt.splitlines()
                    if "stablehlo.concatenate" in l
                    and re.search(r"-> tensor<2048xf32>", l)])
    n_coco = full_concats(lowered("coco"))
    n_cocoef = full_concats(lowered("cocoef"))
    assert n_coco == 1, n_coco            # ghat join only — no dead c join
    assert n_cocoef == 2, n_cocoef        # ghat join + new-error join
    # compiled: coco moves strictly fewer HBM bytes than cocoef
    b_coco = hlo_cost.analyze(lowered("coco").compile().as_text(), 8).bytes
    b_cocoef = hlo_cost.analyze(lowered("cocoef").compile().as_text(), 8).bytes
    assert b_coco < b_cocoef, (b_coco, b_cocoef)
    """, timeout=600)


# ---------------------------------------------------------------------------
# bucket schedule: pipelined (double-buffered overlap) == serial, bitwise
# ---------------------------------------------------------------------------

def test_schedule_parity_serial_vs_pipelined():
    """bucket_schedule="pipelined" issues bucket i's collective before
    running bucket i+1's fused local step (compute/comm overlap); it is
    the SAME ops in a different issue order, so it must stay bit-for-bit
    equal to the serial schedule for every mode x wire x wire-dtype x
    mask — including a total outage."""
    run_sub("""
    from jax.sharding import PartitionSpec as P
    from repro.core.cocoef import CocoEFConfig, cocoef_update
    mesh = make_mesh((4, 2), ("data", "model"))
    n = 2048   # per-device flat: multiple of 4 chunks * 64 block * 4 buckets
    gamma = 0.1
    g = jax.random.normal(jax.random.PRNGKey(6), (8 * n,))
    e = jax.random.normal(jax.random.PRNGKey(7), (8 * n,)) * 0.1
    masks = [jnp.ones((4,)), jnp.array([1., 0., 1., 1.]), jnp.zeros((4,))]
    cases = [("cocoef", "sign", "float32"),
             ("cocoef", "block_topk", "float32"),
             ("cocoef", "block_topk", "bfloat16"),
             ("coco", "sign", "float32")]
    for mode, comp, wdt in cases:
        outs = {}
        for sched in ("serial", "pipelined"):
            ccfg = CocoEFConfig(coding_axes=("data",), group_size=32,
                                compressor=comp, block_size=64,
                                k_per_block=4, wire_dtype=wdt, mode=mode,
                                num_buckets=4, bucket_schedule=sched,
                                backend="jnp")
            f = shard_map(lambda gg, ee, mm: cocoef_update(
                              gg, ee, mm, gamma, ccfg),
                          mesh, in_specs=(P(("data", "model")),) * 2
                          + (P(),),
                          out_specs=(P(("data", "model")),) * 2,
                          axis_names={"data", "model"}, check=False)
            jf = jax.jit(f)
            outs[sched] = [jf(g, e, m) for m in masks]
        for (g1, e1), (g2, e2) in zip(outs["serial"], outs["pipelined"]):
            assert np.array_equal(np.asarray(g1), np.asarray(g2)), \
                ("ghat", mode, comp, wdt)
            assert np.array_equal(np.asarray(e1), np.asarray(e2)), \
                ("e_new", mode, comp, wdt)
    """, timeout=900)


# ---------------------------------------------------------------------------
# dynamic coding plane: in-graph W fold == host-side W fold, bitwise
# ---------------------------------------------------------------------------

def test_elastic_weight_fold_matches_host_fold_bitwise():
    """The elastic step's in-graph per-example weights
    (take_along_axis(W/per_subset, subset_ids), scaled W a jit ARGUMENT)
    must be bit-for-bit the static batch maker's host-side numpy fold
    (W[i, sids] / per_subset baked into the batch).  The 1/per_subset
    division happens on the HOST on both sides — an in-graph
    divide-by-constant is strength-reduced by XLA to a reciprocal
    multiply, which this test catches for non-pow2 per_subset (3, 5)."""
    from repro.core import coding
    from repro.data import pipeline

    rng = np.random.default_rng(0)
    for N, d, per_subset in [(8, 2, 4), (8, 2, 3), (6, 3, 5)]:
        q = rng.uniform(0.3, 1.0, N)
        alloc = coding.rate_aware_allocation(q, N, d, exact_load=True)
        W = coding.encode_weights(alloc, rates=q)
        toks_s, wts_s = pipeline.coded_train_batch(
            jax.random.PRNGKey(1), 3, alloc, W, per_subset, 16, 97)
        toks_e, wts_e, sids = pipeline.elastic_train_batch(
            jax.random.PRNGKey(1), 3, alloc, per_subset, 16, 97)
        assert np.array_equal(np.asarray(toks_s), np.asarray(toks_e))
        W_scaled = jnp.asarray(np.asarray(W) / per_subset)

        @jax.jit
        def fold(Wt, sids, base):
            return base * jnp.take_along_axis(Wt, sids, axis=1)

        folded = fold(W_scaled, sids, wts_e)
        assert np.array_equal(np.asarray(folded), np.asarray(wts_s)), \
            (N, d, per_subset)
