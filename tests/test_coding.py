"""Gradient-coding tests: allocation structure, encode weights, and the
unbiasedness identity  E_I[sum_i I_i g_i] = grad F  (eq. 3 + eq. 8)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import coding


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), n=st.sampled_from([8, 20, 100]),
       d=st.integers(1, 6))
def test_random_allocation_dk(seed, n, d):
    alloc = coding.random_allocation(seed, n, n, d)
    assert alloc.S.shape == (n, n)
    np.testing.assert_array_equal(alloc.d, min(d, n))


def test_random_allocation_dk_fixed():
    """Plain (non-hypothesis) pin of the allocation invariants so the case
    runs identically with or without the optional property-test extras."""
    for seed, n, d in [(0, 8, 1), (3, 20, 4), (7, 100, 6), (11, 8, 12)]:
        alloc = coding.random_allocation(seed, n, n, d)
        assert alloc.S.shape == (n, n)
        np.testing.assert_array_equal(alloc.d, min(d, n))
        assert int(np.asarray(alloc.S).sum()) == n * min(d, n)


def test_cyclic_allocation_pairwise_balance():
    n, d = 12, 3
    alloc = coding.cyclic_allocation(n, n, d)
    np.testing.assert_array_equal(alloc.d, d)
    # every device holds exactly d subsets
    np.testing.assert_array_equal(alloc.S.sum(1), d)


def test_encode_weights_normalization():
    """(1-p) * sum_i W[i,k] == 1 for all k — this is what makes the masked
    aggregate unbiased."""
    alloc = coding.random_allocation(0, 50, 50, 4)
    for p in (0.0, 0.2, 0.7):
        W = np.asarray(coding.encode_weights(alloc, p))
        np.testing.assert_allclose((1 - p) * W.sum(0), 1.0, rtol=1e-5)


def test_coded_aggregate_unbiased():
    """E over the Bernoulli mask of sum_i I_i g_i equals grad F exactly
    (closed form: independence across devices)."""
    N = M = 20
    D = 7
    p = 0.3
    alloc = coding.random_allocation(1, N, M, 3)
    W = np.asarray(coding.encode_weights(alloc, p))
    rng = np.random.default_rng(0)
    grads = rng.normal(size=(M, D))          # per-subset gradients
    g = W @ grads                            # (N, D) coded vectors
    # E[sum_i I_i g_i] = (1-p) sum_i g_i
    expected = grads.sum(0)                  # grad F
    np.testing.assert_allclose((1 - p) * g.sum(0), expected, rtol=1e-6)


def test_straggler_mask_deterministic_and_rate():
    key = jax.random.PRNGKey(0)
    m1 = coding.straggler_mask(key, 7, 1000, 0.3)
    m2 = coding.straggler_mask(key, 7, 1000, 0.3)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
    m3 = coding.straggler_mask(key, 8, 1000, 0.3)
    assert not np.array_equal(np.asarray(m1), np.asarray(m3))
    assert abs(float(m1.mean()) - 0.7) < 0.06


def test_redundancy_theta():
    alloc = coding.random_allocation(0, 10, 10, 10)  # full replication
    assert coding.redundancy_theta(alloc) == pytest.approx(0.0)
    alloc1 = coding.random_allocation(0, 10, 10, 1)
    assert coding.redundancy_theta(alloc1) == pytest.approx(10 * (1 - 0.1))


def test_invalid_p():
    alloc = coding.random_allocation(0, 4, 4, 2)
    with pytest.raises(ValueError):
        coding.encode_weights(alloc, 1.0)
