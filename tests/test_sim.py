"""Cluster-sim subsystem: straggler-process statistics (marginals,
burst-length law, determinism), the legacy bit-for-bit regression through
the cocoef_update mask-provider hook, the wire-aware cost model, and the
wire_bytes single-source-of-truth audit."""
import json
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import coding
from repro.core.collectives import DenseWire, SignWire, SparseWire
from repro.sim import (ComputeProfile, HeterogeneousRates, IIDBernoulli,
                       LinkProfile, MarkovBursty, StepTimer, TraceReplay,
                       get_straggler_process, simulate_run, time_to_target)
from test_distributed import run_sub

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # benchmarks/


# ---------------------------------------------------------------------------
# IIDBernoulli: the legacy eq.-(8) model, bit for bit
# ---------------------------------------------------------------------------

def test_iid_reproduces_legacy_mask_bit_for_bit(rng_key):
    N, p = 24, 0.3
    proc = IIDBernoulli(num_devices=N, p=p)
    for t in (0, 1, 7, 1234):
        np.testing.assert_array_equal(
            np.asarray(proc.mask(rng_key, t)),
            np.asarray(coding.straggler_mask(rng_key, t, N, p)))
    # traced step index too (the train path passes a traced scalar)
    m = jax.jit(lambda s: proc.mask(rng_key, s))(jnp.int32(7))
    np.testing.assert_array_equal(
        np.asarray(m), np.asarray(coding.straggler_mask(rng_key, 7, N, p)))


def test_iid_through_cocoef_update_hook_bit_for_bit():
    """cocoef_update(mask=None, mask_provider=IIDBernoulli.mask) must equal
    the legacy explicit-mask path exactly — ghat AND the new error state —
    on a real multi-device mesh, for several steps."""
    run_sub("""
    from jax.sharding import PartitionSpec as P
    from repro.core.cocoef import CocoEFConfig, cocoef_update
    from repro.core import coding
    from repro.sim import IIDBernoulli
    mesh = make_mesh((4, 2), ("data", "model"))
    n, p = 1024, 0.4
    key = jax.random.PRNGKey(3)
    g = jax.random.normal(jax.random.PRNGKey(4), (8 * n,))
    e = jax.random.normal(jax.random.PRNGKey(5), (8 * n,)) * 0.1
    ccfg = CocoEFConfig(coding_axes=("data",), group_size=32,
                        compressor="sign", backend="jnp")
    proc = IIDBernoulli(num_devices=4, p=p)
    legacy = shard_map(
        lambda gg, ee, ss: cocoef_update(
            gg, ee, coding.straggler_mask(key, ss, 4, p), 0.1, ccfg),
        mesh, in_specs=(P(("data", "model")),) * 2 + (P(),),
        out_specs=(P(("data", "model")),) * 2,
        axis_names={"data", "model"}, check=False)
    hooked = shard_map(
        lambda gg, ee, ss: cocoef_update(
            gg, ee, None, 0.1, ccfg, mask_provider=proc.mask, key=key,
            step=ss),
        mesh, in_specs=(P(("data", "model")),) * 2 + (P(),),
        out_specs=(P(("data", "model")),) * 2,
        axis_names={"data", "model"}, check=False)
    jl, jh = jax.jit(legacy), jax.jit(hooked)
    for t in (0, 3, 17):
        (g1, e1), (g2, e2) = jl(g, e, jnp.int32(t)), jh(g, e, jnp.int32(t))
        assert np.array_equal(np.asarray(g1), np.asarray(g2)), t
        assert np.array_equal(np.asarray(e1), np.asarray(e2)), t
    """, timeout=600)


# ---------------------------------------------------------------------------
# marginal participation rates
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make,rank_atol", [
    pytest.param(lambda: IIDBernoulli(num_devices=16, p=0.3), 0.035,
                 id="iid"),
    # bursts correlate consecutive steps -> ~mean_burst x fewer effective
    # samples per rank, hence the looser per-rank tolerance
    pytest.param(lambda: MarkovBursty(num_devices=16, p=0.3, mean_burst=6.0),
                 0.12, id="markov"),
    pytest.param(lambda: HeterogeneousRates.linear(16, 0.3, spread=0.5),
                 0.035, id="hetero"),
])
def test_empirical_participation_matches_marginal(make, rank_atol, rng_key):
    proc = make()
    T = 3000
    tr = proc.sample_trace(rng_key, T)
    assert tr.shape == (T, 16)
    assert set(np.unique(tr)) <= {0.0, 1.0}
    np.testing.assert_allclose(tr.mean(axis=0), proc.rates(), atol=rank_atol)
    # fleet-wide marginal is tight for every process
    assert abs(tr.mean() - proc.rates().mean()) < 0.03


def test_hetero_per_rank_profile(rng_key):
    proc = HeterogeneousRates.linear(8, 0.4, spread=1.0)
    # p_i spans 0 .. 0.8 linearly: rank 0 never straggles, rank 7 often
    assert proc.p_ranks[0] == 0.0 and proc.p_ranks[-1] == pytest.approx(0.8)
    tr = proc.sample_trace(rng_key, 4000)
    rates = tr.mean(axis=0)
    assert rates[0] == 1.0
    assert np.all(np.diff(proc.rates()) < 0)           # monotone profile
    np.testing.assert_allclose(rates, proc.rates(), atol=0.05)
    two = HeterogeneousRates.two_class(8, p_slow=0.5, slow_fraction=0.25)
    assert two.p_ranks == (0.5, 0.5) + (0.0,) * 6


# ---------------------------------------------------------------------------
# MarkovBursty: burst structure
# ---------------------------------------------------------------------------

def _run_lengths(slow_col):
    runs, n = [], 0
    for v in slow_col:
        if v:
            n += 1
        elif n:
            runs.append(n)
            n = 0
    if n:
        runs.append(n)
    return runs


def test_markov_run_lengths_geometric(rng_key):
    burst = 6.0
    proc = MarkovBursty(num_devices=32, p=0.25, mean_burst=burst)
    tr = proc.sample_trace(rng_key, 4000)
    runs = np.array(sum((_run_lengths(1.0 - col) for col in tr.T), []))
    assert runs.size > 2000
    # Geometric(q = 1/burst): mean 1/q, survival P(L > k) = (1-q)^k
    assert abs(runs.mean() - burst) / burst < 0.15
    q = 1.0 / burst
    for k in range(1, 6):
        emp = (runs > k).mean()
        assert abs(emp - (1 - q) ** k) < 0.08, (k, emp)


def test_markov_mask_pure_and_jittable(rng_key):
    proc = MarkovBursty(num_devices=8, p=0.2, mean_burst=8.0)
    m1 = np.asarray(proc.mask(rng_key, 55))
    m2 = np.asarray(proc.mask(rng_key, 55))
    np.testing.assert_array_equal(m1, m2)
    m3 = np.asarray(jax.jit(lambda s: proc.mask(rng_key, s))(jnp.int32(55)))
    np.testing.assert_array_equal(m1, m3)
    # the sampled trace IS the per-step mask sequence (shared trace between
    # training dynamics and the cost model)
    tr = proc.sample_trace(rng_key, 60)
    np.testing.assert_array_equal(tr[55], m1)


def test_markov_rejects_infeasible_burst():
    with pytest.raises(ValueError):
        MarkovBursty(num_devices=4, p=0.9, mean_burst=1.5)


# ---------------------------------------------------------------------------
# TraceReplay: determinism + JSON roundtrip
# ---------------------------------------------------------------------------

def test_trace_replay_deterministic_and_cyclic(tmp_path):
    rows = np.array([[1, 0, 1], [0, 1, 1], [1, 1, 0], [1, 1, 1]])
    proc = TraceReplay.from_array(rows)
    # key-independent: every device/host derives the identical mask
    for t in range(8):
        a = np.asarray(proc.mask(jax.random.PRNGKey(0), t))
        b = np.asarray(proc.mask(jax.random.PRNGKey(999), t))
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, rows[t % 4])
    np.testing.assert_allclose(proc.rates(), rows.mean(0))
    # JSON roundtrip through the registry
    path = proc.to_json(tmp_path / "trace.json")
    again = get_straggler_process("trace", 3, trace=path)
    assert again == proc
    with pytest.raises(ValueError):
        get_straggler_process("trace", 5, trace=path)   # device mismatch
    with pytest.raises(ValueError):
        get_straggler_process("trace", 3)               # no path


def test_registry_names():
    assert isinstance(get_straggler_process("iid", 4, 0.1), IIDBernoulli)
    assert isinstance(get_straggler_process("markov", 4, 0.1), MarkovBursty)
    assert isinstance(get_straggler_process("hetero", 4, 0.1),
                      HeterogeneousRates)
    with pytest.raises(KeyError):
        get_straggler_process("nope", 4)


# ---------------------------------------------------------------------------
# cost model: wire-aware step times + ledger
# ---------------------------------------------------------------------------

def test_step_timer_wire_aware_ordering():
    """Compressed wires must yield strictly faster simulated steps than the
    dense f32 wire at production scale — the premise of fig8."""
    n = 1 << 22
    full = np.ones(8)
    t_sign = StepTimer(wire=SignWire(group_size=512), n=n).step_time(full)
    t_topk = StepTimer(wire=SparseWire(k_per_block=8, block_size=512),
                       n=n).step_time(full)
    t_dense = StepTimer(wire=DenseWire(), n=n).step_time(full)
    assert t_sign < t_dense and t_topk < t_dense


def test_step_timer_accounting_and_cutoff():
    link = LinkProfile(bandwidth_gbps=10.0, down_bandwidth_gbps=100.0,
                       latency_s=1e-3, server_fanin=0)
    comp = ComputeProfile(grad_s=4e-3, speed_factors=(1.0, 2.0, 1.0, 4.0))
    timer = StepTimer(wire=SignWire(group_size=512), n=1 << 20, link=link,
                      compute=comp)
    assert timer.bytes_up() == SignWire(group_size=512).wire_bytes(1 << 20)
    up = link.up_s(timer.bytes_up())
    down = link.down_s(timer.bytes_down())
    # straggler cutoff: masking out the slowest rank removes its compute
    t_all = timer.step_time([1, 1, 1, 1])
    t_cut = timer.step_time([1, 1, 1, 0])
    assert t_all == pytest.approx(4e-3 * 4.0 + up + down)
    assert t_cut == pytest.approx(4e-3 * 2.0 + up + down)
    assert t_cut < t_all
    # an all-straggler step burns the full compute window
    assert timer.step_time([0, 0, 0, 0]) == pytest.approx(
        4e-3 * 4.0 + down)
    # server fan-in serializes uplink waves
    fanin = StepTimer(wire=SignWire(group_size=512), n=1 << 20,
                      link=LinkProfile(bandwidth_gbps=10.0, latency_s=1e-3,
                                       server_fanin=2), compute=comp)
    assert fanin.step_time([1, 1, 1, 1]) == pytest.approx(
        4e-3 * 4.0 + 2 * fanin.link.up_s(fanin.bytes_up())
        + fanin.link.down_s(fanin.bytes_down()))


def test_simulate_run_ledger(rng_key):
    n = 1 << 20
    wire = SignWire(group_size=512)
    proc = IIDBernoulli(num_devices=8, p=0.25)
    timer = StepTimer(wire=wire, n=n)
    sim = simulate_run(proc, timer, 50, rng_key)
    assert sim.step_time_s.shape == (50,)
    assert np.all(np.diff(sim.cum_time_s) > 0)
    # ledger: uplink bytes = participants x wire_bytes(n), per step
    np.testing.assert_array_equal(
        sim.bytes_up, sim.participants * wire.wire_bytes(n))
    at = sim.at_steps([0, 49])
    assert at["time_s"][1] == pytest.approx(sim.total_time_s)
    assert at["bytes_up_cum"][1] == pytest.approx(sim.bytes_up.sum())


def test_time_to_target_interpolates():
    assert time_to_target([0.0, 1.0, 2.0], [4.0, 2.0, 1.0], 3.0) \
        == pytest.approx(0.5)
    assert time_to_target([0.0, 1.0], [4.0, 2.0], 4.5) == pytest.approx(0.0)
    assert time_to_target([0.0, 1.0], [4.0, 2.0], 1.0) is None


# ---------------------------------------------------------------------------
# wire_bytes single source of truth (ISSUE 3 audit)
# ---------------------------------------------------------------------------

def test_wire_bytes_audit_single_source_of_truth():
    """comm_volume's table, the packed payloads the collective transmits,
    and the cost model's uplink accounting all read the same
    WireFormat.wire_bytes."""
    from benchmarks import comm_volume
    audited = comm_volume.audit_wire_bytes()
    # every uniform wire in the table + the per-rank-budget sparse wire
    assert len(audited) == len(comm_volume.WIRE_TABLE) + 1
    # and the table rows themselves are wire_bytes verbatim
    for (name, nbytes, _), (_, wire) in zip(comm_volume.run_wires(),
                                            comm_volume.WIRE_TABLE):
        assert nbytes == wire.wire_bytes(comm_volume.N_MODEL), name


# ---------------------------------------------------------------------------
# fig8 smoke: the full (time, loss) pipeline end to end
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fig8_smoke_sign_dominates_dense(tmp_path, monkeypatch):
    from benchmarks import fig8_time_to_accuracy as f8
    monkeypatch.setattr(f8, "OUT", tmp_path)
    res = f8.run(smoke=True)
    assert (tmp_path / "fig8.json").exists()
    out = json.loads((tmp_path / "fig8.json").read_text())
    assert set(out["curves"]) >= {"iid", "markov", "hetero"}
    for pname, curves in out["curves"].items():
        assert set(curves) == set(f8.METHODS)
        for c in curves.values():
            assert len(c["time_s"]) == len(c["loss"]) == len(c["step"])
            assert all(t2 > t1 for t1, t2 in zip(c["time_s"],
                                                 c["time_s"][1:]))
        t2t = out["summary"][pname]["time_to_target_s"]
        # acceptance: COCO-EF(sign) strictly dominates dense SGC in
        # simulated time-to-target under the default link profile
        assert t2t["cocoef_sign"] is not None
        assert t2t["sgc_dense"] is None or \
            t2t["cocoef_sign"] < t2t["sgc_dense"]


# ---------------------------------------------------------------------------
# cost model: bucketed aggregation + overlap-aware pipelined schedule
# ---------------------------------------------------------------------------

def _agg_parts(timer, mask):
    """(compute, aggregation) split of one step for hand-derived checks."""
    t = timer.step_time(mask)
    comp = np.asarray(timer.compute.rank_seconds(len(mask)))
    m = np.asarray(mask, np.float64)
    t_comp = np.max(np.where(m > 0, comp, 0.0)) if m.sum() else comp.max()
    return t_comp, t - t_comp


def test_step_timer_bucket_defaults_reduce_to_old_formula():
    """num_buckets=1 / overlap=False / pack_s=0 (the defaults) must price
    exactly the pre-bucketing model: up + down with one latency each."""
    link = LinkProfile(bandwidth_gbps=10.0, down_bandwidth_gbps=100.0,
                       latency_s=1e-3, server_fanin=0)
    comp = ComputeProfile(grad_s=4e-3)
    wire = SignWire(group_size=512)
    base = StepTimer(wire=wire, n=1 << 20, link=link, compute=comp)
    expect = 4e-3 + link.up_s(base.bytes_up()) + link.down_s(
        base.bytes_down())
    assert base.step_time([1, 1, 1, 1]) == pytest.approx(expect)
    # B=1 makes the overlap flag a no-op by construction
    b1 = StepTimer(wire=wire, n=1 << 20, link=link, compute=comp,
                   num_buckets=1, overlap=True)
    assert b1.step_time([1, 1, 1, 1]) == pytest.approx(expect)


def test_step_timer_serial_buckets_add_per_message_latency_only():
    """Serial bucketing splits the transfers but pays the per-message
    latency once per bucket on each of uplink and downlink: exactly
    2*(B-1)*latency over the single-shot step (fanin off)."""
    link = LinkProfile(bandwidth_gbps=10.0, down_bandwidth_gbps=100.0,
                       latency_s=1e-3, server_fanin=0)
    comp = ComputeProfile(grad_s=4e-3)
    wire = SignWire(group_size=512)
    mask = [1, 1, 1, 1]
    t1 = StepTimer(wire=wire, n=1 << 20, link=link,
                   compute=comp).step_time(mask)
    for B in (2, 4, 8):
        tb = StepTimer(wire=wire, n=1 << 20, link=link, compute=comp,
                       num_buckets=B).step_time(mask)
        assert tb == pytest.approx(t1 + 2 * (B - 1) * link.latency_s)


def test_step_timer_overlap_pays_bottleneck_not_sum():
    """Pipelined schedule: t_agg = fill (one bucket through all stages)
    + (B-1) * bottleneck stage — checked against the closed form, and
    never worse than serial."""
    link = LinkProfile(bandwidth_gbps=10.0, down_bandwidth_gbps=100.0,
                       latency_s=1e-3, server_fanin=0)
    comp = ComputeProfile(grad_s=4e-3)
    wire = SignWire(group_size=512)
    mask = [1, 1, 1, 1]
    pack_s = 2e-3
    for B in (2, 4, 8):
        serial = StepTimer(wire=wire, n=1 << 20, link=link, compute=comp,
                           num_buckets=B, pack_s=pack_s)
        pipe = StepTimer(wire=wire, n=1 << 20, link=link, compute=comp,
                         num_buckets=B, overlap=True, pack_s=pack_s)
        lat = link.latency_s
        pack_b = pack_s / B
        up_b = lat + (link.up_s(serial.bytes_up()) - lat) / B
        down_b = lat + (link.down_s(serial.bytes_down()) - lat) / B
        expect_agg = pack_b + up_b + down_b \
            + (B - 1) * max(pack_b, up_b, down_b)
        _, agg = _agg_parts(pipe, mask)
        assert agg == pytest.approx(expect_agg)
        # overlap can only help: serial == B * (sum of per-bucket stages)
        assert pipe.step_time(mask) < serial.step_time(mask)


def test_step_timer_overlap_all_straggler_still_broadcasts():
    """A total outage under the pipelined schedule keeps the single
    all-straggler semantics: full compute window, ZERO uplink time, and
    the zero-aggregate broadcast still streams bucket by bucket."""
    link = LinkProfile(bandwidth_gbps=10.0, down_bandwidth_gbps=100.0,
                       latency_s=1e-3, server_fanin=0)
    comp = ComputeProfile(grad_s=4e-3)
    wire = SignWire(group_size=512)
    timer = StepTimer(wire=wire, n=1 << 20, link=link, compute=comp,
                      num_buckets=4, overlap=True)
    down_b = link.latency_s + (link.down_s(timer.bytes_down())
                               - link.latency_s) / 4
    assert timer.step_time([0, 0, 0, 0]) == pytest.approx(
        4e-3 + down_b + 3 * down_b)
    # and zero uplink bytes on the ledger, like the single-shot model
    _, b_up, _ = timer.steps(np.zeros((1, 4)))
    assert b_up[0] == 0.0
