"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels import ref
from repro.kernels.sign_pack import ef_sign_fused, sign_decode_reduce, \
    sign_pack
from repro.kernels.topk_block import block_topk


@pytest.mark.parametrize("group", [128, 256, 512])
@pytest.mark.parametrize("blocks", [1, 4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sign_pack_sweep(group, blocks, dtype):
    n = 8 * group * blocks
    x = (jax.random.normal(jax.random.PRNGKey(group + blocks), (n,)) * 2
         ).astype(dtype)
    w1, s1 = sign_pack(x, group, interpret=True)
    w2, s2 = ref.sign_pack_ref(x, group)
    np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


def test_pack_unpack_roundtrip_on_quantized():
    """unpack(pack(x)) equals sign(x)*scale -> packing a sign-quantized
    vector is lossless to ~1ulp."""
    n, g = 8 * 256, 256
    x = jax.random.normal(jax.random.PRNGKey(0), (n,))
    w, s = sign_pack(x, g, interpret=True)
    rt = ref.sign_unpack_ref(w, s, g)
    expected = np.where(np.asarray(x) >= 0, 1.0, -1.0) * \
        np.repeat(np.asarray(s), g)
    np.testing.assert_allclose(np.asarray(rt), expected, rtol=1e-6)


@pytest.mark.parametrize("group", [128, 512])
@pytest.mark.parametrize("mask", [0.0, 1.0])
def test_ef_fused_sweep(group, mask):
    n = 8 * group * 2
    g = jax.random.normal(jax.random.PRNGKey(1), (n,))
    e = jax.random.normal(jax.random.PRNGKey(2), (n,)) * 0.1
    outs_k = ef_sign_fused(g, e, 0.01, mask, group, interpret=True)
    outs_r = ref.ef_sign_fused_ref(g, e, 0.01, mask, group)
    for a, b in zip(outs_k, outs_r):
        if a.dtype == jnp.uint32:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-7)


def test_ef_fused_conservation():
    """words/scales decode + e_new reconstruct acc exactly (Algorithm 1)."""
    n, g = 8 * 256, 256
    gv = jax.random.normal(jax.random.PRNGKey(1), (n,))
    e = jax.random.normal(jax.random.PRNGKey(2), (n,)) * 0.1
    gamma = 0.05
    words, scales, c, e_new = ef_sign_fused(gv, e, gamma, 1.0, g,
                                            interpret=True)
    acc = gamma * np.asarray(gv) + np.asarray(e)
    np.testing.assert_allclose(np.asarray(c) + np.asarray(e_new), acc,
                               rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("n_senders", [2, 4, 16])
def test_sign_decode_reduce(n_senders):
    n, g = 8 * 256, 256
    ws, ss = [], []
    for i in range(n_senders):
        x = jax.random.normal(jax.random.PRNGKey(i), (n,))
        w, s = ref.sign_pack_ref(x, g)
        ws.append(w)
        ss.append(s)
    words = jnp.stack(ws)
    scales = jnp.stack(ss)
    mask = (jnp.arange(n_senders) % 2).astype(jnp.float32)
    out_k = sign_decode_reduce(words, scales, mask, g, interpret=True)
    out_r = ref.sign_decode_reduce_ref(words, scales, mask, g)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("k,block", [(4, 128), (8, 256)])
@pytest.mark.parametrize("mask", [0.0, 1.0])
def test_ef_topk_fused_sweep(k, block, mask):
    from repro.kernels.topk_pack import ef_topk_fused
    n = 8 * block * 2
    g = jax.random.normal(jax.random.PRNGKey(3), (n,))
    e = jax.random.normal(jax.random.PRNGKey(4), (n,)) * 0.1
    outs_k = ef_topk_fused(g, e, 0.01, mask, k, block, interpret=True)
    # jit the oracle too: backend parity is a property of the compiled
    # programs (eager evaluation reassociates the accumulate by ~1 ulp)
    outs_r = jax.jit(lambda a, b: ref.ef_topk_fused_ref(a, b, 0.01, mask, k,
                                                        block))(g, e)
    for a, b in zip(outs_k, outs_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ef_topk_fused_conservation():
    """c + e_new reconstruct acc exactly.  `c` is the TRANSMITTED
    reconstruction (normalize -> value_dtype -> denormalize, what a
    receiver unpacks from the wire); at the default value_dtype="float32"
    the rounding is the identity, so c holds the exact kept values, and
    for narrower wire dtypes Sterbenz keeps `acc - c` exact anyway
    (tests/test_topk_select.py covers bfloat16)."""
    from repro.kernels.topk_pack import ef_topk_fused
    n, k, block = 8 * 128, 8, 128
    gv = jax.random.normal(jax.random.PRNGKey(5), (n,))
    e = jax.random.normal(jax.random.PRNGKey(6), (n,)) * 0.1
    gamma = 0.05
    idx, val, sc, c, e_new = ef_topk_fused(gv, e, gamma, 1.0, k, block,
                                           interpret=True)
    # jitted accumulate — XLA contracts gamma*g + e into an FMA, so the
    # bitwise-matching oracle must be compiled too
    acc = np.asarray(jax.jit(lambda a, b: jnp.float32(gamma) * a + b)(gv, e))
    np.testing.assert_array_equal(np.asarray(c) + np.asarray(e_new), acc)
    # payload agrees with the pack-only kernel on the same acc
    from repro.kernels.topk_pack import topk_pack
    i2, v2, s2 = topk_pack(jnp.asarray(acc), k, block, interpret=True)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(val), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(sc), np.asarray(s2))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), k=st.sampled_from([4, 8, 16]),
       block=st.sampled_from([128, 256]))
def test_block_topk_sweep(seed, k, block):
    n = 8 * block
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    out_k = block_topk(x, k, block, interpret=True)
    out_r = ref.block_topk_ref(x, k, block)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
    nnz = (np.asarray(out_k).reshape(-1, block) != 0).sum(-1)
    assert (nnz == k).all()


def test_block_topk_bf16():
    n, k, block = 8 * 128, 4, 128
    x = (jax.random.normal(jax.random.PRNGKey(5), (n,))).astype(jnp.bfloat16)
    out_k = block_topk(x, k, block, interpret=True)
    out_r = ref.block_topk_ref(x, k, block)
    np.testing.assert_array_equal(np.asarray(out_k.astype(jnp.float32)),
                                  np.asarray(out_r.astype(jnp.float32)))


@pytest.mark.parametrize("softcap,window,groups", [
    (0.0, 0, 1), (50.0, 0, 2), (0.0, 64, 2), (30.0, 32, 4)])
def test_flash_attention(softcap, window, groups):
    from repro.kernels.flash_attention import flash_attention
    B, Hkv, S, hd = 2, 2, 512, 64
    H = Hkv * groups
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, H, S, hd)) * hd ** -0.5
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, S, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, S, hd))
    out_k = flash_attention(q, k, v, softcap=softcap, window=window,
                            groups=groups, interpret=True)
    out_r = ref.flash_attention_ref(q, k, v, softcap=softcap, window=window,
                                    groups=groups)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-4, atol=2e-5)
