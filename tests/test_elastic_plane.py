"""The elastic coding plane (ISSUE 9): CodingState as a retrace-free pytree
input, the bias-corrected online RateEstimator, the CodingPlan drift
controller, the exact-load allocator mode the mesh path needs, membership
changes through `checkpoint.elastic_rescale_ef`, and the 1000-rank fleet
wall-clock floor."""
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import elastic_rescale_ef
from repro.core import coding
from repro.core.coding_state import (CodingPlan, CodingState, RateEstimator,
                                     maybe_replan)
from repro.sim import HeterogeneousRates, StepTimer
from test_distributed import run_sub

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # benchmarks/


# ---------------------------------------------------------------------------
# CodingState: pytree contract — value changes never retrace
# ---------------------------------------------------------------------------

def test_coding_state_value_change_does_not_retrace():
    traces = []

    @jax.jit
    def step(x, cs):
        traces.append(1)
        return x * jnp.take_along_axis(
            cs.W, jnp.zeros((cs.W.shape[0], 1), jnp.int32), axis=1).sum() + \
            cs.epoch.astype(jnp.float32)

    plan = CodingPlan.create(np.linspace(0.4, 0.9, 4), 4, 2)
    x = jnp.ones((3,))
    for rates in (None, [0.5, 0.6, 0.7, 0.8], [0.9, 0.2, 0.9, 0.2]):
        cs, _ = maybe_replan(plan, rates)
        step(x, cs)
    assert len(traces) == 1        # three W/epoch values, ONE trace

    # a SHAPE change (membership change) is a legitimate retrace
    plan5 = CodingPlan.create(np.linspace(0.4, 0.9, 5), 4, 2)
    cs5, _ = maybe_replan(plan5, None)
    step(x, cs5)
    assert len(traces) == 2


def test_coding_state_create_dtypes():
    cs = CodingState.create([0.5, 1.0], np.ones((2, 3)), epoch=7)
    assert cs.rates_estimate.dtype == jnp.float32
    assert cs.W.dtype == jnp.float32 and cs.W.shape == (2, 3)
    assert cs.epoch.dtype == jnp.int32 and int(cs.epoch) == 7


# ---------------------------------------------------------------------------
# RateEstimator: bias-corrected EWMA, convergence, elasticity
# ---------------------------------------------------------------------------

def test_rate_estimator_first_mask_and_validation():
    est = RateEstimator(3, alpha=0.25)
    np.testing.assert_array_equal(est.rates, np.ones(3))   # prior before data
    m0 = np.array([1.0, 0.0, 1.0])
    np.testing.assert_array_equal(est.update(m0), m0)      # t=1: exactly m0
    with pytest.raises(ValueError):
        est.update(np.ones(4))
    with pytest.raises(ValueError):
        RateEstimator(3, alpha=0.0)
    with pytest.raises(ValueError):
        RateEstimator(3, prior=1.5)


def test_rate_estimator_converges_to_true_rates(rng_key):
    proc = HeterogeneousRates.two_class(16, p_slow=0.8, p_fast=0.02,
                                        slow_fraction=0.3)
    tr = np.asarray(proc.sample_trace(rng_key, 800), np.float64)
    est = RateEstimator(16, alpha=0.05)
    for t in range(tr.shape[0]):
        est.update(tr[t])
    # EWMA(0.05) steady-state std is sqrt(a/(2-a) q(1-q)) <= 0.08
    np.testing.assert_allclose(est.rates, proc.rates(), atol=0.25)
    assert np.abs(est.rates - proc.rates()).mean() < 0.1


def test_rate_estimator_resize_keeps_survivor_statistics():
    est = RateEstimator(6, alpha=0.5, prior=0.9)
    for _ in range(4):
        est.update([1, 1, 0, 0, 1, 0])
    kept = est.rates[:4].copy()
    est.resize(4)                       # default survivors: first N_new
    assert est.num_ranks == 4
    np.testing.assert_array_equal(est.rates, kept)
    # grow: joiners report the prior until their first observation
    est.resize(6)
    np.testing.assert_array_equal(est.rates[:4], kept)
    np.testing.assert_array_equal(est.rates[4:], [0.9, 0.9])
    assert (est.steps_seen[4:] == 0).all()
    # explicit survivor selection reorders statistics
    est2 = RateEstimator(3, alpha=1.0)
    est2.update([0.0, 1.0, 0.5])
    est2.resize(2, survivors=[2, 0])
    np.testing.assert_array_equal(est2.rates, [0.5, 0.0])
    with pytest.raises(ValueError):
        est2.resize(1, survivors=[5])


# ---------------------------------------------------------------------------
# estimated-rate weights: unbiasedness once converged
# ---------------------------------------------------------------------------

def test_estimated_weights_ghat_unbiased_once_converged(rng_key):
    """E[ghat] under weights fitted to the ONLINE estimate: exactly
    unbiased w.r.t. the estimated rates (closed form), and empirically
    unbiased w.r.t. the true process once the estimator has converged —
    with FAR less bias than the mean-rate weights the plane replaces."""
    proc = HeterogeneousRates.two_class(16, p_slow=0.8, p_fast=0.02,
                                        slow_fraction=0.3)
    q_true = np.asarray(proc.rates(), np.float64)
    tr = np.asarray(proc.sample_trace(rng_key, 2000), np.float64)
    est = RateEstimator(16, alpha=0.02)
    for t in range(600):
        est.update(tr[t])
    q_est = est.rates

    alloc = coding.rate_aware_allocation(q_est, 16, 3)
    W = np.asarray(coding.encode_weights(alloc, rates=q_est), np.float64)
    # exact w.r.t. the estimate (the fitting identity)
    np.testing.assert_allclose(q_est @ W, 1.0, rtol=1e-6)

    grads = np.random.default_rng(3).normal(size=(16, 8))
    dense = grads.sum(0)
    scale = np.abs(dense).max()
    # empirical expectation over fresh masks from the TRUE process
    ghat_mean = (tr[600:] @ (W @ grads)).mean(axis=0)
    err_est = np.abs(ghat_mean - dense).max()
    p_bar = float(1.0 - q_true.mean())
    W_mean = np.asarray(coding.encode_weights(alloc, p_bar), np.float64)
    err_mean = np.abs((tr[600:] @ (W_mean @ grads)).mean(axis=0) - dense).max()
    assert err_est < 0.15 * scale
    assert err_est < 0.5 * err_mean     # the plane beats the mean-rate bug


# ---------------------------------------------------------------------------
# CodingPlan: refit-every-step, re-allocate only on drift
# ---------------------------------------------------------------------------

def test_coding_plan_drift_controller():
    q0 = np.linspace(0.5, 0.9, 8)
    plan = CodingPlan.create(q0, 8, 3, drift_threshold=0.1)
    S0 = plan.allocation.S.copy()

    # below threshold: W refits, allocation and epoch stay
    cs, info = plan.maybe_replan(q0 + 0.05)
    assert not info["reallocated"] and plan.epoch == 0
    assert info["drift"] == pytest.approx(0.05)
    np.testing.assert_array_equal(plan.allocation.S, S0)
    W_shift = np.asarray(coding.encode_weights(plan.allocation,
                                               rates=q0 + 0.05))
    np.testing.assert_array_equal(np.asarray(cs.W), W_shift)

    # past threshold: re-allocation + epoch bump, planned rates move
    q_drift = q0.copy()
    q_drift[0] = 0.1
    cs2, info2 = plan.maybe_replan(q_drift)
    assert info2["reallocated"] and plan.epoch == 1 and int(cs2.epoch) == 1
    np.testing.assert_array_equal(plan.rates_planned, q_drift)
    # the new placement compensates the now-unreliable rank 0
    assert plan.allocation.S[1:, 0].sum() >= S0[1:, 0].sum()

    # rates=None (nothing observed yet) keeps the planned rates
    cs3, info3 = maybe_replan(plan, None)
    assert not info3["reallocated"] and info3["drift"] == 0.0
    np.testing.assert_array_equal(np.asarray(cs3.rates_estimate),
                                  np.asarray(cs2.rates_estimate))

    # min_rate floors a dead rank's estimate before weight fitting
    dead = q_drift.copy()
    dead[3] = 0.0
    cs4, _ = plan.maybe_replan(dead)
    assert np.asarray(cs4.rates_estimate)[3] == pytest.approx(plan.min_rate)
    assert np.isfinite(np.asarray(cs4.W)).all()


def test_coding_plan_pinned_oracle_reproduces_static_w_bitwise():
    """The parity invariant at the unit level: allocation pinned + rates
    pinned to the oracle -> W bit-for-bit the static encode_weights."""
    alloc = coding.cyclic_allocation(6, 6, 2)
    for p in (0.1, 0.25, 0.4):
        oracle = np.full((6,), 1.0 - p)
        plan = CodingPlan.create(oracle, 6, 2, allocation=alloc)
        cs, info = maybe_replan(plan, oracle)
        assert not info["reallocated"]
        np.testing.assert_array_equal(
            np.asarray(cs.W), np.asarray(coding.encode_weights(alloc, p)))


def test_coding_plan_resize_membership_change():
    plan = CodingPlan.create(np.linspace(0.4, 0.9, 8), 8, 3)
    plan.resize(np.linspace(0.5, 0.9, 6), 8)
    assert plan.epoch == 1
    assert plan.allocation.num_devices == 6
    assert plan.allocation.num_subsets == 8
    assert int(plan.allocation.S.sum()) == 3 * 8   # budget preserved


# ---------------------------------------------------------------------------
# exact-load allocator mode (shape-stable batches for the mesh)
# ---------------------------------------------------------------------------

def test_exact_load_allocation_uniform_loads():
    q = HeterogeneousRates.two_class(8, p_slow=0.8, p_fast=0.02,
                                     slow_fraction=0.25).rates()
    alloc = coding.rate_aware_allocation(q, 8, 3, exact_load=True)
    loads = np.asarray(alloc.S).sum(axis=1)
    np.testing.assert_array_equal(loads, np.full(8, 3))    # d*M/N each
    assert int(alloc.S.sum()) == 24
    assert (alloc.d >= 1).all()
    # still beats cyclic coverage under heterogeneity
    cov = coding.expected_coverage(alloc, q)
    cov_cyc = coding.expected_coverage(coding.cyclic_allocation(8, 8, 3), q)
    assert cov.mean() >= cov_cyc.mean()


def test_exact_load_requires_divisibility():
    with pytest.raises(ValueError):
        coding.rate_aware_allocation(np.full(5, 0.7), 8, 3, exact_load=True)
    # 5 ranks, 10 subsets, d=2 -> budget 20, cap 4: fine
    alloc = coding.rate_aware_allocation(np.linspace(0.3, 0.9, 5), 10, 2,
                                         exact_load=True)
    np.testing.assert_array_equal(np.asarray(alloc.S).sum(axis=1),
                                  np.full(5, 4))


# ---------------------------------------------------------------------------
# elastic_rescale_ef edge cases (grow / shrink-to-one / flat mismatch)
# ---------------------------------------------------------------------------

def test_elastic_rescale_ef_grow_keeps_survivors_zero_inits_joiners():
    rng = np.random.default_rng(0)
    e = rng.normal(size=(2, 3, 16)).astype(np.float32)
    new = elastic_rescale_ef(e, (2, 3), (5, 3), 16)
    assert new.shape == (5, 3, 16) and new.dtype == e.dtype
    np.testing.assert_array_equal(new[:2], e)
    assert np.all(new[2:] == 0.0)
    # survivor error sum is preserved (the Appendix-C invariant)
    assert new.sum() == pytest.approx(e.sum(), rel=1e-6)


def test_elastic_rescale_ef_shrink_to_single_rank():
    e = np.arange(4 * 8, dtype=np.float32).reshape(4, 8)
    new = elastic_rescale_ef(e, (4,), (1,), 8)
    assert new.shape == (1, 8)
    np.testing.assert_array_equal(new[0], e[0])


def test_elastic_rescale_ef_flat_truncate_and_pad():
    """Shard counts that do not divide the coordinate dimension change the
    padded local flat size across a resize: the tail truncates (shrinking)
    or zero-pads (growing) while the common prefix is carried."""
    e = np.arange(2 * 10, dtype=np.float32).reshape(2, 10)
    trunc = elastic_rescale_ef(e, (2,), (2,), 7)
    assert trunc.shape == (2, 7)
    np.testing.assert_array_equal(trunc, e[:, :7])
    grown = elastic_rescale_ef(e, (2,), (3,), 13)
    assert grown.shape == (3, 13)
    np.testing.assert_array_equal(grown[:2, :10], e)
    assert np.all(grown[:, 10:] == 0.0) and np.all(grown[2] == 0.0)
    # both at once, across a 2-d device grid
    e2 = np.arange(2 * 2 * 6, dtype=np.float32).reshape(2, 2, 6)
    both = elastic_rescale_ef(e2, (2, 2), (1, 4), 4)
    assert both.shape == (1, 4, 4)
    np.testing.assert_array_equal(both[0, :2], e2[0, :, :4])
    assert np.all(both[0, 2:] == 0.0)


# ---------------------------------------------------------------------------
# the 1000-rank fleet floor (host hot paths stay interactive)
# ---------------------------------------------------------------------------

def test_thousand_rank_fleet_under_budget(rng_key):
    """1024-rank allocation + 1000 sampled masks + StepTimer + estimator
    updates inside the fig11 wall-clock budget (the old dense-argmax
    allocator alone took minutes at this scale)."""
    from benchmarks import fig11_elastic as f11
    out = f11.run_perf_floor()          # SystemExit on violation
    assert out["N"] == 1024 and out["masks"] == 1000
    assert out["total_s"] < f11.PERF_BUDGET_S
    assert out["alloc_replicas"] == 3 * 1024


# ---------------------------------------------------------------------------
# static vs elastic production setup: bit-for-bit at pinned rates
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_static_vs_elastic_train_setup_bitwise():
    """The end-to-end acceptance gate on the REAL mesh step: a TrainRun
    with elastic=True, its CodingState pinned to the setup's own planned
    (oracle) rates, produces bit-for-bit the params and error state of the
    static TrainRun for a multi-step run — the dynamic plane is a pure
    refactor until the estimates actually move."""
    run_sub("""
    import dataclasses
    from repro.configs import REGISTRY
    from repro.configs.common import ShapeCfg
    from repro.launch.train import (TrainRun, build_train_setup,
                                    elastic_coding_state, make_batch_for_step)
    spec = REGISTRY["olmoe-1b-7b"]
    spec = dataclasses.replace(spec, coding=dataclasses.replace(
        spec.coding, group_size=32, block_size=64, k_per_block=8,
        straggler_p=0.25))
    shape = ShapeCfg("train", seq_len=64, global_batch=16)
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    key = jax.random.PRNGKey(0)

    results = {}
    for name, run in (("static", TrainRun(base_lr=5e-3, mode="cocoef",
                                          straggler="hetero")),
                      ("elastic", TrainRun(base_lr=5e-3, mode="cocoef",
                                           straggler="hetero",
                                           elastic=True))):
        setup = build_train_setup(spec, mesh, shape, run, smoke=True)
        params, e, opt = setup.init_state(key)
        jstep = jax.jit(setup.train_step)
        for t in range(3):
            batch = jax.device_put(
                make_batch_for_step(setup, spec, shape, key, t, smoke=True),
                setup.batch_shardings)
            extra = ()
            if run.elastic:
                state, info = elastic_coding_state(setup)   # pinned: planned
                assert not info["reallocated"]
                extra = (state,)
            params, e, opt, m = jstep(params, e, opt, batch, jnp.int32(t),
                                      key, *extra)
        results[name] = (jax.tree.map(np.asarray, params), np.asarray(e),
                         float(m["loss"]))

    ps, es, ls = results["static"]
    pe, ee, le = results["elastic"]
    assert ls == le, (ls, le)
    for a, b in zip(jax.tree.leaves(ps), jax.tree.leaves(pe)):
        assert np.array_equal(a, b)
    assert np.array_equal(es, ee)
    """, timeout=600)


@pytest.mark.slow
def test_fig11_smoke_estimated_tracks_oracle(tmp_path):
    """The fig11 acceptance contract: the online-estimated plane's
    time-to-target stays close to the oracle's under every process, and
    the mid-run membership change never resets the loss curve."""
    from benchmarks import fig11_elastic as f11
    res = f11.run(smoke=True, out_dir=tmp_path)
    assert (tmp_path / "fig11.json").exists()
    assert set(res["curves"]) == {"hetero", "markov", "trace"}
    for pname, s in res["summary"].items():
        t = s["time_to_target_s"]
        assert t["oracle"] is not None and t["estimated"] is not None, pname
        assert t["estimated"] <= 1.10 * t["oracle"] + 1e-9, (pname, t)
        assert s["resize_continuous"], (pname, s)
        assert s["mean_replans"]["estimated"] > 0      # the plane is live
        assert s["mean_replans"]["oracle"] == 0
